"""Per-family layer blocks: init + train/prefill/decode application.

Every block is pre-norm residual.  Attention compute routes through
``repro.dist.flash`` so the mesh strategy (head-parallel / context-parallel
/ flash-decode lse-combine) is chosen in one place.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.dist.flash import (causal_attention, decode_update_and_attend,
                              mla_decode_attend)
from repro.dist.sharding import current_ctx
from .attention import (cross_attention, cross_attn_init, gqa_init, gqa_qkv,
                        mla_init, _mla_qkv_full)
from .layers import (Params, apply_rope, cast_params, gelu_mlp,
                     gelu_mlp_init, layernorm, layernorm_init, mlp, mlp_init,
                     rmsnorm, rmsnorm_init, _dtype)
from .mamba import mamba_decode, mamba_init, mamba_prefill, mamba_train
from .moe import moe_ffn, moe_init, zero_aux
import numpy as np


# ------------------------------------------------------------- GQA attention

def _attn_apply(p: Params, x: jax.Array, cfg, positions: jax.Array,
                want_cache: bool = False):
    q, k, v = gqa_qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    out = causal_attention(q, k, v, cfg=cfg, window=cfg.sliding_window)
    o = jnp.einsum("bshk,hkd->bsd", out, p["w_o"])
    if want_cache:
        # decode caches are HEAD-MAJOR (B, K, S, hd): the per-step decode
        # dot then needs no transpose of the cache stripe (§Perf)
        return o, {"k": jnp.transpose(k, (0, 2, 1, 3)),
                   "v": jnp.transpose(v, (0, 2, 1, 3))}
    return o


def _attn_decode(p: Params, x: jax.Array, cfg, cache: Dict[str, jax.Array],
                 cur_len: jax.Array):
    q, k, v = gqa_qkv(p, x, cfg)
    pos = jnp.asarray(cur_len)[None][None, :]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    out, kc, vc = decode_update_and_attend(
        q, k, v, cache["k"], cache["v"], cur_len, cfg=cfg,
        window=cfg.sliding_window)
    o = jnp.einsum("bshk,hkd->bsd", out, p["w_o"])
    return o, {"k": kc, "v": vc}


# -------------------------------------------------------------- MLA attention

def _mla_apply(p: Params, x: jax.Array, cfg, positions: jax.Array,
               want_cache: bool = False):
    q, k, v, c_kv, k_rope = _mla_qkv_full(p, x, cfg, positions)
    out = causal_attention(q, k, v, cfg=cfg)
    o = jnp.einsum("bshk,hkd->bsd", out, p["w_o"])
    if want_cache:
        return o, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}
    return o


def _mla_decode(p: Params, x: jax.Array, cfg, cache: Dict[str, jax.Array],
                cur_len: jax.Array):
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    rkv = cfg.kv_lora_rank
    cq = rmsnorm(p["q_norm"], jnp.einsum("bsd,dr->bsr", x, p["w_dq"]),
                 cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    pos = jnp.asarray(cur_len)[None][None, :]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    dkv = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"])
    c_new = rmsnorm(p["kv_norm"], dkv[..., :rkv], cfg.norm_eps)
    kr_new = apply_rope(dkv[..., None, rkv:], pos, cfg.rope_theta)[:, :, 0]
    q_latent = jnp.einsum("bshk,rhk->bshr", q_nope, p["w_uk"])
    out_latent, c_kv, k_rope = mla_decode_attend(
        q_latent, q_rope, c_new, kr_new, cache["c_kv"], cache["k_rope"],
        cur_len, scale=1.0 / np.sqrt(dn + dr))
    out = jnp.einsum("bshr,rhk->bshk", out_latent, p["w_uv"])
    o = jnp.einsum("bshk,hkd->bsd", out, p["w_o"])
    return o, {"c_kv": c_kv, "k_rope": k_rope}


# --------------------------------------------------------------- decoder layer

def decoder_layer_init(key, cfg, kind: str) -> Params:
    """kind ∈ {dense, moe, mla_dense, mla_moe}."""
    k1, k2 = jax.random.split(key)
    dt = _dtype(cfg.param_dtype)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model, dt),
                 "ln2": rmsnorm_init(cfg.d_model, dt)}
    if kind.startswith("mla"):
        p["attn"] = mla_init(k1, cfg)
    else:
        p["attn"] = gqa_init(k1, cfg)
    if kind.endswith("moe"):
        p["moe"] = moe_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dt)
    return p


def _sp(x):
    return current_ctx().constrain(x, "dp", "sp", None)


def decoder_layer_train(p: Params, x: jax.Array, cfg, positions: jax.Array,
                        kind: str) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Returns (x, aux dict) — see ``moe.zero_aux`` for the aux schema."""
    p = cast_params(p, cfg.dtype)
    x = _sp(x)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    attn = _mla_apply(p["attn"], h, cfg, positions) if kind.startswith("mla") \
        else _attn_apply(p["attn"], h, cfg, positions)
    x = _sp(x + attn)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind.endswith("moe"):
        f, aux = moe_ffn(p["moe"], h, cfg)
    else:
        f, aux = mlp(p["mlp"], h), zero_aux()
    return _sp(x + f), aux


def decoder_layer_prefill(p: Params, x: jax.Array, cfg, positions: jax.Array,
                          kind: str) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    p = cast_params(p, cfg.dtype)
    x = _sp(x)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind.startswith("mla"):
        attn, cache = _mla_apply(p["attn"], h, cfg, positions, want_cache=True)
    else:
        attn, cache = _attn_apply(p["attn"], h, cfg, positions, want_cache=True)
    x = _sp(x + attn)
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind.endswith("moe"):
        f, _ = moe_ffn(p["moe"], h, cfg)
    else:
        f = mlp(p["mlp"], h)
    return _sp(x + f), cache


def decoder_layer_decode(p: Params, x: jax.Array, cfg,
                         cache: Dict[str, jax.Array], cur_len: jax.Array,
                         kind: str) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    p = cast_params(p, cfg.dtype)
    h = rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind.startswith("mla"):
        attn, cache = _mla_decode(p["attn"], h, cfg, cache, cur_len)
    else:
        attn, cache = _attn_decode(p["attn"], h, cfg, cache, cur_len)
    x = x + attn
    h = rmsnorm(p["ln2"], x, cfg.norm_eps)
    if kind.endswith("moe"):
        f, _ = moe_ffn(p["moe"], h, cfg)
    else:
        f = mlp(p["mlp"], h)
    return x + f, cache


# ----------------------------------------------------------------- mamba layer

def mamba_layer_init(key, cfg) -> Params:
    dt = _dtype(cfg.param_dtype)
    return {"ln": rmsnorm_init(cfg.d_model, dt), "mixer": mamba_init(key, cfg)}


def mamba_layer_train(p: Params, x: jax.Array, cfg) -> jax.Array:
    p = cast_params(p, cfg.dtype)
    x = _sp(x)
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    return _sp(x + mamba_train(p["mixer"], h, cfg))


def mamba_layer_prefill(p: Params, x: jax.Array, cfg):
    p = cast_params(p, cfg.dtype)
    x = _sp(x)
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    y, cache = mamba_prefill(p["mixer"], h, cfg)
    return _sp(x + y), cache


def mamba_layer_decode(p: Params, x: jax.Array, cfg, cache):
    p = cast_params(p, cfg.dtype)
    h = rmsnorm(p["ln"], x, cfg.norm_eps)
    y, cache = mamba_decode(p["mixer"], h, cfg, cache)
    return x + y, cache


# ------------------------------------------------------------- whisper blocks

def enc_layer_init(key, cfg) -> Params:
    k1, k2 = jax.random.split(key)
    dt = _dtype(cfg.param_dtype)
    return {"ln1": layernorm_init(cfg.d_model, dt),
            "attn": cross_attn_init(k1, cfg),       # MHA weights (q,k,v,o)
            "ln2": layernorm_init(cfg.d_model, dt),
            "mlp": gelu_mlp_init(k2, cfg.d_model, cfg.d_ff, dt)}


def enc_layer_apply(p: Params, x: jax.Array, cfg) -> jax.Array:
    p = cast_params(p, cfg.dtype)
    h = layernorm(p["ln1"], x, cfg.norm_eps)
    # bidirectional self-attention (reuse cross_attention with enc=h)
    attn = cross_attention(p["attn"], h, h)
    x = x + attn
    h = layernorm(p["ln2"], x, cfg.norm_eps)
    return x + gelu_mlp(p["mlp"], h)


def dec_layer_init(key, cfg) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    dt = _dtype(cfg.param_dtype)
    return {"ln1": layernorm_init(cfg.d_model, dt),
            "attn": gqa_init(k1, cfg),
            "ln_x": layernorm_init(cfg.d_model, dt),
            "cross": cross_attn_init(k2, cfg),
            "ln2": layernorm_init(cfg.d_model, dt),
            "mlp": gelu_mlp_init(k3, cfg.d_model, cfg.d_ff, dt)}


def dec_layer_train(p: Params, x: jax.Array, enc: jax.Array, cfg,
                    positions: jax.Array) -> jax.Array:
    p = cast_params(p, cfg.dtype)
    h = layernorm(p["ln1"], x, cfg.norm_eps)
    x = x + _attn_apply(p["attn"], h, cfg, positions)
    h = layernorm(p["ln_x"], x, cfg.norm_eps)
    x = x + cross_attention(p["cross"], h, enc)
    h = layernorm(p["ln2"], x, cfg.norm_eps)
    return x + gelu_mlp(p["mlp"], h)


def dec_layer_prefill(p: Params, x: jax.Array, enc: jax.Array, cfg,
                      positions: jax.Array):
    p = cast_params(p, cfg.dtype)
    h = layernorm(p["ln1"], x, cfg.norm_eps)
    attn, cache = _attn_apply(p["attn"], h, cfg, positions, want_cache=True)
    x = x + attn
    h = layernorm(p["ln_x"], x, cfg.norm_eps)
    # cache cross-attention K/V once
    ck = jnp.einsum("bsd,dhk->bshk", enc, p["cross"]["w_k"])
    cv = jnp.einsum("bsd,dhk->bshk", enc, p["cross"]["w_v"])
    q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["w_q"])
    from .attention import full_attention
    xo = full_attention(q, ck, cv, causal=False)
    x = x + jnp.einsum("bshk,hkd->bsd", xo, p["cross"]["w_o"])
    h = layernorm(p["ln2"], x, cfg.norm_eps)
    x = x + gelu_mlp(p["mlp"], h)
    return x, {**cache, "cross_k": ck, "cross_v": cv}


def dec_layer_decode(p: Params, x: jax.Array, cfg, cache, cur_len):
    p = cast_params(p, cfg.dtype)
    h = layernorm(p["ln1"], x, cfg.norm_eps)
    attn, new_cache = _attn_decode(p["attn"],
                                   h, cfg, {"k": cache["k"], "v": cache["v"]},
                                   cur_len)
    x = x + attn
    h = layernorm(p["ln_x"], x, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["cross"]["w_q"])
    from .attention import full_attention
    xo = full_attention(q, cache["cross_k"], cache["cross_v"], causal=False)
    x = x + jnp.einsum("bshk,hkd->bsd", xo, p["cross"]["w_o"])
    h = layernorm(p["ln2"], x, cfg.norm_eps)
    x = x + gelu_mlp(p["mlp"], h)
    return x, {**new_cache, "cross_k": cache["cross_k"],
               "cross_v": cache["cross_v"]}
