"""Mixture-of-Experts layer with TPU-idiomatic expert parallelism.

Design (see DESIGN.md §6): activations are replicated over the "model" mesh
axis (TP convention), expert weights are sharded over it (EP).  Every model
shard routes the *same* local tokens deterministically, computes only its
local experts with a sort-based grouped-GEMM dispatch, and a single psum
over "model" combines expert contributions — no all-to-all, no (T,E,C)
one-hot einsum, no FLOPs inflation.

Token dropping: per-expert capacity ``C = ceil(k·T·capacity_factor / E)``
(local tokens T).  Dropped tokens fall through on the residual path.

This mirrors the paper's §6 *data block partitioning*: the expert weight
bank is one logical block partitioned E-ways; each shard acquires its
disjoint partition in EW mode (see ``repro.dist.sharding`` for the bridge).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Params, dense_init, mlp, mlp_init, _dtype


def moe_init(key, cfg) -> Params:
    d = cfg.d_model
    e = cfg.num_experts
    f = cfg.moe_d_ff or cfg.d_ff
    dt = _dtype(cfg.param_dtype)
    keys = jax.random.split(key, 6)

    def expert_bank(k, shape_in, shape_out):
        ws = jax.random.normal(k, (e, shape_in, shape_out), dtype=jnp.float32)
        return (ws / np.sqrt(shape_in)).astype(dt)

    p: Params = {
        "router": dense_init(keys[0], d, (e,), jnp.float32),
        "w_gate": expert_bank(keys[1], d, f),
        "w_up": expert_bank(keys[2], d, f),
        "w_down": expert_bank(keys[3], f, d),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = mlp_init(keys[4], d, f * cfg.num_shared_experts, dt)
    if cfg.moe_dense_residual:
        p["dense_residual"] = mlp_init(keys[5], d, cfg.d_ff, dt)
    return p


def _route(logits: jax.Array, k: int, renormalize: bool = True
           ) -> Tuple[jax.Array, jax.Array]:
    """Top-k routing.  logits: (T, E) fp32 → (gates (T,k) fp32, idx (T,k) i32)."""
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    if renormalize:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def load_balance_loss(logits: jax.Array, idx: jax.Array, num_experts: int) -> jax.Array:
    """Switch-style auxiliary loss: E · Σ_e f_e · P_e."""
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    # fraction of tokens whose top-1 choice is e
    top1 = idx[:, 0]
    f = jnp.mean(jax.nn.one_hot(top1, num_experts, dtype=jnp.float32), axis=0)
    p = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p)


def _grouped_experts(x_flat: jax.Array, gates: jax.Array, idx: jax.Array,
                     w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
                     capacity: int, e_offset: int) -> jax.Array:
    """Sort-based grouped-GEMM dispatch for one shard's local experts.

    x_flat: (T, D); gates/idx: (T, k); w_*: (E_loc, D, F)/(E_loc, F, D).
    Returns (T, D) sum of local-expert contributions (token-dropped beyond
    ``capacity``).
    """
    t, d = x_flat.shape
    k = idx.shape[1]
    e_loc = w_gate.shape[0]
    n = t * k

    flat_e = idx.reshape(n)                                   # global expert ids
    flat_g = gates.reshape(n)
    tok_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)

    # rank of each (token, choice) within its expert, in token order:
    # stable-sort by expert id, then position = index - start_of_run,
    # where start_of_run propagates via a running maximum.
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    arange_n = jnp.arange(n, dtype=jnp.int32)
    new_run = jnp.concatenate([jnp.ones((1,), jnp.bool_),
                               sorted_e[1:] != sorted_e[:-1]])
    starts = jnp.where(new_run, arange_n, 0)
    starts = jax.lax.associative_scan(jnp.maximum, starts)
    pos_sorted = arange_n - starts
    pos = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)

    local_e = flat_e - e_offset
    valid = (local_e >= 0) & (local_e < e_loc) & (pos < capacity) & (flat_g > 0)
    safe_e = jnp.where(valid, local_e, 0).astype(jnp.int32)
    safe_pos = jnp.where(valid, pos, capacity).astype(jnp.int32)  # row C = trash

    w = (flat_g * valid).astype(jnp.float32)
    x_grouped = _dispatch(x_flat, safe_e, safe_pos, tok_ids, w,
                          e_loc, capacity, str(x_flat.dtype), t)

    g = jnp.einsum("ecd,edf->ecf", x_grouped, w_gate)
    u = jnp.einsum("ecd,edf->ecf", x_grouped, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x_flat.dtype) * u
    y_grouped = jnp.einsum("ecf,efd->ecd", h, w_down)         # (E_loc, C, D)

    y = _combine(y_grouped, safe_e, safe_pos, tok_ids, w, t)
    return y.astype(x_flat.dtype)


def _chunks(n: int, target: int = 16384) -> int:
    c = min(n, target)
    while n % c:
        c //= 2
    return c


def _chunked(arrs, c):
    return tuple(a.reshape(a.shape[0] // c, c, *a.shape[1:]) for a in arrs)


# Dispatch and combine are (bi)linear scatter/gathers over the routing
# tables.  They run as chunked scans so the (T·k, D) gather never
# materializes, and carry custom VJPs so the *backward* is the mirror-image
# chunked scan (plain autodiff of the scan would stack per-chunk gather
# residuals — O(T·k·D) again).

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _dispatch(x_flat, e, p, t, w, e_loc, capacity, dtype_name, t_total):
    d = x_flat.shape[1]
    c = _chunks(e.shape[0])

    def step(acc, inp):
        e_i, p_i, t_i, w_i = inp
        xc = x_flat[t_i] * (w_i > 0)[:, None].astype(x_flat.dtype)
        return acc.at[e_i, p_i].add(xc, mode="drop"), None

    acc0 = jnp.zeros((e_loc, capacity + 1, d), dtype=x_flat.dtype)
    acc, _ = jax.lax.scan(step, acc0, _chunked((e, p, t, w), c))
    return acc[:, :capacity]


def _dispatch_fwd(x_flat, e, p, t, w, e_loc, capacity, dtype_name, t_total):
    out = _dispatch(x_flat, e, p, t, w, e_loc, capacity, dtype_name, t_total)
    return out, (e, p, t, w)


def _dispatch_bwd(e_loc, capacity, dtype_name, t_total, res, g_out):
    (e, p, t, w) = res
    d = g_out.shape[-1]
    g_ext = jnp.concatenate(
        [g_out, jnp.zeros((e_loc, 1, d), g_out.dtype)], axis=1)
    c = _chunks(e.shape[0])

    def step(acc, inp):
        e_i, p_i, t_i, w_i = inp
        gc = g_ext[e_i, p_i] * (w_i > 0)[:, None].astype(g_ext.dtype)
        return acc.at[t_i].add(gc, mode="drop"), None

    dx0 = jnp.zeros((t_total, d), dtype=g_out.dtype)
    dx, _ = jax.lax.scan(step, dx0, _chunked((e, p, t, w), c))
    return (dx.astype(dtype_name), None, None, None, None)


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _combine(y_grouped, e, p, t, w, t_total):
    d = y_grouped.shape[-1]
    y_ext = jnp.concatenate(
        [y_grouped, jnp.zeros((y_grouped.shape[0], 1, d), y_grouped.dtype)],
        axis=1)
    c = _chunks(e.shape[0])

    def step(acc, inp):
        e_i, p_i, t_i, w_i = inp
        yc = y_ext[e_i, p_i].astype(jnp.float32) * w_i[:, None]
        return acc.at[t_i].add(yc, mode="drop"), None

    y0 = jnp.zeros((t_total, d), dtype=jnp.float32)
    y, _ = jax.lax.scan(step, y0, _chunked((e, p, t, w), c))
    return y


def _combine_fwd(y_grouped, e, p, t, w, t_total):
    return _combine(y_grouped, e, p, t, w, t_total), (y_grouped, e, p, t, w)


def _combine_bwd(t_total, res, dy):
    y_grouped, e, p, t, w = res
    e_loc, cap, d = y_grouped.shape
    c = _chunks(e.shape[0])

    def step(carry, inp):
        dg_acc, dw_parts = carry
        e_i, p_i, t_i, w_i = inp
        dy_rows = dy[t_i]                                    # (c, D) f32
        dg_acc = dg_acc.at[e_i, p_i].add(
            (dy_rows * w_i[:, None]).astype(dg_acc.dtype), mode="drop")
        yg = jnp.concatenate(
            [y_grouped, jnp.zeros((e_loc, 1, d), y_grouped.dtype)], axis=1
        )[e_i, p_i].astype(jnp.float32)
        dw_i = jnp.sum(yg * dy_rows, axis=-1)                # (c,)
        return (dg_acc, dw_parts), dw_i

    dg0 = jnp.zeros((e_loc, cap + 1, d), dtype=jnp.float32)
    (dg, _), dws = jax.lax.scan(step, (dg0, 0.0), _chunked((e, p, t, w), c))
    dw = dws.reshape(-1)
    return (dg[:, :cap].astype(y_grouped.dtype), None, None, None, dw)


_combine.defvjp(_combine_fwd, _combine_bwd)


def _capacity(cfg, tokens: int) -> int:
    c = int(np.ceil(cfg.experts_per_token * tokens * cfg.capacity_factor
                    / cfg.num_experts))
    return max(8, int(np.ceil(c / 8) * 8))


def moe_ffn(params: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """MoE feed-forward.  x: (B, S, D) → (y, aux_loss).

    Routing (cheap, (T,E)) runs in global view; expert compute runs under
    ``shard_map`` when a mesh with a "model" axis is ambient: expert banks
    are sharded E→"model" (EP) and D→"data" (FSDP, re-gathered per layer),
    every model shard computes only its local experts on its (replicated-
    over-model) local tokens, and one psum over "model" combines — no
    all-to-all, no one-hot dispatch einsum.
    """
    from repro.dist.sharding import current_ctx, shard_map
    from jax.sharding import PartitionSpec as P

    ctx = current_ctx()
    b, s, d = x.shape
    t = b * s

    x = ctx.constrain(x, "dp", None, None)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    logits = ctx.constrain(logits, "dp", None, None)
    gates, idx = _route(logits.reshape(t, cfg.num_experts),
                        cfg.experts_per_token)
    aux = load_balance_loss(logits.reshape(t, cfg.num_experts), idx,
                            cfg.num_experts)
    gates_b = gates.reshape(b, s, -1)
    idx_b = idx.reshape(b, s, -1)

    m = ctx.model_size
    use_shmap = (ctx.active and m > 1 and cfg.num_experts % m == 0
                 and not ctx.pure_dp)

    if not use_shmap:
        y = _grouped_experts(x.reshape(t, d), gates, idx,
                             params["w_gate"], params["w_up"], params["w_down"],
                             _capacity(cfg, t), 0).reshape(b, s, d)
    else:
        e_loc = cfg.num_experts // m
        dp_b = ctx.resolve("dp", b)
        # FSDP axes the expert banks are sharded over (may span pod+data)
        fs = ctx.resolve("fsdp", d)

        def inner(xx, gg, ii, wg, wu, wd):
            if fs is not None:
                wg = jax.lax.all_gather(wg, fs, axis=1, tiled=True)
                wu = jax.lax.all_gather(wu, fs, axis=1, tiled=True)
                wd = jax.lax.all_gather(wd, fs, axis=2, tiled=True)
            bl, sl, _ = xx.shape
            tl = bl * sl
            e_off = jax.lax.axis_index("model") * e_loc
            y = _grouped_experts(xx.reshape(tl, d), gg.reshape(tl, -1),
                                 ii.reshape(tl, -1), wg, wu, wd,
                                 _capacity(cfg, tl), e_off)
            y = jax.lax.psum(y, "model")
            return y.reshape(bl, sl, d)

        xspec = P(dp_b, None, None)
        fn = shard_map(
            inner, ctx.mesh,
            in_specs=(xspec, xspec, xspec,
                      P("model", fs, None), P("model", fs, None),
                      P("model", None, fs)),
            out_specs=xspec, check=False)
        y = fn(x, gates_b, idx_b.astype(jnp.int32),
               params["w_gate"], params["w_up"], params["w_down"])

    if "shared" in params:
        y = y + mlp(params["shared"], x)
    if "dense_residual" in params:
        y = y + mlp(params["dense_residual"], x)
    return y, aux
