"""Mixture-of-Experts layer with capacity-bucketed all-to-all dispatch.

Expert parallelism (the default, ``cfg.moe_dispatch="a2a"``): tokens are
sharded over the "model" mesh axis alongside the expert banks.  Each shard
packs its local routed (token, choice) pairs into per-destination-expert
buckets of capacity ``C`` (drop on overflow, stats recorded), a single
``lax.all_to_all`` hands every peer exactly the §6-disjoint bucket ranges
bound for its local experts, the sort-based grouped-GEMM runs on purely
local experts, and the reverse all-to-all returns results to the source
shard for the gate-weighted combine.  Per shard this moves
``2 · E · C · D`` bucket bytes — independent of the model-axis width —
where the old replicate-over-"model" + psum combine moved the *full* token
set twice per shard (O(E) wasted bytes at production expert counts; see
``benchmarks/bench_moe.py``).  The exchange rides a custom VJP whose
backward is the *reverse* exchange, never a psum.

The legacy path (``moe_dispatch="psum"``) replicates activations over
"model", computes local experts against all tokens, and psums the combine.
It remains the fallback when the sequence does not divide the model axis,
and the baseline the a2a path is benchmarked against.

Token dropping: per-expert capacity ``C = ceil(k·T·capacity_factor / E)``
over the tokens T that route *together* (per source shard under a2a —
total expert capacity ``m·C`` matches the psum path's global ``C``).
Dropped (token, choice) pairs fall through on the residual path; drops are
deterministic — the pack is a stable sort, so the earliest tokens keep
their slots.

This mirrors the paper's §6 *data block partitioning* twice over: the
expert weight bank is one logical block partitioned E-ways, and each
shard's bucket buffer is one block whose per-destination ranges are the
disjoint §6 partitions the all-to-all exchanges (see
``repro.dist.sharding.moe_bucket_ranges`` for the lowering).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Params, dense_init, mlp, mlp_init, _dtype


def moe_init(key, cfg) -> Params:
    d = cfg.d_model
    e = cfg.num_experts
    f = cfg.moe_d_ff or cfg.d_ff
    dt = _dtype(cfg.param_dtype)
    keys = jax.random.split(key, 6)

    def expert_bank(k, shape_in, shape_out):
        ws = jax.random.normal(k, (e, shape_in, shape_out), dtype=jnp.float32)
        return (ws / np.sqrt(shape_in)).astype(dt)

    p: Params = {
        "router": dense_init(keys[0], d, (e,), jnp.float32),
        "w_gate": expert_bank(keys[1], d, f),
        "w_up": expert_bank(keys[2], d, f),
        "w_down": expert_bank(keys[3], f, d),
    }
    if cfg.num_shared_experts > 0:
        p["shared"] = mlp_init(keys[4], d, f * cfg.num_shared_experts, dt)
    if cfg.moe_dense_residual:
        p["dense_residual"] = mlp_init(keys[5], d, cfg.d_ff, dt)
    return p


def _route(logits: jax.Array, k: int, renormalize: bool = True
           ) -> Tuple[jax.Array, jax.Array]:
    """Top-k routing.  logits: (T, E) fp32 → (gates (T,k) fp32, idx (T,k) i32)."""
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    if renormalize:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def load_balance_loss(logits: jax.Array, idx: jax.Array, num_experts: int) -> jax.Array:
    """Switch-style auxiliary loss over ALL k routed choices: E · Σ_e f_e·P_e.

    ``f_e`` is the fraction of (token, choice) dispatch slots assigned to
    expert e — scoring only the top-1 choice (the old behaviour) let a hot
    expert hide in everyone's 2nd..k-th slots while the actual dispatch
    distribution overloaded it.  At k=1 this is the classic Switch loss.
    """
    probs = jax.nn.softmax(logits, axis=-1)                  # (T, E)
    f = jnp.mean(jax.nn.one_hot(idx.reshape(-1), num_experts,
                                dtype=jnp.float32), axis=0)
    p = jnp.mean(probs, axis=0)
    return num_experts * jnp.sum(f * p)


def zero_aux() -> Dict[str, jax.Array]:
    """Zero MoE aux pytree (dense layers / non-MoE backbones)."""
    z = jnp.zeros((), jnp.float32)
    return {"loss": z, "dropped": z, "routed": z, "a2a_bytes": z}


def _expert_positions(flat_e: jax.Array, n: int) -> jax.Array:
    """Rank of each (token, choice) within its expert, in token order:
    stable-sort by expert id, then position = index - start_of_run,
    where start_of_run propagates via a running maximum."""
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    arange_n = jnp.arange(n, dtype=jnp.int32)
    new_run = jnp.concatenate([jnp.ones((1,), jnp.bool_),
                               sorted_e[1:] != sorted_e[:-1]])
    starts = jnp.where(new_run, arange_n, 0)
    starts = jax.lax.associative_scan(jnp.maximum, starts)
    pos_sorted = arange_n - starts
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)


def _grouped_experts(x_flat: jax.Array, gates: jax.Array, idx: jax.Array,
                     w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
                     capacity: int, e_offset: int
                     ) -> Tuple[jax.Array, jax.Array]:
    """Sort-based grouped-GEMM dispatch for one shard's local experts.

    x_flat: (T, D); gates/idx: (T, k); w_*: (E_loc, D, F)/(E_loc, F, D).
    Returns ``(y, kept)``: (T, D) sum of local-expert contributions
    (token-dropped beyond ``capacity``) and the per-token count of routed
    choices that landed in this shard's window *and* kept their slot.
    """
    t, d = x_flat.shape
    k = idx.shape[1]
    e_loc = w_gate.shape[0]
    n = t * k

    flat_e = idx.reshape(n)                                   # global expert ids
    flat_g = gates.reshape(n)
    tok_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    pos = _expert_positions(flat_e, n)

    local_e = flat_e - e_offset
    valid = (local_e >= 0) & (local_e < e_loc) & (pos < capacity) & (flat_g > 0)
    safe_e = jnp.where(valid, local_e, 0).astype(jnp.int32)
    safe_pos = jnp.where(valid, pos, capacity).astype(jnp.int32)  # row C = trash

    w = (flat_g * valid).astype(jnp.float32)
    x_grouped = _dispatch(x_flat, safe_e, safe_pos, tok_ids, w,
                          e_loc, capacity, str(x_flat.dtype), t)

    g = jnp.einsum("ecd,edf->ecf", x_grouped, w_gate)
    u = jnp.einsum("ecd,edf->ecf", x_grouped, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x_flat.dtype) * u
    y_grouped = jnp.einsum("ecf,efd->ecd", h, w_down)         # (E_loc, C, D)

    y = _combine(y_grouped, safe_e, safe_pos, tok_ids, w, t)
    kept = jnp.sum(valid.reshape(t, k), axis=1).astype(jnp.float32)
    return y.astype(x_flat.dtype), kept


def _chunks(n: int, target: int = 16384) -> int:
    c = min(n, target)
    while n % c:
        c //= 2
    return c


def _chunked(arrs, c):
    return tuple(a.reshape(a.shape[0] // c, c, *a.shape[1:]) for a in arrs)


# Dispatch and combine are (bi)linear scatter/gathers over the routing
# tables.  They run as chunked scans so the (T·k, D) gather never
# materializes, and carry custom VJPs so the *backward* is the mirror-image
# chunked scan (plain autodiff of the scan would stack per-chunk gather
# residuals — O(T·k·D) again).

@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8))
def _dispatch(x_flat, e, p, t, w, e_loc, capacity, dtype_name, t_total):
    d = x_flat.shape[1]
    c = _chunks(e.shape[0])

    def step(acc, inp):
        e_i, p_i, t_i, w_i = inp
        xc = x_flat[t_i] * (w_i > 0)[:, None].astype(x_flat.dtype)
        return acc.at[e_i, p_i].add(xc, mode="drop"), None

    acc0 = jnp.zeros((e_loc, capacity + 1, d), dtype=x_flat.dtype)
    acc, _ = jax.lax.scan(step, acc0, _chunked((e, p, t, w), c))
    return acc[:, :capacity]


def _dispatch_fwd(x_flat, e, p, t, w, e_loc, capacity, dtype_name, t_total):
    out = _dispatch(x_flat, e, p, t, w, e_loc, capacity, dtype_name, t_total)
    return out, (e, p, t, w)


def _dispatch_bwd(e_loc, capacity, dtype_name, t_total, res, g_out):
    (e, p, t, w) = res
    d = g_out.shape[-1]
    g_ext = jnp.concatenate(
        [g_out, jnp.zeros((e_loc, 1, d), g_out.dtype)], axis=1)
    c = _chunks(e.shape[0])

    def step(acc, inp):
        e_i, p_i, t_i, w_i = inp
        gc = g_ext[e_i, p_i] * (w_i > 0)[:, None].astype(g_ext.dtype)
        return acc.at[t_i].add(gc, mode="drop"), None

    dx0 = jnp.zeros((t_total, d), dtype=g_out.dtype)
    dx, _ = jax.lax.scan(step, dx0, _chunked((e, p, t, w), c))
    return (dx.astype(dtype_name), None, None, None, None)


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _combine(y_grouped, e, p, t, w, t_total):
    d = y_grouped.shape[-1]
    y_ext = jnp.concatenate(
        [y_grouped, jnp.zeros((y_grouped.shape[0], 1, d), y_grouped.dtype)],
        axis=1)
    c = _chunks(e.shape[0])

    def step(acc, inp):
        e_i, p_i, t_i, w_i = inp
        yc = y_ext[e_i, p_i].astype(jnp.float32) * w_i[:, None]
        return acc.at[t_i].add(yc, mode="drop"), None

    y0 = jnp.zeros((t_total, d), dtype=jnp.float32)
    y, _ = jax.lax.scan(step, y0, _chunked((e, p, t, w), c))
    return y


def _combine_fwd(y_grouped, e, p, t, w, t_total):
    return _combine(y_grouped, e, p, t, w, t_total), (y_grouped, e, p, t, w)


def _combine_bwd(t_total, res, dy):
    y_grouped, e, p, t, w = res
    e_loc, cap, d = y_grouped.shape
    c = _chunks(e.shape[0])

    def step(carry, inp):
        dg_acc, dw_parts = carry
        e_i, p_i, t_i, w_i = inp
        dy_rows = dy[t_i]                                    # (c, D) f32
        dg_acc = dg_acc.at[e_i, p_i].add(
            (dy_rows * w_i[:, None]).astype(dg_acc.dtype), mode="drop")
        yg = jnp.concatenate(
            [y_grouped, jnp.zeros((e_loc, 1, d), y_grouped.dtype)], axis=1
        )[e_i, p_i].astype(jnp.float32)
        dw_i = jnp.sum(yg * dy_rows, axis=-1)                # (c,)
        return (dg_acc, dw_parts), dw_i

    dg0 = jnp.zeros((e_loc, cap + 1, d), dtype=jnp.float32)
    (dg, _), dws = jax.lax.scan(step, (dg0, 0.0), _chunked((e, p, t, w), c))
    dw = dws.reshape(-1)
    return (dg[:, :cap].astype(y_grouped.dtype), None, None, None, dw)


_combine.defvjp(_combine_fwd, _combine_bwd)


# ------------------------------------------------------ all-to-all exchange

@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _exchange(buckets: jax.Array, axis: str) -> jax.Array:
    """Bucket exchange over ``axis`` (inside shard_map): leading dim m is
    the per-peer split — peer j receives our block j, we receive every
    peer's block i at position i (source-major)."""
    return jax.lax.all_to_all(buckets, axis, split_axis=0, concat_axis=0)


def _exchange_fwd(buckets, axis):
    return _exchange(buckets, axis), None


def _exchange_bwd(axis, _res, g):
    # the transpose of the bucket exchange is the REVERSE exchange (the
    # peer-block permutation is an involution) — dispatch mirrors to
    # combine without ever widening to a psum
    return (jax.lax.all_to_all(g, axis, split_axis=0, concat_axis=0),)


_exchange.defvjp(_exchange_fwd, _exchange_bwd)


def _a2a_experts(x_flat: jax.Array, gates: jax.Array, idx: jax.Array,
                 w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array,
                 capacity: int, m: int, axis: str
                 ) -> Tuple[jax.Array, jax.Array]:
    """Capacity-bucketed all-to-all dispatch (inside shard_map).

    x_flat: (T_loc, D) — this shard's *disjoint* tokens.  Packs the routed
    (token, choice) pairs into per-destination-expert buckets (E, C, D)
    via the same stable-sort tables as :func:`_grouped_experts`, exchanges
    the per-destination §6 ranges with the peers over ``axis``, runs the
    local experts on the received (E_loc, m·C, D), and reverse-exchanges
    the results for the gate-weighted combine back on the source shard.
    Returns ``(y (T_loc, D), kept (T_loc,))``.
    """
    t, d = x_flat.shape
    k = idx.shape[1]
    e_loc = w_gate.shape[0]
    e = e_loc * m                                             # global experts
    n = t * k

    flat_e = idx.reshape(n).astype(jnp.int32)
    flat_g = gates.reshape(n)
    tok_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    pos = _expert_positions(flat_e, n)
    valid = (pos < capacity) & (flat_g > 0)
    safe_pos = jnp.where(valid, pos, capacity).astype(jnp.int32)  # row C = trash
    w = (flat_g * valid).astype(jnp.float32)

    # pack: bucket (g, c) holds this shard's c-th surviving token for
    # global expert g; overflow lands in the trash row and is dropped
    buckets = _dispatch(x_flat, flat_e, safe_pos, tok_ids, w,
                        e, capacity, str(x_flat.dtype), t)

    # exchange: reshaped (m, E_loc, C, D), peer j's slice is exactly the
    # contiguous §6 range covering its experts [j·E_loc, (j+1)·E_loc)
    recv = _exchange(buckets.reshape(m, e_loc, capacity, d), axis)
    x_grouped = jnp.moveaxis(recv, 0, 1).reshape(e_loc, m * capacity, d)

    g = jnp.einsum("ecd,edf->ecf", x_grouped, w_gate)
    u = jnp.einsum("ecd,edf->ecf", x_grouped, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x_flat.dtype) * u
    y_grouped = jnp.einsum("ecf,efd->ecd", h, w_down)     # (E_loc, m·C, D)

    # reverse exchange: source shard gets back its own bucket layout
    back = _exchange(jnp.moveaxis(
        y_grouped.reshape(e_loc, m, capacity, d), 1, 0), axis)
    y = _combine(back.reshape(e, capacity, d), flat_e, safe_pos, tok_ids,
                 w, t)
    kept = jnp.sum(valid.reshape(t, k), axis=1).astype(jnp.float32)
    return y.astype(x_flat.dtype), kept


def _capacity(cfg, tokens: int) -> int:
    """Per-expert bucket capacity over ``tokens`` routing together: the
    psum path rounds up to 8 (lane-friendly grouped GEMM over (E, C));
    the a2a path calls :func:`_a2a_capacity` instead — its GEMM batches
    m·C rows, so tiny per-source buckets stay tight."""
    c = int(np.ceil(cfg.experts_per_token * tokens * cfg.capacity_factor
                    / cfg.num_experts))
    return max(8, int(np.ceil(c / 8) * 8))


def _a2a_capacity(cfg, tokens: int) -> int:
    c = int(np.ceil(cfg.experts_per_token * tokens * cfg.capacity_factor
                    / cfg.num_experts))
    return max(1, c)


def moe_ffn(params: Params, x: jax.Array, cfg
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """MoE feed-forward.  x: (B, S, D) → (y, aux dict).

    The aux dict carries the balance loss plus dispatch stats
    (``dropped`` / ``routed`` (token, choice) counts and the per-device
    ``a2a_bytes`` the exchange moves per layer); layers sum it through the
    backbone scan and the trainer surfaces it as Stats gauges.

    Routing (cheap, (T,E)) runs in global view; expert compute runs under
    ``shard_map`` when a mesh with a "model" axis is ambient, expert banks
    sharded E→"model" (EP) and D→"data" (FSDP, re-gathered per layer).
    ``cfg.moe_dispatch`` picks the EP combine:

    * ``"a2a"`` (default): tokens shard S→"model"; each shard packs
      per-destination-expert capacity buckets and two ``all_to_all``s
      exchange exactly the §6-disjoint routed ranges (see module docs).
    * ``"psum"``: tokens replicate over "model"; every shard computes its
      local experts against all tokens and a full-width psum combines —
      the O(E)-wasteful baseline, kept for fallback (S not divisible by
      the model axis) and for ``bench_moe``'s comparison.
    """
    from repro.dist.sharding import current_ctx, moe_bucket_ranges, shard_map
    from jax.sharding import PartitionSpec as P

    ctx = current_ctx()
    b, s, d = x.shape
    t = b * s

    m = ctx.model_size
    use_shmap = (ctx.active and m > 1 and cfg.num_experts % m == 0
                 and not ctx.pure_dp)
    dispatch = getattr(cfg, "moe_dispatch", "a2a")
    use_a2a = (use_shmap and dispatch == "a2a"
               and ctx.resolve("sp", s) is not None)

    # a2a keeps tokens sharded over "model" (matching the blocks' sp
    # constraint — no gather at shard_map entry); psum replicates them
    x = ctx.constrain(x, "dp", "sp" if use_a2a else None, None)
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), params["router"])
    logits = ctx.constrain(logits, "dp", "sp" if use_a2a else None, None)
    gates, idx = _route(logits.reshape(t, cfg.num_experts),
                        cfg.experts_per_token)
    aux = load_balance_loss(logits.reshape(t, cfg.num_experts), idx,
                            cfg.num_experts)
    gates_b = gates.reshape(b, s, -1)
    idx_b = idx.reshape(b, s, -1)
    routed = jnp.asarray(float(t * cfg.experts_per_token), jnp.float32)
    a2a_bytes = jnp.zeros((), jnp.float32)

    dp_b = ctx.resolve("dp", b) if use_shmap else None
    # FSDP axes the expert banks are sharded over (may span pod+data)
    fs = ctx.resolve("fsdp", d) if use_shmap else None

    def _gather_banks(wg, wu, wd):
        if fs is not None:
            wg = jax.lax.all_gather(wg, fs, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, fs, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, fs, axis=2, tiled=True)
        return wg, wu, wd

    if not use_shmap:
        y, kept = _grouped_experts(
            x.reshape(t, d), gates, idx,
            params["w_gate"], params["w_up"], params["w_down"],
            _capacity(cfg, t), 0)
        y = y.reshape(b, s, d)
        kept_b = kept.reshape(b, s)
    elif use_a2a:
        dp_names = dp_b if isinstance(dp_b, tuple) else \
            ((dp_b,) if dp_b else ())
        dp_size = 1
        for a_ in dp_names:
            dp_size *= ctx.axis_sizes[a_]
        cap = _a2a_capacity(cfg, t // (dp_size * m))   # == inner tl
        # per-device bucket bytes per layer: two exchanges over the §6
        # destination ranges of one shard's (E, C, D) bucket block
        ranges = moe_bucket_ranges(cfg.num_experts, cap, d,
                                   x.dtype.itemsize, ctx)
        a2a_bytes = jnp.asarray(2.0 * sum(sz for _, sz in ranges),
                                jnp.float32)

        def inner_a2a(xx, gg, ii, wg, wu, wd):
            wg, wu, wd = _gather_banks(wg, wu, wd)
            bl, sl, _ = xx.shape
            tl = bl * sl
            y, kept = _a2a_experts(xx.reshape(tl, d), gg.reshape(tl, -1),
                                   ii.reshape(tl, -1), wg, wu, wd,
                                   _a2a_capacity(cfg, tl), m, "model")
            return y.reshape(bl, sl, d), kept.reshape(bl, sl)

        xspec = P(dp_b, "model", None)
        fn = shard_map(
            inner_a2a, ctx.mesh,
            in_specs=(xspec, xspec, xspec,
                      P("model", fs, None), P("model", fs, None),
                      P("model", None, fs)),
            out_specs=(xspec, P(dp_b, "model")), check=False)
        y, kept_b = fn(x, gates_b, idx_b.astype(jnp.int32),
                       params["w_gate"], params["w_up"], params["w_down"])
    else:
        e_loc = cfg.num_experts // m

        def inner(xx, gg, ii, wg, wu, wd):
            wg, wu, wd = _gather_banks(wg, wu, wd)
            bl, sl, _ = xx.shape
            tl = bl * sl
            e_off = jax.lax.axis_index("model") * e_loc
            y, kept = _grouped_experts(xx.reshape(tl, d), gg.reshape(tl, -1),
                                       ii.reshape(tl, -1), wg, wu, wd,
                                       _capacity(cfg, tl), e_off)
            y = jax.lax.psum(y, "model")
            # each choice is kept by exactly one owning shard (or dropped)
            kept = jax.lax.psum(kept, "model")
            return y.reshape(bl, sl, d), kept.reshape(bl, sl)

        xspec = P(dp_b, None, None)
        fn = shard_map(
            inner, ctx.mesh,
            in_specs=(xspec, xspec, xspec,
                      P("model", fs, None), P("model", fs, None),
                      P("model", None, fs)),
            out_specs=(xspec, P(dp_b, None)), check=False)
        y, kept_b = fn(x, gates_b, idx_b.astype(jnp.int32),
                       params["w_gate"], params["w_up"], params["w_down"])

    dropped = routed - jnp.sum(kept_b)
    auxd = {"loss": aux, "dropped": dropped, "routed": routed,
            "a2a_bytes": a2a_bytes}

    if "shared" in params:
        y = y + mlp(params["shared"], x)
    if "dense_residual" in params:
        y = y + mlp(params["dense_residual"], x)
    return y, auxd
