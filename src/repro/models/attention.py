"""Attention variants: GQA (full / blockwise-flash / decode), SWA, MLA, cross.

Layout conventions:
  activations  x : (batch, seq, d_model)
  q            : (batch, seq, n_heads, head_dim)
  k, v         : (batch, seq, n_kv_heads, head_dim)
  kv cache     : dict(k=(B, S_max, K, hd), v=(B, S_max, K, hd))
  MLA cache    : dict(c_kv=(B, S_max, r), k_rope=(B, S_max, rd))

Long-sequence training/prefill routes through ``flash_attention_train`` —
the differentiable Pallas flash kernel (`repro.kernels.flash_attention`,
custom-VJP backward kernels; compiled on TPU, interpret mode on CPU so the
dry-run still lowers).  ``blockwise_attention`` / ``flash_attention_jnp``
remain as the sub-quadratic jnp oracles the kernel gradchecks against.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Params, apply_rope, dense_init, rmsnorm, rmsnorm_init

NEG_INF = -1e30


# ------------------------------------------------------------------ GQA params

def gqa_init(key, cfg, d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    h, k_, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    keys = jax.random.split(key, 4)
    from .layers import _dtype
    dt = _dtype(cfg.param_dtype)
    p = {
        "w_q": dense_init(keys[0], d, (h, hd), dt),
        "w_k": dense_init(keys[1], d, (k_, hd), dt),
        "w_v": dense_init(keys[2], d, (k_, hd), dt),
        "w_o": dense_init(keys[3], h * hd, (d,), dt).reshape(h, hd, d),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h, hd), dtype=dt)
        p["b_k"] = jnp.zeros((k_, hd), dtype=dt)
        p["b_v"] = jnp.zeros((k_, hd), dtype=dt)
    return p


# ------------------------------------------------------- dense full attention

def _causal_window_mask(sq: int, sk: int, offset: int, window: int) -> jax.Array:
    """(sq, sk) boolean mask. offset = absolute position of q row 0 minus
    absolute position of k col 0.  window==0 → plain causal."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(sk)[None, :]
    m = kj <= qi
    if window > 0:
        m &= (qi - kj) < window
    return m


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool = True, window: int = 0,
                   q_offset: int = 0) -> jax.Array:
    """Dense reference attention with GQA head grouping.

    q: (B,Sq,H,hd); k,v: (B,Sk,K,hd) with H = K*G.
    """
    b, sq, h, hd = q.shape
    _, sk, kh, _ = k.shape
    hd_v = v.shape[-1]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores *= 1.0 / np.sqrt(hd)
    if causal:
        mask = _causal_window_mask(sq, sk, q_offset, window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v)
    return out.reshape(b, sq, h, hd_v)


# -------------------------------------------------- blockwise flash attention

def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: int = 0,
                        block_q: int = 512, block_k: int = 1024,
                        q_offset: int = 0) -> jax.Array:
    """Flash-style attention with O(S) memory: scan over KV blocks with an
    online-softmax carry, vmapped over Q blocks.  jnp oracle of the Pallas
    kernel; exact (up to fp assoc.) w.r.t. :func:`full_attention`."""
    b, sq, h, hd = q.shape
    _, sk, kh, _ = k.shape
    hd_v = v.shape[-1]
    g = h // kh
    assert sq % block_q == 0 and sk % block_k == 0, (sq, sk, block_q, block_k)
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / np.sqrt(hd)

    qb = q.reshape(b, nq, block_q, kh, g, hd)
    kb = k.reshape(b, nk, block_k, kh, hd)
    vb = v.reshape(b, nk, block_k, kh, hd_v)

    def process_q_block(qi: jax.Array, q_block: jax.Array) -> jax.Array:
        # q_block: (b, block_q, kh, g, hd)
        def kv_step(carry, inputs):
            m, l, acc = carry
            kj, k_block, v_block = inputs
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_block, k_block)
            s = (s * scale).astype(jnp.float32)
            if causal or window > 0:
                qpos = qi * block_q + jnp.arange(block_q) + q_offset
                kpos = kj * block_k + jnp.arange(block_k)
                msk = kpos[None, :] <= qpos[:, None]
                if window > 0:
                    msk &= (qpos[:, None] - kpos[None, :]) < window
                s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v_block.dtype), v_block)
            acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kh, g, block_q), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((b, kh, g, block_q), dtype=jnp.float32)
        a0 = jnp.zeros((b, kh, g, block_q, hd_v), dtype=jnp.float32)
        ks = jnp.arange(nk)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks, jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)))
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        # (b, kh, g, block_q, hd) -> (b, block_q, kh, g, hd)
        return jnp.transpose(out, (0, 3, 1, 2, 4))

    out_blocks = jax.lax.map(
        lambda args: process_q_block(*args),
        (jnp.arange(nq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(out_blocks, 0, 1).reshape(b, sq, kh, g, hd_v)
    return out.reshape(b, sq, h, hd_v).astype(q.dtype)


# --------------------------------------- flash attention with O(S) backward

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def flash_attention_jnp(q, k, v, q_offset, causal=True, window=0,
                        block_q=512, block_k=1024):
    """Blockwise attention whose *backward* also runs tile-by-tile from the
    saved LSE (O(S) memory) — differentiating the plain scan would stack
    per-tile probabilities, i.e. O(S²).  jnp twin of the Pallas kernel's
    custom gradient; used on all training paths.

    ``q_offset`` is an f32 scalar *array* (it may be a traced
    ``axis_index`` product under shard_map); its cotangent is zero.
    """
    out, _ = _flash_fwd_impl(q, k, v, causal, window, block_q, block_k,
                             q_offset)
    return out


def _flash_fwd_impl(q, k, v, causal, window, block_q, block_k, q_offset):
    q_offset = jnp.asarray(q_offset).astype(jnp.int32)
    b, sq, h, hd = q.shape
    _, sk, kh, _ = k.shape
    hd_v = v.shape[-1]
    g = h // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / np.sqrt(hd)
    qb = jnp.moveaxis(q.reshape(b, nq, block_q, kh, g, hd), 1, 0)
    kb = jnp.moveaxis(k.reshape(b, nk, block_k, kh, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nk, block_k, kh, hd_v), 1, 0)

    def q_block(args):
        qi, q_blk = args

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, k_blk, v_blk = inp
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_blk)
            s = (s * scale).astype(jnp.float32)
            if causal or window > 0:
                qpos = qi * block_q + jnp.arange(block_q) + q_offset
                kpos = kj * block_k + jnp.arange(block_k)
                msk = kpos[None, :] <= qpos[:, None]
                if window > 0:
                    msk &= (qpos[:, None] - kpos[None, :]) < window
                s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bkgqs,bskh->bkgqh", p.astype(v_blk.dtype), v_blk)
            return (m_new, l_new, acc * alpha[..., None] + pv.astype(jnp.float32)), None

        m0 = jnp.full((b, kh, g, block_q), NEG_INF, dtype=jnp.float32)
        l0 = jnp.zeros((b, kh, g, block_q), dtype=jnp.float32)
        a0 = jnp.zeros((b, kh, g, block_q, hd_v), dtype=jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nk), kb, vb))
        l = jnp.maximum(l, 1e-37)
        o = (acc / l[..., None])
        lse = m + jnp.log(l)
        return jnp.transpose(o, (0, 3, 1, 2, 4)), lse   # (b,bq,kh,g,hd)

    outs, lses = jax.lax.map(q_block, (jnp.arange(nq), qb))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd_v).astype(q.dtype)
    lse = jnp.moveaxis(lses, 0, 3)      # (b,kh,g,nq,block_q) -> wait below
    # lses: (nq, b, kh, g, block_q) -> (b, kh, g, sq)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, kh, g, sq)
    return out, lse


def _flash_fwd(q, k, v, q_offset, causal, window, block_q, block_k):
    out, lse = _flash_fwd_impl(q, k, v, causal, window, block_q, block_k,
                               q_offset)
    return out, (q, k, v, out, lse, q_offset)


def _flash_bwd(causal, window, block_q, block_k, res, do):
    q, k, v, out, lse, q_offset = res
    q_offset = jnp.asarray(q_offset).astype(jnp.int32)
    b, sq, h, hd = q.shape
    _, sk, kh, _ = k.shape
    hd_v = v.shape[-1]
    g = h // kh
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    nq, nk = sq // bq, sk // bk
    scale = 1.0 / np.sqrt(hd)

    qg = q.reshape(b, nq, bq, kh, g, hd)
    dog = do.reshape(b, nq, bq, kh, g, hd_v)
    lseg = lse.reshape(b, kh, g, nq, bq)
    # delta_i = rowsum(do * out): computed elementwise on the UNBLOCKED
    # arrays — expressing it as a dot over the blocked layout makes GSPMD
    # fully rematerialize head-sharded operands (observed 4.3 GB/device
    # replicated copies on deepseek-v2)
    delta_flat = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                         axis=-1)                      # (b, sq, h)
    delta = jnp.transpose(delta_flat.reshape(b, sq, kh, g), (0, 2, 3, 1))
    delta = delta.reshape(b, kh, g, nq, bq)

    def q_step(carry, inp):
        dk_acc, dv_acc = carry
        qi, q_blk, do_blk, lse_blk, delta_blk = inp
        # q_blk (b,bq,kh,g,hd); lse/delta (b,kh,g,bq)

        def kv_step(carry2, inp2):
            dq_blk = carry2
            kj, k_blk, v_blk, dk_blk, dv_blk = inp2
            s = jnp.einsum("bqkgh,bskh->bkgqs", q_blk, k_blk)
            s = (s * scale).astype(jnp.float32)
            if causal or window > 0:
                qpos = qi * bq + jnp.arange(bq) + q_offset
                kpos = kj * bk + jnp.arange(bk)
                msk = kpos[None, :] <= qpos[:, None]
                if window > 0:
                    msk &= (qpos[:, None] - kpos[None, :]) < window
                s = jnp.where(msk[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lse_blk[..., None])               # (b,kh,g,bq,bk)
            dp = jnp.einsum("bqkgh,bskh->bkgqs", do_blk,
                            v_blk).astype(jnp.float32)
            ds = p * (dp - delta_blk[..., None]) * scale
            dq_blk = dq_blk + jnp.einsum("bkgqs,bskh->bqkgh",
                                         ds.astype(k_blk.dtype), k_blk)
            dk_blk = dk_blk + jnp.einsum("bkgqs,bqkgh->bskh",
                                         ds.astype(q_blk.dtype), q_blk)
            dv_blk = dv_blk + jnp.einsum("bkgqs,bqkgh->bskh",
                                         p.astype(do_blk.dtype), do_blk)
            return dq_blk, (dk_blk, dv_blk)

        dq0 = jnp.zeros_like(q_blk)
        dq_blk, (dk_new, dv_new) = jax.lax.scan(
            kv_step, dq0,
            (jnp.arange(nk), jnp.moveaxis(k.reshape(b, nk, bk, kh, hd), 1, 0),
             jnp.moveaxis(v.reshape(b, nk, bk, kh, hd_v), 1, 0),
             jnp.moveaxis(dk_acc, 1, 0), jnp.moveaxis(dv_acc, 1, 0)))
        return (jnp.moveaxis(dk_new, 0, 1), jnp.moveaxis(dv_new, 0, 1)), dq_blk

    dk0 = jnp.zeros((b, nk, bk, kh, hd), q.dtype)
    dv0 = jnp.zeros((b, nk, bk, kh, hd_v), q.dtype)
    (dk, dv), dqs = jax.lax.scan(
        q_step, (dk0, dv0),
        (jnp.arange(nq), jnp.moveaxis(qg, 1, 0), jnp.moveaxis(dog, 1, 0),
         jnp.moveaxis(lseg, 3, 0), jnp.moveaxis(delta, 3, 0)))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, sq, h, hd)
    dk = dk.reshape(b, sk, kh, hd)
    dv = dv.reshape(b, sk, kh, hd_v)
    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            jnp.zeros((), jnp.float32))


flash_attention_jnp.defvjp(_flash_fwd, _flash_bwd)


# --------------------------------------------- Pallas training dispatcher

def flash_min_seq(cfg) -> int:
    """Sequence length above which training/prefill attention goes flash.

    With no config override the floor comes from the autotuner's minimum
    block (two q tiles must fit the sequence) instead of a fixed tile
    constant — so the fwd threshold and the bwd kernels' planning agree.
    """
    from repro.kernels import autotune
    bq = getattr(cfg, "attn_block_q", None) or autotune.min_block()
    return max(2 * bq, getattr(cfg, "attn_flash_min_seq", 2048) or 2048)


def flash_attention_train(q, k, v, q_offset=0.0, *, causal=True, window=0,
                          block_q=None, block_k=None):
    """Differentiable flash attention for training/prefill paths.

    Runs the Pallas kernel with its custom-VJP backward kernels
    (``repro.kernels.flash_attention``) — compiled on a TPU backend,
    interpret mode elsewhere, so the same grid/mask arithmetic executes
    on every backend (CPU parity is the TPU kernel's oracle).  Blocks
    default to the trace-time autotuner; pass ints to pin them.
    """
    from repro.kernels import ops as kernel_ops
    return kernel_ops.flash_attention(q, k, v, q_offset, causal=causal,
                                      window=window, block_q=block_q,
                                      block_k=block_k)


# ------------------------------------------------------------ decode attention

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array, *,
                     cur_len: jax.Array, window: int = 0) -> jax.Array:
    """One-token attention against a (B, S_max, K, hd) cache.

    cur_len: scalar or (B,) number of valid cache entries (new token included).
    """
    b, sq, h, hd = q.shape
    _, smax, kh, _ = k_cache.shape
    g = h // kh
    qg = q.reshape(b, sq, kh, g, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache).astype(jnp.float32)
    scores *= 1.0 / np.sqrt(hd)
    pos = jnp.arange(smax)
    cur = jnp.asarray(cur_len)
    if cur.ndim == 0:
        valid = pos < cur                           # (smax,), shared
        if window > 0:
            valid &= pos >= jnp.maximum(cur - window, 0)
        mask = valid[None, None, None, None, :]
    else:
        valid = pos[None, :] < cur[:, None]         # (B, smax), per row
        if window > 0:
            valid &= pos[None, :] >= jnp.maximum(cur - window, 0)[:, None]
        mask = valid[:, None, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs, v_cache)
    return out.reshape(b, sq, h, hd)


# ------------------------------------------------------------------ GQA block

def gqa_qkv(params: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["w_v"])
    if "b_q" in params:
        q = q + params["b_q"]
        k = k + params["b_k"]
        v = v + params["b_v"]
    return q, k, v


def gqa_train(params: Params, x: jax.Array, cfg, positions: jax.Array,
              use_rope: bool = True) -> jax.Array:
    """Full-sequence causal attention (training / prefill compute)."""
    q, k, v = gqa_qkv(params, x, cfg)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    seq = x.shape[1]
    if seq > flash_min_seq(cfg):
        out = flash_attention_train(q, k, v, window=cfg.sliding_window,
                                    block_q=cfg.attn_block_q,
                                    block_k=cfg.attn_block_k)
    else:
        out = full_attention(q, k, v, causal=True, window=cfg.sliding_window)
    return jnp.einsum("bshk,hkd->bsd", out, params["w_o"])


def gqa_prefill(params: Params, x: jax.Array, cfg, positions: jax.Array,
                use_rope: bool = True) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill: same compute as train, also returns the KV cache."""
    q, k, v = gqa_qkv(params, x, cfg)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    seq = x.shape[1]
    if seq > flash_min_seq(cfg):
        out = flash_attention_train(q, k, v, window=cfg.sliding_window,
                                    block_q=cfg.attn_block_q,
                                    block_k=cfg.attn_block_k)
    else:
        out = full_attention(q, k, v, causal=True, window=cfg.sliding_window)
    o = jnp.einsum("bshk,hkd->bsd", out, params["w_o"])
    return o, {"k": k, "v": v}


def gqa_decode(params: Params, x: jax.Array, cfg, cache: Dict[str, jax.Array],
               cur_len: jax.Array, use_rope: bool = True,
               ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode: append to cache at cur_len-? and attend.

    x: (B, 1, D); cache arrays (B, S_max, K, hd); cur_len: scalar int32 —
    number of tokens already in the cache (the new token goes at cur_len).
    """
    q, k, v = gqa_qkv(params, x, cfg)
    pos = jnp.asarray(cur_len)[None]          # (1,) absolute position
    if use_rope:
        q = apply_rope(q, pos[None, :], cfg.rope_theta)
        k = apply_rope(k, pos[None, :], cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                           (0, cur_len, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                           (0, cur_len, 0, 0))
    out = decode_attention(q, k_cache, v_cache, cur_len=cur_len + 1,
                           window=cfg.sliding_window)
    o = jnp.einsum("bshk,hkd->bsd", out, params["w_o"])
    return o, {"k": k_cache, "v": v_cache}


# -------------------------------------------------------------- cross attention

def cross_attn_init(key, cfg) -> Params:
    # encoder-decoder (whisper): kv over encoder states, MHA (kv heads = heads)
    d = cfg.d_model
    h, hd = cfg.num_heads, cfg.head_dim
    keys = jax.random.split(key, 4)
    from .layers import _dtype
    dt = _dtype(cfg.param_dtype)
    return {
        "w_q": dense_init(keys[0], d, (h, hd), dt),
        "w_k": dense_init(keys[1], d, (h, hd), dt),
        "w_v": dense_init(keys[2], d, (h, hd), dt),
        "w_o": dense_init(keys[3], h * hd, (d,), dt).reshape(h, hd, d),
    }


def cross_attention(params: Params, x: jax.Array, enc: jax.Array) -> jax.Array:
    q = jnp.einsum("bsd,dhk->bshk", x, params["w_q"])
    k = jnp.einsum("bsd,dhk->bshk", enc, params["w_k"])
    v = jnp.einsum("bsd,dhk->bshk", enc, params["w_v"])
    out = full_attention(q, k, v, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, params["w_o"])


# ----------------------------------------------------------------------- MLA

def mla_init(key, cfg) -> Params:
    """DeepSeek-V2 multi-head latent attention."""
    d = cfg.d_model
    h = cfg.num_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    keys = jax.random.split(key, 7)
    from .layers import _dtype
    dt = _dtype(cfg.param_dtype)
    return {
        "w_dq": dense_init(keys[0], d, (rq,), dt),
        "q_norm": rmsnorm_init(rq, dt),
        "w_uq": dense_init(keys[1], rq, (h, dn + dr), dt),
        "w_dkv": dense_init(keys[2], d, (rkv + dr,), dt),
        "kv_norm": rmsnorm_init(rkv, dt),
        "w_uk": dense_init(keys[3], rkv, (h, dn), dt),
        "w_uv": dense_init(keys[4], rkv, (h, dv), dt),
        "w_o": dense_init(keys[5], h * dv, (d,), dt).reshape(h, dv, d),
    }


def _mla_latents(params: Params, x: jax.Array, cfg, positions: jax.Array):
    """Shared low-rank projections: q_nope/q_rope per head, compressed
    kv latent c_kv (b,s,rkv) and its rope key k_rope (b,s,1,dr)."""
    dn = cfg.qk_nope_head_dim
    rkv = cfg.kv_lora_rank
    cq = rmsnorm(params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["w_dq"]),
                 cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    dkv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_kv = rmsnorm(params["kv_norm"], dkv[..., :rkv], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, rkv:], positions, cfg.rope_theta)
    return q_nope, q_rope, c_kv, k_rope


def _mla_qkv_full(params: Params, x: jax.Array, cfg, positions: jax.Array):
    dr = cfg.qk_rope_head_dim
    q_nope, q_rope, c_kv, k_rope = _mla_latents(params, x, cfg, positions)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uk"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, params["w_uv"])

    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:-1], dr))], axis=-1)
    return q_full, k_full, v, c_kv, k_rope


def _mla_absorbed_flash(params: Params, x: jax.Array, cfg,
                        positions: jax.Array, q_offset=0.0):
    """Absorbed-matrix MLA attention through the Pallas flash VJP.

    Same absorption as ``mla_decode``, but differentiable and full
    sequence: scores live in the compressed latent space, so the kernel
    sees ONE kv head (MQA) of width rkv + dr — k = [c_kv, k_rope],
    v = c_kv — and g = num_heads queries sharing it.  The up-projection
    W_UV is applied to the kernel's latent output (attention is linear
    in v, so p·(c_kv W_UV) == (p·c_kv) W_UV exactly).  The kernel
    scales scores by 1/sqrt(rkv + dr); MLA semantics want
    1/sqrt(dn + dr), so q is pre-scaled by the ratio.  Returns
    (out_heads (b,s,h,dv), c_kv, k_rope) so prefill can reuse the
    latents as its cache.
    """
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    rkv = cfg.kv_lora_rank
    q_nope, q_rope, c_kv, k_rope = _mla_latents(params, x, cfg, positions)
    # absorb W_UK into the query path: q_latent (b,s,h,rkv)
    q_latent = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"])
    q_eff = jnp.concatenate([q_latent, q_rope], axis=-1)
    q_eff = q_eff * np.sqrt((rkv + dr) / (dn + dr)).astype(q_eff.dtype)
    k_eff = jnp.concatenate([c_kv[:, :, None, :], k_rope], axis=-1)
    v_eff = c_kv[:, :, None, :]
    out_latent = flash_attention_train(q_eff, k_eff, v_eff, q_offset,
                                       causal=True,
                                       block_q=cfg.attn_block_q,
                                       block_k=cfg.attn_block_k)
    out = jnp.einsum("bshr,rhk->bshk", out_latent, params["w_uv"])
    return out, c_kv, k_rope


def mla_train(params: Params, x: jax.Array, cfg, positions: jax.Array) -> jax.Array:
    seq = x.shape[1]
    if seq > flash_min_seq(cfg):
        out, _, _ = _mla_absorbed_flash(params, x, cfg, positions)
    else:
        q, k, v, _, _ = _mla_qkv_full(params, x, cfg, positions)
        out = full_attention(q, k, v, causal=True)
    return jnp.einsum("bshk,hkd->bsd", out, params["w_o"])


def mla_prefill(params: Params, x: jax.Array, cfg, positions: jax.Array
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    seq = x.shape[1]
    if seq > flash_min_seq(cfg):
        out, c_kv, k_rope = _mla_absorbed_flash(params, x, cfg, positions)
    else:
        q, k, v, c_kv, k_rope = _mla_qkv_full(params, x, cfg, positions)
        out = full_attention(q, k, v, causal=True)
    o = jnp.einsum("bshk,hkd->bsd", out, params["w_o"])
    return o, {"c_kv": c_kv, "k_rope": k_rope[:, :, 0, :]}


def mla_decode(params: Params, x: jax.Array, cfg, cache: Dict[str, jax.Array],
               cur_len: jax.Array) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Absorbed-matrix MLA decode: attend in the compressed latent space.

    cache: c_kv (B, S_max, rkv), k_rope (B, S_max, dr).  The up-projections
    W_UK / W_UV are absorbed into the query / output paths, so the per-step
    cost is O(S·rkv) instead of O(S·H·hd) — deepseek-v2's key serving win.
    """
    dn, dr = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    rkv = cfg.kv_lora_rank
    cq = rmsnorm(params["q_norm"], jnp.einsum("bsd,dr->bsr", x, params["w_dq"]),
                 cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, params["w_uq"])
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    pos = jnp.asarray(cur_len)[None]
    q_rope = apply_rope(q_rope, pos[None, :], cfg.rope_theta)

    dkv = jnp.einsum("bsd,dr->bsr", x, params["w_dkv"])
    c_new = rmsnorm(params["kv_norm"], dkv[..., :rkv], cfg.norm_eps)
    kr_new = apply_rope(dkv[..., None, rkv:], pos[None, :], cfg.rope_theta)[:, :, 0]

    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new.astype(cache["c_kv"].dtype),
                                        (0, cur_len, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"],
                                          kr_new.astype(cache["k_rope"].dtype),
                                          (0, cur_len, 0))
    # absorb W_UK: q_latent (b,1,h,rkv)
    q_latent = jnp.einsum("bshk,rhk->bshr", q_nope, params["w_uk"])
    scores = (jnp.einsum("bshr,btr->bhst", q_latent, c_kv)
              + jnp.einsum("bshk,btk->bhst", q_rope, k_rope)).astype(jnp.float32)
    scores *= 1.0 / np.sqrt(dn + dr)
    smax = c_kv.shape[1]
    valid = jnp.arange(smax) < (cur_len + 1)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out_latent = jnp.einsum("bhst,btr->bshr", probs, c_kv)
    out = jnp.einsum("bshr,rhk->bshk", out_latent, params["w_uv"])
    o = jnp.einsum("bshk,hkd->bsd", out, params["w_o"])
    return o, {"c_kv": c_kv, "k_rope": k_rope}
