"""Mamba2 (state-space duality) block: chunked training scan + O(1) decode.

Follows arXiv:2405.21060 (SSD): the sequence is split into chunks of
``ssm_chunk``; intra-chunk contributions are dense matmuls (MXU-friendly),
inter-chunk state is carried by a short ``lax.scan`` over chunks.  The
Pallas kernel (`repro.kernels.ssd_scan`) implements the same algorithm with
explicit VMEM tiling; this module is its jnp oracle and the dry-run path.

Decode is the recurrent form: state (B, H, P, N) updated per token — cache
size independent of sequence length (why SSM archs run ``long_500k``).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .layers import Params, _dtype, dense_init, rmsnorm, rmsnorm_init


def mamba_init(key, cfg) -> Params:
    """Projections are stored *separately* per component (z, x, B, C, dt)
    rather than as one fused in_proj: the x/z parts are head-aligned and
    shard over the "model" axis, while B/C/dt are head-shared and stay
    replicated — a fused layout would interleave both (see DESIGN.md §6).
    """
    d = cfg.d_model
    di = cfg.d_inner
    n = cfg.ssm_state
    h = cfg.ssm_heads
    ck = cfg.conv_kernel
    dt = _dtype(cfg.param_dtype)
    keys = jax.random.split(key, 7)
    return {
        "w_z": dense_init(keys[0], d, (di,), dt),
        "w_x": dense_init(keys[1], d, (di,), dt),
        "w_B": dense_init(keys[2], d, (n,), dt),
        "w_C": dense_init(keys[3], d, (n,), dt),
        "w_dt": dense_init(keys[4], d, (h,), dt),
        "conv_x": (jax.random.normal(keys[5], (ck, di), jnp.float32)
                   / np.sqrt(ck)).astype(dt),
        "conv_b_x": jnp.zeros((di,), dtype=dt),
        "conv_B": (jax.random.normal(keys[6], (ck, n), jnp.float32)
                   / np.sqrt(ck)).astype(dt),
        "conv_b_B": jnp.zeros((n,), dtype=dt),
        "conv_C": (jax.random.normal(jax.random.fold_in(key, 7), (ck, n),
                                     jnp.float32) / np.sqrt(ck)).astype(dt),
        "conv_b_C": jnp.zeros((n,), dtype=dt),
        "A_log": jnp.zeros((h,), dtype=jnp.float32),
        "D": jnp.ones((h,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((h,), dtype=jnp.float32),
        "norm": rmsnorm_init(di, dt),
        "out_proj": dense_init(jax.random.fold_in(key, 8), di, (d,), dt),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d.  xbc: (B, S, C); w: (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    s = xbc.shape[1]
    for i in range(k):
        out = out + pad[:, i: i + s].astype(jnp.float32) * w[i].astype(jnp.float32)
    out = out + b.astype(jnp.float32)
    return jax.nn.silu(out).astype(xbc.dtype)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array,
                B: jax.Array, C: jax.Array, chunk: int,
                initial_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  (b, s, h, p)   — per-head inputs
    dt: (b, s, h)      — positive step sizes (already softplus'd)
    A:  (h,)           — negative decay rates
    B:  (b, s, n)      — input projections (single group, shared over heads)
    C:  (b, s, n)      — output projections
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk:
        # pad with dt=0 steps: decay exp(0)=1 and no input contribution, so
        # the carried state and real outputs are unaffected
        pad = chunk - s % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        y, st = ssd_chunked(x, dt, A, B, C, chunk, initial_state)
        return y[:, :s], st
    nc = s // chunk

    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    dA = dtc.astype(jnp.float32) * A.astype(jnp.float32)       # (b,nc,q,h) ≤ 0
    cum = jnp.cumsum(dA, axis=2)                               # running log-decay
    total = cum[:, :, -1, :]                                   # (b,nc,h)

    # ---- intra-chunk (diagonal block): attention-like masked matmul
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))                    # (b,nc,q,q)
    # decay from position k to q (q >= k): exp(cum_q - cum_k)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (b,nc,q,k,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), dtype=bool))
    att = CB[..., None] * jnp.where(mask[None, None, :, :, None], decay, 0.0)
    att = att * dtc[:, :, None, :, :]                          # weight by dt_k
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", att, xc.astype(jnp.float32))

    # ---- chunk states: contribution of chunk c to the carried state
    # state_c = sum_k exp(total_c - cum_k) * dt_k * B_k ⊗ x_k   (b,h,p,n)
    w = jnp.exp(total[:, :, None, :] - cum) * dtc               # (b,nc,q,h)
    states = jnp.einsum("bcqh,bcqn,bcqhp->bchpn", w, Bc.astype(jnp.float32),
                        xc.astype(jnp.float32))

    # ---- inter-chunk recurrence
    if initial_state is None:
        init = jnp.zeros((b, h, p, n), dtype=jnp.float32)
    else:
        init = initial_state.astype(jnp.float32)

    decay_chunk = jnp.exp(total)                                # (b,nc,h)

    def carry_fn(state, inp):
        st_c, dec_c = inp                                       # (b,h,p,n), (b,h)
        prev = state
        new = prev * dec_c[:, :, None, None] + st_c
        return new, prev

    (final_state, prevs) = jax.lax.scan(
        carry_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(decay_chunk, 1, 0)))
    prev_states = jnp.moveaxis(prevs, 0, 1)                     # (b,nc,h,p,n)

    # ---- off-diagonal: y_off = C_q · (exp(cum_q) * prev_state)
    outw = jnp.exp(cum)                                         # (b,nc,q,h)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc.astype(jnp.float32),
                       prev_states, outw)

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final_state


def ssd_reference(x, dt, A, B, C, initial_state=None):
    """O(S) sequential-scan oracle for :func:`ssd_chunked` (tests only)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    state = (jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
             else initial_state.astype(jnp.float32))

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp
        dA = jnp.exp(dt_t.astype(jnp.float32) * A)              # (b,h)
        dBx = jnp.einsum("bh,bn,bhp->bhpn", dt_t.astype(jnp.float32),
                         B_t.astype(jnp.float32), x_t.astype(jnp.float32))
        state = state * dA[:, :, None, None] + dBx
        y_t = jnp.einsum("bhpn,bn->bhp", state, C_t.astype(jnp.float32))
        return state, y_t

    state, ys = jax.lax.scan(
        step, state,
        (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0)))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), state


def _mamba_proj(params: Params, x: jax.Array, cfg):
    """Shared projection + conv for train/prefill paths."""
    z = jnp.einsum("bsd,de->bse", x, params["w_z"])
    xr = jnp.einsum("bsd,de->bse", x, params["w_x"])
    Br = jnp.einsum("bsd,dn->bsn", x, params["w_B"])
    Cr = jnp.einsum("bsd,dn->bsn", x, params["w_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["w_dt"])
    xs = _causal_conv(xr, params["conv_x"], params["conv_b_x"])
    B = _causal_conv(Br, params["conv_B"], params["conv_b_B"])
    C = _causal_conv(Cr, params["conv_C"], params["conv_b_C"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    return z, xs, B, C, dt, A, (xr, Br, Cr)


def _mamba_out(params: Params, y_heads: jax.Array, xh: jax.Array, z: jax.Array,
               cfg, lead_shape) -> jax.Array:
    y = y_heads + params["D"].astype(jnp.float32)[:, None] * xh.astype(jnp.float32)
    y = y.reshape(*lead_shape, cfg.d_inner).astype(z.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(z.dtype)
    y = rmsnorm(params["norm"], y, cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["out_proj"])


def mamba_train(params: Params, x: jax.Array, cfg,
                use_kernel: bool = None) -> jax.Array:
    """Full-sequence Mamba2 block (training / prefill compute).

    ``use_kernel`` defaults to the backend: Pallas SSD kernel on TPU, the
    jnp chunked scan elsewhere (REPRO_NO_KERNELS=1 opts out)."""
    if use_kernel is None:
        import os
        use_kernel = (jax.default_backend() == "tpu"
                      and os.environ.get("REPRO_NO_KERNELS") != "1"
                      and x.shape[1] % cfg.ssm_chunk == 0)
    h, pdim = cfg.ssm_heads, cfg.ssm_head_dim
    z, xs, B, C, dt, A, _ = _mamba_proj(params, x, cfg)
    xh = xs.reshape(*xs.shape[:-1], h, pdim)
    if use_kernel:
        from repro.kernels import ops as kops
        y, _ = kops.ssd_scan(xh, dt, A, B, C, chunk=cfg.ssm_chunk)
    else:
        y, _ = ssd_chunked(xh, dt, A, B, C, cfg.ssm_chunk)
    return _mamba_out(params, y, xh, z, cfg, xs.shape[:-1])


def mamba_prefill(params: Params, x: jax.Array, cfg
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Prefill returning the recurrent cache (conv tails + SSD state)."""
    h, pdim, ck = cfg.ssm_heads, cfg.ssm_head_dim, cfg.conv_kernel
    z, xs, B, C, dt, A, (xr, Br, Cr) = _mamba_proj(params, x, cfg)
    xh = xs.reshape(*xs.shape[:-1], h, pdim)
    y, state = ssd_chunked(xh, dt, A, B, C, cfg.ssm_chunk)
    out = _mamba_out(params, y, xh, z, cfg, xs.shape[:-1])
    cache = {
        "conv_x": xr[:, -(ck - 1):, :],     # pre-activation conv tails
        "conv_B": Br[:, -(ck - 1):, :],
        "conv_C": Cr[:, -(ck - 1):, :],
        "state": state.astype(jnp.float32),
    }
    return out, cache


def _conv_step(tail: jax.Array, new: jax.Array, w: jax.Array, b: jax.Array):
    """One-token causal conv: tail (B, K-1, C), new (B, 1, C)."""
    win = jnp.concatenate([tail, new], axis=1)                  # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", win.astype(jnp.float32), w.astype(jnp.float32))
    out = jax.nn.silu(out + b.astype(jnp.float32))
    return out.astype(new.dtype), win[:, 1:, :]


def mamba_decode(params: Params, x: jax.Array, cfg,
                 cache: Dict[str, jax.Array]
                 ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token recurrent step.  x: (B, 1, D); O(1) in sequence length."""
    di, n, h, pdim = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = jnp.einsum("bsd,de->bse", x, params["w_z"])
    xr = jnp.einsum("bsd,de->bse", x, params["w_x"])
    Br = jnp.einsum("bsd,dn->bsn", x, params["w_B"])
    Cr = jnp.einsum("bsd,dn->bsn", x, params["w_C"])
    dt_raw = jnp.einsum("bsd,dh->bsh", x, params["w_dt"])

    xs, conv_x = _conv_step(cache["conv_x"], xr, params["conv_x"], params["conv_b_x"])
    B1, conv_B = _conv_step(cache["conv_B"], Br, params["conv_B"], params["conv_b_B"])
    C1, conv_C = _conv_step(cache["conv_C"], Cr, params["conv_C"], params["conv_b_C"])

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])[:, 0]  # (B,H)
    A = -jnp.exp(params["A_log"])
    xh = xs.reshape(xs.shape[0], h, pdim)                        # (B,H,P)
    dA = jnp.exp(dt * A)                                         # (B,H)
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, B1.astype(jnp.float32),
                     xh.astype(jnp.float32))
    state = cache["state"] * dA[:, :, None, None] + dBx
    y = jnp.einsum("bhpn,bn->bhp", state, C1.astype(jnp.float32))
    y = y[:, None]                                               # (B,1,H,P)
    out = _mamba_out(params, y, xh[:, None], z, cfg, (x.shape[0], 1))
    return out, {"conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C,
                 "state": state}
