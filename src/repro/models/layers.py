"""Shared neural-net layers: norms, RoPE, MLPs, embeddings.

Pure-functional JAX: every layer is ``init(key, cfg) -> params`` plus an
``apply(params, x, ...)`` function.  Parameters are plain dict pytrees so
sharding rules can be expressed by key-path (see ``repro.dist.sharding``).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


Params = Dict[str, Any]


def _dtype(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


# Leaves kept in fp32 regardless of compute dtype (numerics-sensitive).
_FP32_LEAVES = {"A_log", "dt_bias", "D", "router"}


def cast_params(params: Params, dtype_name: str) -> Params:
    """Cast fp32 master weights to the compute dtype at point of use.

    Called *inside* scan bodies so the low-precision copy never
    materializes for the whole stack at once.  The cast output is
    constrained to the master's sharding so the FSDP all-gather moves
    bf16, not fp32 (halves weight-gather traffic — §Perf).
    """
    dt = _dtype(dtype_name)

    from repro.dist.sharding import _resolve_with_priority, current_ctx
    ctx = current_ctx()

    def cast(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if name in _FP32_LEAVES or leaf.dtype != jnp.float32:
            return leaf
        out = leaf.astype(dt)
        if ctx.active:
            keys = tuple(p.key if hasattr(p, "key") else str(p)
                         for p in path)
            spec = _resolve_with_priority(keys, tuple(leaf.shape), ctx)
            out = jax.lax.with_sharding_constraint(
                out, jax.sharding.NamedSharding(ctx.mesh, spec))
        return out

    return jax.tree_util.tree_map_with_path(cast, params)


# ----------------------------------------------------------------- initializers

def dense_init(key, in_dim: int, out_shape: Tuple[int, ...], dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, *out_shape), dtype=jnp.float32)
            * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jax.Array:
    scale = 1.0 / np.sqrt(dim)        # keeps tied-unembedding logits O(1)
    return (jax.random.normal(key, (vocab, dim), dtype=jnp.float32)
            * scale).astype(dtype)


# ----------------------------------------------------------------------- norms

def rmsnorm_init(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(dim: int, dtype) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype),
            "bias": jnp.zeros((dim,), dtype=dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(dt)


# ------------------------------------------------------------------------ RoPE

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs   # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------------- MLP

def mlp_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, (d_ff,), dtype),
        "w_up": dense_init(k2, d_model, (d_ff,), dtype),
        "w_down": dense_init(k3, d_ff, (d_model,), dtype),
    }


def mlp(params: Params, x: jax.Array) -> jax.Array:
    """SwiGLU MLP (llama/qwen/mistral family)."""
    g = jnp.einsum("...d,df->...f", x, params["w_gate"])
    u = jnp.einsum("...d,df->...f", x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, params["w_down"])


def gelu_mlp_init(key, d_model: int, d_ff: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "w_in": dense_init(k1, d_model, (d_ff,), dtype),
        "b_in": jnp.zeros((d_ff,), dtype=dtype),
        "w_out": dense_init(k2, d_ff, (d_model,), dtype),
        "b_out": jnp.zeros((d_model,), dtype=dtype),
    }


def gelu_mlp(params: Params, x: jax.Array) -> jax.Array:
    """GELU MLP (whisper)."""
    h = jnp.einsum("...d,df->...f", x, params["w_in"]) + params["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, params["w_out"]) + params["b_out"]
