"""Unified language model over all assigned architecture families.

``LanguageModel(cfg)`` exposes:
  init(key)                          -> params
  train_loss(params, batch)         -> (loss, metrics)
  prefill(params, batch)            -> (last_logits, cache)
  decode_step(params, cache, token, cur_len) -> (logits, cache)
  cache_spec(batch, seq)            -> ShapeDtypeStruct tree (for AOT decode)

Layers are stacked (vmap-init) and iterated with ``lax.scan`` so compile
time and HLO size are O(1) in depth; heterogeneous stacks (deepseek's
leading dense layer, zamba2's shared-attention groups) scan homogeneous
segments.  The sequence-chunked cross-entropy never materializes full
(B, S, V) logits.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import current_ctx
from . import blocks
from .layers import Params, _dtype, embed_init, rmsnorm, rmsnorm_init


def stack_init(key, n: int, fn):
    return jax.vmap(fn)(jax.random.split(key, n))


def _remat(body, cfg):
    if cfg.remat == "none":
        return body
    if cfg.remat == "dots":
        return jax.checkpoint(
            body, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(body)


def _sinusoid(seq: int, dim: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / np.power(10000.0, 2 * i / dim)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return out.astype(np.float32)


@dataclasses.dataclass
class LanguageModel:
    cfg: Any

    @property
    def _dense_cfg(self):
        # deepseek-v2: the leading dense layer uses the full intermediate
        # size (12288) rather than the per-expert 1536
        cfg = self.cfg
        if cfg.use_mla and cfg.first_k_dense:
            return dataclasses.replace(cfg, d_ff=12288 if cfg.d_model == 5120
                                       else cfg.d_ff * 8)
        return cfg

    # ----------------------------------------------------------------- init

    def init(self, key) -> Params:
        cfg = self.cfg
        dt = _dtype(cfg.param_dtype)
        keys = jax.random.split(key, 8)
        p: Params = {
            "embedding": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
            "final_norm": rmsnorm_init(cfg.d_model, dt),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = (jax.random.normal(
                keys[1], (cfg.d_model, cfg.vocab_size), jnp.float32)
                / np.sqrt(cfg.d_model)).astype(dt)

        fam = cfg.family
        if fam in ("dense", "vlm"):
            p["layers"] = stack_init(
                keys[2], cfg.num_layers,
                lambda k: blocks.decoder_layer_init(k, cfg, "dense"))
        elif fam == "moe":
            kind = "mla_moe" if cfg.use_mla else "moe"
            dense_kind = "mla_dense" if cfg.use_mla else "dense"
            n_moe = cfg.num_layers - cfg.first_k_dense
            if cfg.first_k_dense:
                dense_cfg = self._dense_cfg
                p["dense_layers"] = stack_init(
                    keys[3], cfg.first_k_dense,
                    lambda k: blocks.decoder_layer_init(k, dense_cfg, dense_kind))
            p["layers"] = stack_init(
                keys[2], n_moe,
                lambda k: blocks.decoder_layer_init(k, cfg, kind))
        elif fam == "ssm":
            p["layers"] = stack_init(
                keys[2], cfg.num_layers,
                lambda k: blocks.mamba_layer_init(k, cfg))
        elif fam == "hybrid":
            p["layers"] = stack_init(
                keys[2], cfg.num_layers,
                lambda k: blocks.mamba_layer_init(k, cfg))
            p["shared_attn"] = blocks.decoder_layer_init(keys[3], cfg, "dense")
        elif fam == "encdec":
            p["enc_layers"] = stack_init(
                keys[2], cfg.num_encoder_layers,
                lambda k: blocks.enc_layer_init(k, cfg))
            p["dec_layers"] = stack_init(
                keys[3], cfg.num_layers,
                lambda k: blocks.dec_layer_init(k, cfg))
            from .layers import layernorm_init
            p["final_norm"] = layernorm_init(cfg.d_model, dt)
            p["enc_norm"] = layernorm_init(cfg.d_model, dt)
        else:
            raise ValueError(fam)
        return p

    # ------------------------------------------------------------ embedding

    def _embed(self, params: Params, tokens: jax.Array,
               extra: Optional[Dict[str, jax.Array]] = None) -> jax.Array:
        cfg = self.cfg
        ctx = current_ctx()
        x = jnp.take(params["embedding"], tokens, axis=0).astype(_dtype(cfg.dtype))
        if cfg.family == "vlm" and extra is not None and "patches" in extra:
            x = jnp.concatenate(
                [extra["patches"].astype(x.dtype), x], axis=1)
        return ctx.constrain(x, "dp", None, None)

    def _unembed_weight(self, params: Params) -> jax.Array:
        if self.cfg.tie_embeddings:
            return params["embedding"].T
        return params["lm_head"]

    # ----------------------------------------------------------- backbones

    def _hybrid_segments(self):
        cfg = self.cfg
        g = cfg.num_layers // cfg.attn_every
        rem = cfg.num_layers - g * cfg.attn_every
        return g, rem

    def _backbone_train(self, params: Params, x: jax.Array,
                        extra: Optional[Dict[str, jax.Array]] = None
                        ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Returns (hidden, aux dict summed over layers — moe.zero_aux
        schema: balance loss + dispatch drop/byte stats)."""
        from .moe import zero_aux
        cfg = self.cfg
        positions = jnp.arange(x.shape[1])[None, :]
        aux0 = zero_aux()
        fam = cfg.family

        def _acc(aux, a):
            return jax.tree_util.tree_map(jnp.add, aux, a)

        if fam in ("dense", "vlm", "moe"):
            if fam == "moe":
                kind = "mla_moe" if cfg.use_mla else "moe"
            else:
                kind = "dense"

            if "dense_layers" in params:
                dkind = "mla_dense" if cfg.use_mla else "dense"
                dcfg = self._dense_cfg

                def dbody(carry, p_l):
                    xx, aux = carry
                    xx, a = blocks.decoder_layer_train(p_l, xx, dcfg,
                                                       positions, dkind)
                    return (xx, _acc(aux, a)), None
                (x, aux0), _ = jax.lax.scan(_remat(dbody, cfg), (x, aux0),
                                            params["dense_layers"])

            def body(carry, p_l):
                xx, aux = carry
                xx, a = blocks.decoder_layer_train(p_l, xx, cfg, positions, kind)
                return (xx, _acc(aux, a)), None
            (x, aux), _ = jax.lax.scan(_remat(body, cfg), (x, aux0),
                                       params["layers"])
            return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux

        if fam == "ssm":
            def body(xx, p_l):
                return blocks.mamba_layer_train(p_l, xx, cfg), None
            x, _ = jax.lax.scan(_remat(body, cfg), x, params["layers"])
            return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux0

        if fam == "hybrid":
            g, rem = self._hybrid_segments()
            per = cfg.attn_every
            grouped = jax.tree_util.tree_map(
                lambda a: a[: g * per].reshape(g, per, *a.shape[1:]),
                params["layers"])
            remainder = jax.tree_util.tree_map(
                lambda a: a[g * per:], params["layers"])
            shared = params["shared_attn"]

            def mamba_body(xx, p_l):
                return blocks.mamba_layer_train(p_l, xx, cfg), None

            def group_body(xx, p_g):
                xx, _ = blocks.decoder_layer_train(shared, xx, cfg,
                                                   positions, "dense")
                xx, _ = jax.lax.scan(_remat(mamba_body, cfg), xx, p_g)
                return xx, None

            x, _ = jax.lax.scan(group_body, x, grouped)
            if rem:
                x, _ = jax.lax.scan(_remat(mamba_body, cfg), x, remainder)
            return rmsnorm(params["final_norm"], x, cfg.norm_eps), aux0

        if fam == "encdec":
            from .layers import layernorm
            frames = extra["frames"].astype(x.dtype)
            enc_pos = jnp.asarray(
                _sinusoid(frames.shape[1], cfg.d_model))[None].astype(x.dtype)
            e = frames + enc_pos

            def ebody(xx, p_l):
                return blocks.enc_layer_apply(p_l, xx, cfg), None
            e, _ = jax.lax.scan(_remat(ebody, cfg), e, params["enc_layers"])
            e = layernorm(params["enc_norm"], e, cfg.norm_eps)

            def dbody(xx, p_l):
                return blocks.dec_layer_train(p_l, xx, e, cfg, positions), None
            x, _ = jax.lax.scan(_remat(dbody, cfg), x, params["dec_layers"])
            return layernorm(params["final_norm"], x, cfg.norm_eps), aux0

        raise ValueError(fam)

    # ----------------------------------------------------------------- loss

    def lm_loss(self, params: Params, h: jax.Array, targets: jax.Array
                ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        """Sequence-chunked vocab-parallel cross entropy."""
        cfg = self.cfg
        ctx = current_ctx()
        b, s, d = h.shape
        w = self._unembed_weight(params).astype(h.dtype)
        chunk = min(cfg.loss_chunk, s)
        while s % chunk:
            chunk //= 2
        nc = s // chunk
        h_c = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
        t_c = jnp.moveaxis(targets.reshape(b, nc, chunk), 1, 0)

        def chunk_fn(carry, inp):
            loss_sum, z_sum, correct, count = carry
            h_i, t_i = inp
            logits = jnp.einsum("bsd,dv->bsv", h_i, w).astype(jnp.float32)
            logits = ctx.constrain(logits, "dp", None, "vocab")
            lse = jax.scipy.special.logsumexp(logits, axis=-1)
            safe_t = jnp.maximum(t_i, 0)
            ll = jnp.take_along_axis(logits, safe_t[..., None], axis=-1)[..., 0]
            mask = (t_i >= 0).astype(jnp.float32)
            loss_sum = loss_sum + jnp.sum((lse - ll) * mask)
            z_sum = z_sum + jnp.sum(jnp.square(lse) * mask)
            pred = jnp.argmax(logits, axis=-1)
            correct = correct + jnp.sum((pred == safe_t) * mask)
            count = count + jnp.sum(mask)
            return (loss_sum, z_sum, correct, count), None

        init = (jnp.zeros((), jnp.float32),) * 4
        (loss_sum, z_sum, correct, count), _ = jax.lax.scan(
            _remat(chunk_fn, cfg), init, (h_c, t_c))
        count = jnp.maximum(count, 1.0)
        loss = loss_sum / count
        metrics = {"ce_loss": loss, "z_loss": z_sum / count,
                   "accuracy": correct / count, "tokens": count}
        return loss, metrics

    def train_loss(self, params: Params, batch: Dict[str, jax.Array]
                   ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x = self._embed(params, batch["tokens"], batch)
        h, aux = self._backbone_train(params, x, batch)
        targets = batch["targets"]
        if cfg.family == "vlm" and "patches" in batch:
            # patch positions carry no next-token loss
            pad = jnp.full((targets.shape[0], batch["patches"].shape[1]),
                           -1, dtype=targets.dtype)
            targets = jnp.concatenate([pad, targets], axis=1)
        loss, metrics = self.lm_loss(params, h, targets)
        total = loss + 0.01 * aux["loss"] + 1e-4 * metrics["z_loss"]
        metrics["aux_loss"] = aux["loss"]
        # MoE dispatch stats (zeros for non-MoE families) — the trainer
        # surfaces these as Stats gauges, bench_moe snapshots them
        metrics["moe_dropped_tokens"] = aux["dropped"]
        metrics["moe_overflow_rate"] = aux["dropped"] / jnp.maximum(
            aux["routed"], 1.0)
        metrics["moe_a2a_bytes"] = aux["a2a_bytes"]
        metrics["loss"] = total
        return total, metrics

    # --------------------------------------------------------------- prefill

    def prefill(self, params: Params, batch: Dict[str, jax.Array]
                ) -> Tuple[jax.Array, Any]:
        cfg = self.cfg
        x = self._embed(params, batch["tokens"], batch)
        positions = jnp.arange(x.shape[1])[None, :]
        fam = cfg.family
        cache: Dict[str, Any] = {}

        if fam in ("dense", "vlm", "moe"):
            kind = ("mla_moe" if cfg.use_mla else "moe") if fam == "moe" else "dense"
            if "dense_layers" in params:
                dkind = "mla_dense" if cfg.use_mla else "dense"
                dcfg = self._dense_cfg

                def dbody(xx, p_l):
                    return blocks.decoder_layer_prefill(p_l, xx, dcfg,
                                                        positions, dkind)
                x, cache["dense"] = jax.lax.scan(dbody, x,
                                                 params["dense_layers"])

            def body(xx, p_l):
                return blocks.decoder_layer_prefill(p_l, xx, cfg, positions, kind)
            x, cache["layers"] = jax.lax.scan(body, x, params["layers"])
            h = rmsnorm(params["final_norm"], x, cfg.norm_eps)

        elif fam == "ssm":
            def body(xx, p_l):
                return blocks.mamba_layer_prefill(p_l, xx, cfg)
            x, cache["layers"] = jax.lax.scan(body, x, params["layers"])
            h = rmsnorm(params["final_norm"], x, cfg.norm_eps)

        elif fam == "hybrid":
            g, rem = self._hybrid_segments()
            per = cfg.attn_every
            grouped = jax.tree_util.tree_map(
                lambda a: a[: g * per].reshape(g, per, *a.shape[1:]),
                params["layers"])
            remainder = jax.tree_util.tree_map(
                lambda a: a[g * per:], params["layers"])
            shared = params["shared_attn"]

            def mamba_body(xx, p_l):
                return blocks.mamba_layer_prefill(p_l, xx, cfg)

            def group_body(xx, p_g):
                xx, attn_c = blocks.decoder_layer_prefill(
                    shared, xx, cfg, positions, "dense")
                xx, mamba_c = jax.lax.scan(mamba_body, xx, p_g)
                return xx, {"attn": attn_c, "mamba": mamba_c}

            x, gcache = jax.lax.scan(group_body, x, grouped)
            cache["groups"] = gcache
            if rem:
                x, cache["remainder"] = jax.lax.scan(mamba_body, x, remainder)
            h = rmsnorm(params["final_norm"], x, cfg.norm_eps)

        elif fam == "encdec":
            from .layers import layernorm
            frames = batch["frames"].astype(x.dtype)
            enc_pos = jnp.asarray(
                _sinusoid(frames.shape[1], cfg.d_model))[None].astype(x.dtype)
            e = frames + enc_pos

            def ebody(xx, p_l):
                return blocks.enc_layer_apply(p_l, xx, cfg), None
            e, _ = jax.lax.scan(ebody, e, params["enc_layers"])
            e = layernorm(params["enc_norm"], e, cfg.norm_eps)

            def dbody(xx, p_l):
                return blocks.dec_layer_prefill(p_l, xx, e, cfg, positions)
            x, cache["layers"] = jax.lax.scan(dbody, x, params["dec_layers"])
            h = layernorm(params["final_norm"], x, cfg.norm_eps)
        else:
            raise ValueError(fam)

        logits = jnp.einsum("bd,dv->bv", h[:, -1],
                            self._unembed_weight(params).astype(h.dtype))
        return logits.astype(jnp.float32), cache

    # ---------------------------------------------------------------- decode

    def decode_step(self, params: Params, cache: Any, token: jax.Array,
                    cur_len: jax.Array) -> Tuple[jax.Array, Any]:
        """token: (B, 1) int32; cur_len: scalar int32 tokens already cached."""
        cfg = self.cfg
        x = jnp.take(params["embedding"], token, axis=0).astype(_dtype(cfg.dtype))
        fam = cfg.family
        new_cache: Dict[str, Any] = {}

        if fam in ("dense", "vlm", "moe"):
            kind = ("mla_moe" if cfg.use_mla else "moe") if fam == "moe" else "dense"
            if "dense_layers" in params:
                dkind = "mla_dense" if cfg.use_mla else "dense"
                dcfg = self._dense_cfg

                def dbody(xx, inp):
                    p_l, c_l = inp
                    return blocks.decoder_layer_decode(p_l, xx, dcfg, c_l,
                                                       cur_len, dkind)
                x, new_cache["dense"] = jax.lax.scan(
                    dbody, x, (params["dense_layers"], cache["dense"]))

            def body(xx, inp):
                p_l, c_l = inp
                return blocks.decoder_layer_decode(p_l, xx, cfg, c_l,
                                                   cur_len, kind)
            x, new_cache["layers"] = jax.lax.scan(
                body, x, (params["layers"], cache["layers"]))
            h = rmsnorm(params["final_norm"], x, cfg.norm_eps)

        elif fam == "ssm":
            def body(xx, inp):
                p_l, c_l = inp
                return blocks.mamba_layer_decode(p_l, xx, cfg, c_l)
            x, new_cache["layers"] = jax.lax.scan(
                body, x, (params["layers"], cache["layers"]))
            h = rmsnorm(params["final_norm"], x, cfg.norm_eps)

        elif fam == "hybrid":
            g, rem = self._hybrid_segments()
            per = cfg.attn_every
            grouped = jax.tree_util.tree_map(
                lambda a: a[: g * per].reshape(g, per, *a.shape[1:]),
                params["layers"])
            remainder = jax.tree_util.tree_map(
                lambda a: a[g * per:], params["layers"])
            shared = params["shared_attn"]

            def mamba_body(xx, inp):
                p_l, c_l = inp
                return blocks.mamba_layer_decode(p_l, xx, cfg, c_l)

            def group_body(xx, inp):
                p_g, c_g = inp
                xx, attn_c = blocks.decoder_layer_decode(
                    shared, xx, cfg, c_g["attn"], cur_len, "dense")
                xx, mamba_c = jax.lax.scan(mamba_body, xx, (p_g, c_g["mamba"]))
                return xx, {"attn": attn_c, "mamba": mamba_c}

            x, new_cache["groups"] = jax.lax.scan(
                group_body, x, (grouped, cache["groups"]))
            if rem:
                x, new_cache["remainder"] = jax.lax.scan(
                    mamba_body, x, (remainder, cache["remainder"]))
            h = rmsnorm(params["final_norm"], x, cfg.norm_eps)

        elif fam == "encdec":
            from .layers import layernorm

            def body(xx, inp):
                p_l, c_l = inp
                return blocks.dec_layer_decode(p_l, xx, cfg, c_l, cur_len)
            x, new_cache["layers"] = jax.lax.scan(
                body, x, (params["dec_layers"], cache["layers"]))
            h = layernorm(params["final_norm"], x, cfg.norm_eps)
        else:
            raise ValueError(fam)

        logits = jnp.einsum("bd,dv->bv", h[:, -1],
                            self._unembed_weight(params).astype(h.dtype))
        return logits.astype(jnp.float32), new_cache

    # ------------------------------------------------------------ cache spec

    def cache_spec(self, batch: int, seq: int) -> Any:
        """ShapeDtypeStruct tree for an AOT decode step (no allocation)."""
        cfg = self.cfg
        bf = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
        f32 = jnp.float32
        S = jax.ShapeDtypeStruct
        fam = cfg.family
        kh, hd = cfg.num_kv_heads, cfg.head_dim

        def kv(n_layers):
            # head-major decode caches (B, K, S, hd) — see dist/flash.py
            return {"k": S((n_layers, batch, kh, seq, hd), bf),
                    "v": S((n_layers, batch, kh, seq, hd), bf)}

        def mla(n_layers):
            return {"c_kv": S((n_layers, batch, seq, cfg.kv_lora_rank), bf),
                    "k_rope": S((n_layers, batch, seq, cfg.qk_rope_head_dim), bf)}

        def mamba(n_layers):
            ck = cfg.conv_kernel - 1
            return {"conv_x": S((n_layers, batch, ck, cfg.d_inner), bf),
                    "conv_B": S((n_layers, batch, ck, cfg.ssm_state), bf),
                    "conv_C": S((n_layers, batch, ck, cfg.ssm_state), bf),
                    "state": S((n_layers, batch, cfg.ssm_heads,
                                cfg.ssm_head_dim, cfg.ssm_state), f32)}

        if fam in ("dense", "vlm"):
            return {"layers": kv(cfg.num_layers)}
        if fam == "moe":
            inner = mla if cfg.use_mla else kv
            out = {"layers": inner(cfg.num_layers - cfg.first_k_dense)}
            if cfg.first_k_dense:
                out["dense"] = inner(cfg.first_k_dense)
            return out
        if fam == "ssm":
            return {"layers": mamba(cfg.num_layers)}
        if fam == "hybrid":
            g, rem = self._hybrid_segments()
            per = cfg.attn_every
            groups = {
                "attn": {"k": S((g, batch, kh, seq, hd), bf),
                         "v": S((g, batch, kh, seq, hd), bf)},
                "mamba": jax.tree_util.tree_map(
                    lambda s: S((g, per, *s.shape[1:]), s.dtype), mamba(1)),
            }
            out = {"groups": groups}
            if rem:
                out["remainder"] = mamba(rem)
            return out
        if fam == "encdec":
            enc = cfg.encoder_seq
            h = cfg.num_heads
            return {"layers": {
                "k": S((cfg.num_layers, batch, h, seq, hd), bf),
                "v": S((cfg.num_layers, batch, h, seq, hd), bf),
                "cross_k": S((cfg.num_layers, batch, enc, h, hd), bf),
                "cross_v": S((cfg.num_layers, batch, enc, h, hd), bf)}}
        raise ValueError(fam)
