from .steps import make_prefill_step, make_decode_step
