from .steps import (make_decode_step, make_paged_decode_step,
                    make_paged_prefill_step, make_prefill_step)
from .engine import (ModelBackend, Request, ServeEngine, StepCost,
                     SyntheticBackend, poisson_workload, run_static)
