"""Continuous-batching serve engine on a §6-paged KV cache.

The engine couples two layers:

* the virtual-time OCR runtime models the *resources*: request slots are
  a labeled-GUID array (§4 — a creator function makes each slot exactly
  once, so concurrent same-timestamp admissions can never double-create),
  the KV cache is one shared data block whose fixed-size pages are §6
  partitions (disjointness is enforced by ``db_partition``), and session
  eviction rides PR 5's spill machinery — a cold session's pages are
  demoted into an archive block that spills through the IO queue and
  re-materializes on resume via the existing grant-deferral path;
* a pluggable compute backend produces the tokens: ``ModelBackend`` runs
  the real paged jax steps (`repro.serve.steps`), ``SyntheticBackend`` is
  a deterministic token function for open-loop benchmark sweeps.

Scheduling is classic continuous batching: an admission queue feeds free
slots, prefill interleaves with the running decode batch, rows join and
leave every step, and page-table indirection keeps the decode tensor at a
fixed (B_cap, max_pages) shape so nothing ever retraces.  Time is virtual
(`StepCost`), which makes the continuous-vs-static comparison and the
p50/p99 numbers deterministic and machine-independent.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core import (DbMode, EDT_PROP_MAPPED, NULL_GUID, Runtime, TaskCtx,
                        spawn_main)


# ------------------------------------------------------------------ workload

@dataclasses.dataclass
class Request:
    rid: int
    arrival: float                    # virtual seconds
    prompt: np.ndarray                # (plen,) int32
    gen: int                          # tokens to produce (incl. first)
    out: List[int] = dataclasses.field(default_factory=list)
    t_first: float = -1.0
    t_done: float = -1.0


def poisson_workload(n: int, rate: float, *, prompt_len=(8, 32),
                     gen=(4, 16), vocab: int = 512, seed: int = 0
                     ) -> List[Request]:
    """Open-loop Poisson arrivals: exponential gaps at ``rate`` req/s."""
    rng = np.random.RandomState(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        plen = int(rng.randint(prompt_len[0], prompt_len[1] + 1))
        g = int(rng.randint(gen[0], gen[1] + 1))
        reqs.append(Request(rid=i, arrival=t,
                            prompt=rng.randint(0, vocab, plen).astype(np.int32),
                            gen=g))
    return reqs


@dataclasses.dataclass
class StepCost:
    """Virtual cost model.  The decode tensor is a fixed (B_cap, ·) shape,
    so a step costs the same whether rows are active or padding — the
    continuous engine wins by keeping more of them useful."""
    prefill_base: float = 2e-3
    prefill_per_tok: float = 1e-4
    decode_base: float = 1e-3
    decode_per_row: float = 1e-4


# ------------------------------------------------------------------ backends

class SyntheticBackend:
    """Deterministic stand-in: the token stream is a pure function of
    (request id, cache length), so eviction timing can never change the
    output — ``restore_row`` verifies the archive bytes round-tripped the
    spill file intact."""

    def __init__(self, page_size: int, *, kv_bytes_per_token: int = 32,
                 vocab: int = 50257):
        self.page = page_size
        self.page_bytes = page_size * kv_bytes_per_token
        self.vocab = vocab
        self._rid = {}

    def _tok(self, rid: int, cur: int) -> int:
        return (rid * 2654435761 + cur * 97) % self.vocab

    def _pattern(self, rid: int, logical_page: int) -> bytes:
        base = (rid * 31 + logical_page * 7) % 256
        return bytes(((base + j) % 256) for j in range(min(self.page_bytes, 64))
                     ) * ((self.page_bytes + 63) // 64)

    def prefill(self, row: int, req: Request, pages: List[int]) -> int:
        self._rid[row] = req.rid
        return self._tok(req.rid, len(req.prompt))

    def decode_step(self, page_table, cur_lens, active, tokens, rids):
        out = np.zeros(len(cur_lens), np.int64)
        for r in np.nonzero(active)[0]:
            out[r] = self._tok(int(rids[r]), int(cur_lens[r]) + 1)
        return out

    def evict_row(self, row: int, pages: List[int]) -> bytes:
        rid = self._rid[row]
        return b"".join(self._pattern(rid, i)[: self.page_bytes]
                        for i in range(len(pages)))

    def restore_row(self, row: int, pages: List[int], raw: bytes,
                    cur_len: int) -> None:
        rid = self._rid[row]
        expect = b"".join(self._pattern(rid, i)[: self.page_bytes]
                          for i in range(len(pages)))
        if raw[: len(expect)] != expect:
            raise RuntimeError(
                f"request {rid}: KV bytes corrupted through the spill "
                f"round-trip")


class ModelBackend:
    """Real paged jax serving: per-layer page pools plus the jitted
    prefill-into-pages / paged-decode steps from ``repro.serve.steps``."""

    def __init__(self, model, params, *, pool_pages: int, page_size: int,
                 prompt_pad: int):
        import jax.numpy as jnp
        from repro.models.layers import _dtype
        from repro.serve.steps import (make_paged_decode_step,
                                       make_paged_prefill_step)
        cfg = model.cfg
        if prompt_pad % page_size:
            raise ValueError("prompt_pad must be a multiple of page_size")
        self.model, self.params = model, params
        self.page = page_size
        self.pool_pages = pool_pages
        self.prompt_pad = prompt_pad
        dt = _dtype(cfg.dtype)
        shape = (cfg.num_layers, pool_pages, cfg.num_kv_heads, page_size,
                 cfg.head_dim)
        self.k_pools = jnp.zeros(shape, dt)
        self.v_pools = jnp.zeros(shape, dt)
        self._np_dtype = np.asarray(jnp.zeros((), dt)).dtype
        self.page_bytes = (2 * cfg.num_layers * cfg.num_kv_heads * page_size
                           * cfg.head_dim * self._np_dtype.itemsize)
        self._prefill = make_paged_prefill_step(model, page_size)
        self._decode = make_paged_decode_step(model)

    def prefill(self, row: int, req: Request, pages: List[int]) -> int:
        import jax.numpy as jnp
        plen = len(req.prompt)
        if plen > self.prompt_pad:
            raise ValueError(f"prompt {plen} > prompt_pad {self.prompt_pad}")
        tk = np.zeros((1, self.prompt_pad), np.int32)
        tk[0, :plen] = req.prompt
        pg = np.full(self.prompt_pad // self.page, self.pool_pages, np.int32)
        pg[: len(pages)] = pages
        nt, _, self.k_pools, self.v_pools = self._prefill(
            self.params, self.k_pools, self.v_pools, jnp.asarray(tk),
            jnp.int32(plen), jnp.asarray(pg))
        return int(nt)

    def decode_step(self, page_table, cur_lens, active, tokens, rids):
        import jax.numpy as jnp
        nt, _, self.k_pools, self.v_pools, _ = self._decode(
            self.params, self.k_pools, self.v_pools,
            jnp.asarray(page_table), jnp.asarray(cur_lens),
            jnp.asarray(active), jnp.asarray(tokens))
        return np.asarray(nt)

    def evict_row(self, row: int, pages: List[int]) -> bytes:
        import jax.numpy as jnp
        idx = jnp.asarray(np.asarray(pages, np.int32))
        k = np.asarray(self.k_pools[:, idx])
        v = np.asarray(self.v_pools[:, idx])
        return k.tobytes() + v.tobytes()

    def restore_row(self, row: int, pages: List[int], raw: bytes,
                    cur_len: int) -> None:
        import jax.numpy as jnp
        idx = jnp.asarray(np.asarray(pages, np.int32))
        half = len(raw) // 2
        shape = (self.k_pools.shape[0], len(pages), *self.k_pools.shape[2:])
        k = np.frombuffer(raw[:half], self._np_dtype).reshape(shape)
        v = np.frombuffer(raw[half:], self._np_dtype).reshape(shape)
        self.k_pools = self.k_pools.at[:, idx].set(jnp.asarray(k))
        self.v_pools = self.v_pools.at[:, idx].set(jnp.asarray(v))


# ----------------------------------------------------------- labeled slots

def _slot_creator(ctx, lid, index, paramv, guidv):
    """§4 creator: runs exactly once per slot label, at the owning node,
    no matter how many same-timestamp admissions race on the index."""
    ctx.db_create(paramv[0], props=EDT_PROP_MAPPED)


@dataclasses.dataclass
class _Session:
    req: Request
    slot: int                          # slot index == batch row
    slot_guid: Any = None
    pages: List[int] = dataclasses.field(default_factory=list)
    page_guids: List[Any] = dataclasses.field(default_factory=list)
    cur: int = 0                       # tokens in the KV cache
    produced: int = 0
    last_tok: int = 0
    state: str = "running"             # running | evicted | resuming
    archive: Any = None
    n_pages_archived: int = 0
    just_resumed: bool = False         # decoded 0 tokens since resume


# -------------------------------------------------------------------- engine

class ServeEngine:
    """Continuous-batching loop over a paged KV cache with spill eviction.

    ``b_cap`` slots (= batch rows), ``pool_pages`` pages of
    ``backend.page_bytes`` each inside one shared §6 cache block,
    ``max_pages`` page-table width.  ``resident_budget`` (data blocks per
    node) arms the runtime's spill threshold: session archives past it
    write back to disk through the IO queue and resume via grant deferral.
    """

    def __init__(self, backend, *, b_cap: int, pool_pages: int,
                 max_pages: int, resident_budget: Optional[int] = None,
                 io_latency: float = 2e-3, cost: Optional[StepCost] = None,
                 sanitize: Any = None, monitor: Any = None,
                 admit_max_inflight_io: Optional[int] = None,
                 admit_max_queue_depth: Optional[int] = None,
                 monitor_interval: float = 0.0,
                 on_monitor: Optional[Any] = None):
        self.backend = backend
        self.b_cap = b_cap
        self.pool_pages = pool_pages
        self.max_pages = max_pages
        self.page = backend.page
        self.cost = cost or StepCost()
        self._eps = 1e-9

        # IO backpressure admission gates (live registry values, PR 7
        # follow-on: today's gates are free pages/slots only).  Setting
        # either — or asking for interval snapshots — implies monitoring.
        self.admit_max_inflight_io = admit_max_inflight_io
        self.admit_max_queue_depth = admit_max_queue_depth
        self.monitor_interval = float(monitor_interval)
        self.on_monitor = on_monitor
        if monitor is None and (admit_max_inflight_io is not None
                                or admit_max_queue_depth is not None
                                or monitor_interval > 0.0):
            monitor = True

        self.rt = Runtime(spill_threshold=resident_budget,
                          io_latency=io_latency, shard_bits=4,
                          sanitize=sanitize, monitor=monitor)
        self.registry = self.rt.registry
        self.ctx = TaskCtx(self.rt, 0, None)
        self.cache_db, _ = self.ctx.db_create(pool_pages * backend.page_bytes)
        self.slot_map = self.ctx.map_create(b_cap, _slot_creator,
                                            paramv=(64,))
        self.free_pages: List[int] = list(range(pool_pages))
        self.free_slots: deque = deque(range(b_cap))
        self.sessions: Dict[int, _Session] = {}

        self.page_table = np.full((b_cap, max_pages), pool_pages, np.int32)
        self.cur_lens = np.zeros(b_cap, np.int32)
        self.active = np.zeros(b_cap, bool)
        self.tokens = np.zeros(b_cap, np.int32)
        self.rids = np.full(b_cap, -1, np.int64)

        self.t = 0.0
        self.evictions = 0
        self.resumes = 0
        self.peak_spilled = 0
        self._resume_ready: Dict[int, bytes] = {}
        self.deferred_admissions = 0
        self.monitor_snapshots: List[Dict[str, float]] = []
        self._admit_queue: Optional[deque] = None

    # -- time / DES glue ----------------------------------------------------

    def san_report(self):
        """Sanitizer findings for the engine's runtime (needs
        ``sanitize=`` at construction or ``REPRO_SANITIZE`` set)."""
        return self.rt.san_report()

    # -- monitoring ----------------------------------------------------------

    def monitor(self) -> Dict[str, float]:
        """Mid-run snapshot of the whole monitoring registry.

        Callable from inside ``run()`` (via ``monitor_interval`` /
        ``on_monitor``) or between calls: refreshes the live ``io.*``
        gauges to the current virtual instant, stamps the engine's own
        ``serve.*`` gauges, and returns ``Registry.snapshot()`` — no
        virtual time passes, nothing stops.
        """
        reg = self.rt.registry
        if self.rt._mon is not None:
            self.rt._mon.on_io(self.rt.io)
        reg.set("serve.time_s", self.t)
        reg.set("serve.queued",
                0 if self._admit_queue is None else len(self._admit_queue))
        reg.set("serve.sessions", len(self.sessions))
        reg.set("serve.active",
                sum(1 for s in self.sessions.values()
                    if s.state == "running"))
        reg.set("serve.free_pages", len(self.free_pages))
        reg.set("serve.free_slots", len(self.free_slots))
        reg.set("serve.evictions", self.evictions)
        reg.set("serve.resumes", self.resumes)
        reg.set("serve.deferred_admissions", self.deferred_admissions)
        return reg.snapshot()

    def _io_backpressured(self) -> bool:
        """The live-registry admission gate: defer admissions while the
        IO plane is saturated (ops in flight / queued behind the disk
        past the configured bounds), even when pages and a slot are
        free — the page/slot-only gate would admit into the backlog."""
        if (self.admit_max_inflight_io is None
                and self.admit_max_queue_depth is None):
            return False
        if self.rt._mon is not None:
            self.rt._mon.on_io(self.rt.io)
        reg = self.rt.registry
        if (self.admit_max_inflight_io is not None
                and reg.value("io.inflight_ops")
                > self.admit_max_inflight_io):
            return True
        if (self.admit_max_queue_depth is not None
                and reg.value("io.queue_depth")
                > self.admit_max_queue_depth):
            return True
        return False

    def _flush(self) -> None:
        """Drain runtime events up to the engine clock, then pin the DES
        clock to it so newly spawned tasks schedule at engine time."""
        self.rt.run(until=self.t)
        self.rt.clock = max(self.rt.clock, self.t)
        self.peak_spilled = max(self.peak_spilled,
                                self.rt.stats.spilled_objects)

    # -- pages --------------------------------------------------------------

    def _alloc_pages(self, sess: _Session, n: int) -> None:
        """Carve ``n`` fresh pages for ``sess`` out of the shared cache
        block — one ``db_partition`` call, so overlap with any live page
        is a hard runtime error, not a silent corruption."""
        while len(self.free_pages) < n:
            if not self._evict_one(protect=sess):
                raise RuntimeError(
                    f"page pool exhausted: {n} pages needed, "
                    f"{len(self.free_pages)} free, nothing evictable")
        phys = [self.free_pages.pop(0) for _ in range(n)]
        pb = self.backend.page_bytes
        guids = self.ctx.db_partition(
            self.cache_db, [(p * pb, pb) for p in phys])
        row = sess.slot
        for p in phys:
            self.page_table[row, len(sess.pages)] = p
            sess.pages.append(p)
        sess.page_guids.extend(guids)

    def _release_pages(self, sess: _Session) -> None:
        for g in sess.page_guids:
            self.ctx.db_destroy(g)
        self._flush()                     # land the destroys before reuse
        self.free_pages.extend(sess.pages)
        row = sess.slot
        self.page_table[row, :] = self.pool_pages
        sess.pages, sess.page_guids = [], []

    # -- admission ----------------------------------------------------------

    def _admit(self, req: Request) -> _Session:
        slot = self.free_slots.popleft()
        sess = _Session(req=req, slot=slot)
        eng = self

        def _body(paramv, depv, api):
            # §4 slot allocation: the creator makes the slot block exactly
            # once per label; reuse after retirement returns the same GUID
            lid = api.map_get(eng.slot_map, slot)

            def _stamp(pv, dv, a):
                # EW acquire of the slot block: records the request id and
                # touch-stamps the block for the recency spill policy
                dv[0].ptr[:8] = np.frombuffer(
                    np.int64(req.rid).tobytes(), np.uint8)
                return NULL_GUID

            tmpl = api.edt_template_create(_stamp, 0, 1)
            api.edt_create(tmpl, depv=[lid], dep_modes=[DbMode.EW],
                           duration=eng._eps)
            return NULL_GUID

        spawn_main(self.rt, _body, duration=self._eps)
        self._flush()
        m = self.rt.lookup(self.rt.resolve(self.slot_map))
        sess.slot_guid = m.entries[slot]

        plen = len(req.prompt)
        self._alloc_pages(sess, (plen + self.page - 1) // self.page)
        first = self.backend.prefill(slot, req, sess.pages)
        self.t += (self.cost.prefill_base
                   + self.cost.prefill_per_tok * plen)
        self._flush()

        sess.cur = plen
        sess.produced = 1
        sess.last_tok = first
        req.out.append(first)
        req.t_first = self.t
        if self.rt._mon is not None:
            self.rt.registry.histogram("serve.ttft_s").observe(
                self.t - req.arrival)
        self.cur_lens[slot] = plen
        self.tokens[slot] = first
        self.rids[slot] = req.rid
        self.active[slot] = True
        self.sessions[slot] = sess
        if sess.produced >= req.gen:
            self._retire(sess)
        return sess

    def _retire(self, sess: _Session) -> None:
        sess.req.t_done = self.t
        if self.rt._mon is not None:
            self.rt.registry.histogram("serve.latency_s").observe(
                self.t - sess.req.arrival)
        self._release_pages(sess)
        self.active[sess.slot] = False
        self.cur_lens[sess.slot] = 0
        self.rids[sess.slot] = -1
        del self.sessions[sess.slot]
        self.free_slots.append(sess.slot)

    # -- eviction / resume --------------------------------------------------

    def _evict_one(self, protect: Optional[_Session] = None) -> bool:
        cands = [s for s in self.sessions.values()
                 if s.state == "running" and s is not protect and s.pages]
        if not cands:
            return False
        # anti-ping-pong: a freshly resumed session gets to decode at least
        # one token before it can be demoted again, else resume/evict can
        # livelock under sustained page pressure
        fresh = [s for s in cands if not s.just_resumed]
        pool = fresh or cands
        victim = max(pool, key=lambda s: (s.req.gen - s.produced, -s.slot))
        self.evict(victim)
        return True

    def evict(self, sess: _Session) -> None:
        """Demote a session: serialize its pages into an archive block,
        destroy the page partitions, and let the spill policy write the
        cold archive back to disk."""
        raw = self.backend.evict_row(sess.slot, sess.pages)
        g, buf = self.ctx.db_create(max(len(raw), 1))
        if raw:
            buf[: len(raw)] = np.frombuffer(raw, np.uint8)
        sess.archive = g
        sess.n_pages_archived = len(sess.pages)
        self._release_pages(sess)
        self.active[sess.slot] = False
        sess.state = "evicted"
        self.evictions += 1
        self.rt.spill_check(0)           # the archive is new cold memory
        self._flush()

    def _start_resume(self, sess: _Session) -> None:
        """Acquire the (possibly spilled) archive RO from a task: a
        spilled archive defers the grant until the IO-queue read lands —
        the same path §5 unread file chunks take."""
        sess.state = "resuming"
        eng = self

        def _body(paramv, depv, api):
            eng._resume_ready[sess.req.rid] = bytes(depv[0].ptr)
            return NULL_GUID

        def _main(paramv, depv, api):
            tmpl = api.edt_template_create(_body, 0, 1)
            api.edt_create(tmpl, depv=[sess.archive],
                           dep_modes=[DbMode.RO], duration=eng._eps)
            return NULL_GUID

        spawn_main(self.rt, _main, duration=self._eps)
        self._flush()

    def _finish_resume(self, sess: _Session) -> None:
        raw = self._resume_ready.pop(sess.req.rid)
        n = sess.n_pages_archived
        self._alloc_pages(sess, n)
        self.backend.restore_row(sess.slot, sess.pages, raw, sess.cur)
        self.ctx.db_destroy(sess.archive)
        sess.archive = None
        sess.state = "running"
        sess.just_resumed = True
        self.cur_lens[sess.slot] = sess.cur
        self.tokens[sess.slot] = sess.last_tok
        self.active[sess.slot] = True
        self.resumes += 1
        self._flush()

    # -- main loop ----------------------------------------------------------

    def run(self, requests: List[Request]) -> Dict[str, float]:
        pending = deque(sorted(requests, key=lambda r: r.arrival))
        queued: deque = deque()
        self._admit_queue = queued
        next_snap = 0.0
        n_done = 0
        total = len(requests)

        while n_done < total:
            self._flush()
            if self.monitor_interval > 0.0 and self.t >= next_snap:
                snap = self.monitor()
                self.monitor_snapshots.append(snap)
                if self.on_monitor is not None:
                    self.on_monitor(self.t, snap)
                next_snap = self.t + self.monitor_interval
            while pending and pending[0].arrival <= self.t:
                queued.append(pending.popleft())

            # resumed sessions rejoin before new admissions (they arrived
            # first); only land ones whose archive bytes are back
            for sess in list(self.sessions.values()):
                if (sess.state == "resuming"
                        and sess.req.rid in self._resume_ready
                        and len(self.free_pages) > sess.n_pages_archived):
                    self._finish_resume(sess)

            # admissions: prefill interleaves with the running batch
            while queued and self.free_slots:
                if self._io_backpressured():
                    # pages and a slot may be free — the page/slot-only
                    # gate would admit — but the IO plane is saturated:
                    # defer until the backlog drains (its MIoDone events
                    # guarantee forward progress below)
                    self.deferred_admissions += 1
                    break
                req = queued.popleft()
                need = (len(req.prompt) + self.page - 1) // self.page
                if (len(self.free_pages) < need + 1
                        and not any(s.state == "running"
                                    for s in self.sessions.values())):
                    queued.appendleft(req)   # wait for pages, not deadlock
                    break
                before = self._done_count(requests)
                self._admit(req)
                n_done += self._done_count(requests) - before

            # kick resume reads for evicted sessions
            for sess in self.sessions.values():
                if sess.state == "evicted":
                    self._start_resume(sess)

            rows = [s for s in self.sessions.values() if s.state == "running"]
            if not rows:
                nxt = []
                if pending:
                    nxt.append(pending[0].arrival)
                if self.rt._heap:
                    nxt.append(self.rt._heap[0][0])
                if not nxt:
                    if queued:
                        raise RuntimeError("serve engine stalled with "
                                           f"{len(queued)} queued requests")
                    break
                self.t = max(self.t, min(nxt))
                continue

            # grow pages for rows whose next token crosses a boundary;
            # _alloc_pages may evict a session that is still in this
            # snapshot, so re-check state as we go
            for sess in rows:
                if (sess.state == "running"
                        and sess.cur // self.page >= len(sess.pages)):
                    self._alloc_pages(sess, 1)
            rows = [s for s in rows if s.state == "running"]
            if not rows:
                continue

            nt = self.backend.decode_step(self.page_table, self.cur_lens,
                                          self.active, self.tokens,
                                          self.rids)
            self.t += (self.cost.decode_base
                       + self.cost.decode_per_row * self.b_cap)
            for sess in rows:
                row = sess.slot
                sess.just_resumed = False
                sess.cur += 1
                self.cur_lens[row] = sess.cur
                sess.produced += 1
                sess.last_tok = int(nt[row])
                self.tokens[row] = sess.last_tok
                sess.req.out.append(sess.last_tok)
                if sess.produced >= sess.req.gen:
                    self._retire(sess)
                    n_done += 1

        self._flush()
        return self._metrics(requests)

    @staticmethod
    def _done_count(requests) -> int:
        return sum(1 for r in requests if r.t_done >= 0)

    def _metrics(self, requests) -> Dict[str, float]:
        lat = np.array([r.t_done - r.arrival for r in requests])
        tokens = sum(r.gen for r in requests)
        stats = self.rt.stats
        out = {
            "tokens": float(tokens),
            "makespan_s": float(self.t),
            "tok_per_s": tokens / max(self.t, 1e-12),
            "p50_latency_s": float(np.percentile(lat, 50)),
            "p99_latency_s": float(np.percentile(lat, 99)),
            "evictions": float(self.evictions),
            "resumes": float(self.resumes),
            "spilled_objects": float(self.peak_spilled),
            "creator_calls": float(stats.creator_calls),
            "spill_slots_reused": float(stats.spill_slots_reused),
            "deferred_admissions": float(self.deferred_admissions),
        }
        if self.rt._mon is not None:
            # histogram-sourced quantiles: measured distributions over
            # every retirement, not the two-point np.percentile summary
            reg = self.rt.registry
            lat_h = reg.histogram("serve.latency_s")
            ttft_h = reg.histogram("serve.ttft_s")
            out["p50_hist_latency_s"] = lat_h.quantile(0.50)
            out["p99_hist_latency_s"] = lat_h.quantile(0.99)
            out["p99_hist_ttft_s"] = ttft_h.quantile(0.99)
        return out


# ----------------------------------------------------------- static baseline

def run_static(requests: List[Request], b_cap: int,
               cost: Optional[StepCost] = None) -> Dict[str, float]:
    """Static-batch baseline: admit whatever is queued when the engine is
    free (up to ``b_cap``), prefill the batch, decode lockstep until the
    *longest* request finishes, only then admit again.  Same per-step cost
    model as the continuous engine — the drain/fill bubbles are the only
    difference, which is the point of the comparison."""
    cost = cost or StepCost()
    reqs = sorted(requests, key=lambda r: r.arrival)
    t, i, lat, tokens = 0.0, 0, [], 0
    step = cost.decode_base + cost.decode_per_row * b_cap
    while i < len(reqs):
        t = max(t, reqs[i].arrival)
        batch = [reqs[i]]
        i += 1
        while i < len(reqs) and reqs[i].arrival <= t and len(batch) < b_cap:
            batch.append(reqs[i])
            i += 1
        for r in batch:
            t += cost.prefill_base + cost.prefill_per_tok * len(r.prompt)
        # per-request completion credited at its own step (generous to the
        # baseline); the engine still drains to the longest request
        for r in batch:
            lat.append(t + (r.gen - 1) * step - r.arrival)
            tokens += r.gen
        t += (max(r.gen for r in batch) - 1) * step
    lat_a = np.array(lat)
    return {
        "tokens": float(tokens),
        "makespan_s": float(t),
        "tok_per_s": tokens / max(t, 1e-12),
        "p50_latency_s": float(np.percentile(lat_a, 50)),
        "p99_latency_s": float(np.percentile(lat_a, 99)),
    }
