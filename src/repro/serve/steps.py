"""Serving steps: prefill and single-token decode (the shapes the
``decode_*`` / ``long_*`` dry-run cells lower), plus the paged variants
the continuous-batching engine runs.

The paged steps keep the whole KV cache in per-layer page pools
(L, P, KH, page, hd) indexed through a (B, max_pages) page table — §6's
disjoint-partition decomposition applied to serving: every request owns a
disjoint set of fixed-size pages of one shared cache block, appended as
it decodes.  Positions are carried as traced (B,) arrays — steps never
retrace across decode lengths or batch compositions.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dist.flash import paged_update_and_attend
from repro.models import blocks
from repro.models.layers import apply_rope, cast_params, mlp, rmsnorm, _dtype
from repro.models.attention import gqa_qkv
from repro.models.model import LanguageModel


def make_prefill_step(model: LanguageModel):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: LanguageModel):
    def decode_step(params, cache, token, cur_len):
        return model.decode_step(params, cache, token, cur_len)
    return decode_step


# -------------------------------------------------------------- paged steps

def make_paged_prefill_step(model: LanguageModel, page_size: int):
    """Prefill one request straight into its pages.

    Returned step signature:
      step(params, k_pools, v_pools, tokens, plen, pages)
        tokens: (1, Spad) int32, right-padded — Spad must be a multiple of
          ``page_size`` and is a static bucket (one trace per bucket);
        plen: () int32 true prompt length (logits read position plen-1;
          pad positions write KV that stays masked behind ``cur_lens``);
        pages: (Spad//page_size,) int32 physical page ids for this request
          (unused tail entries point one past the pool and drop).
      -> (next_token () int32, logits (V,) f32, k_pools', v_pools')

    Dense-family GQA only — the engine's paged path; other families keep
    the contiguous-cache decode.
    """
    cfg = model.cfg
    if cfg.family not in ("dense", "vlm") or getattr(cfg, "use_mla", False):
        raise ValueError(f"paged serving supports dense GQA, not {cfg.family}")

    def step(params, k_pools, v_pools, tokens, plen, pages):
        x = jnp.take(params["embedding"], tokens, axis=0).astype(_dtype(cfg.dtype))
        positions = jnp.arange(tokens.shape[1])[None, :]

        def body(xx, p_l):
            return blocks.decoder_layer_prefill(p_l, xx, cfg, positions,
                                                "dense")

        x, cache = jax.lax.scan(body, x, params["layers"])
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        h_last = jax.lax.dynamic_index_in_dim(h[0], plen - 1, axis=0,
                                              keepdims=False)
        logits = jnp.einsum("d,dv->v", h_last,
                            model._unembed_weight(params).astype(h.dtype))
        # cache k/v: (L, 1, KH, Spad, hd) head-major -> page-major scatter
        nlayers, _, kh, spad, hd = cache["k"].shape
        npg = spad // page_size
        kc = cache["k"][:, 0].reshape(nlayers, kh, npg, page_size, hd)
        vc = cache["v"][:, 0].reshape(nlayers, kh, npg, page_size, hd)
        kc = jnp.transpose(kc, (0, 2, 1, 3, 4))
        vc = jnp.transpose(vc, (0, 2, 1, 3, 4))
        k_pools = k_pools.at[:, pages].set(kc.astype(k_pools.dtype),
                                           mode="drop")
        v_pools = v_pools.at[:, pages].set(vc.astype(v_pools.dtype),
                                           mode="drop")
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, logits.astype(jnp.float32), k_pools, v_pools

    return jax.jit(step)


def make_paged_decode_step(model: LanguageModel):
    """One continuous-batching decode step over the paged pools.

    Returned step signature:
      step(params, k_pools, v_pools, page_table, cur_lens, active, tokens)
        page_table: (B, max_pages) int32; cur_lens: (B,) int32 tokens
        already cached per row; active: (B,) bool; tokens: (B,) int32 last
        sampled token per row.
      -> (next_tokens (B,) int32, logits (B, V) f32, k_pools', v_pools',
          cur_lens')

    Every array is traced — the step compiles once per (B, max_pages)
    shape and the position state never round-trips through Python ints.
    """
    cfg = model.cfg
    if cfg.family not in ("dense", "vlm") or getattr(cfg, "use_mla", False):
        raise ValueError(f"paged serving supports dense GQA, not {cfg.family}")

    def step(params, k_pools, v_pools, page_table, cur_lens, active, tokens):
        x = jnp.take(params["embedding"], tokens[:, None],
                     axis=0).astype(_dtype(cfg.dtype))      # (B, 1, D)
        pos = cur_lens[:, None]                             # (B, 1) per row

        def body(xx, inp):
            p_l, kp, vp = inp
            p_l = cast_params(p_l, cfg.dtype)
            h = rmsnorm(p_l["ln1"], xx, cfg.norm_eps)
            q, k, v = gqa_qkv(p_l["attn"], h, cfg)
            q = apply_rope(q, pos, cfg.rope_theta)
            k = apply_rope(k, pos, cfg.rope_theta)
            out, kp, vp = paged_update_and_attend(
                q, k, v, kp, vp, page_table, cur_lens, active,
                window=cfg.sliding_window)
            xx = xx + jnp.einsum("bshk,hkd->bsd", out, p_l["attn"]["w_o"])
            h = rmsnorm(p_l["ln2"], xx, cfg.norm_eps)
            xx = xx + mlp(p_l["mlp"], h)
            return xx, (kp, vp)

        x, (k_pools, v_pools) = jax.lax.scan(
            body, x, (params["layers"], k_pools, v_pools))
        h = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = jnp.einsum("bd,dv->bv", h[:, 0],
                            model._unembed_weight(params).astype(h.dtype))
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        cur_new = cur_lens + active.astype(jnp.int32)
        return (next_tok, logits.astype(jnp.float32), k_pools, v_pools,
                cur_new)

    return jax.jit(step)
