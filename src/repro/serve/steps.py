"""Serving steps: prefill and single-token decode (the shapes the
``decode_*`` / ``long_*`` dry-run cells lower)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import LanguageModel


def make_prefill_step(model: LanguageModel):
    def prefill_step(params, batch):
        return model.prefill(params, batch)
    return prefill_step


def make_decode_step(model: LanguageModel):
    def decode_step(params, cache, token, cur_len):
        return model.decode_step(params, cache, token, cur_len)
    return decode_step
