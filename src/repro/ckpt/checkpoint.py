"""Chunked + §6-sharded checkpointing on the paper's §5 file-mapped blocks.

Layout of a checkpoint at ``<dir>/step_<N>/``:
  leaf_<i>.bin     one file per pytree leaf
  manifest.json    tree paths, shapes, dtypes, chunk/range tables, hashes

Two write paths share one manifest format:

* **Chunked (host leaves)** — a leaf without a device sharding is written
  as fixed-size disjoint chunks by parallel writer EDTs acquiring their
  chunk data blocks in EW mode; non-overlap is *enforced by the runtime*
  (§5 ``ocrFileGetChunk``), so a buggy writer cannot corrupt a neighbour.
* **Sharded (§6 ranges)** — a leaf carrying a ``NamedSharding`` is written
  as exactly the disjoint §6 byte ranges
  :func:`repro.dist.sharding.device_ranges_of` assigns to each device:
  one writer EDT per ``(node, offset, size)`` range, acquiring a §6
  *partition* of the node's file-mapped chunk in EW mode.  Bytes come
  from each device's own shard — **no host-side full-leaf gather**
  (``CkptStats.host_gathers`` stays 0), and adjacent ranges destroyed
  together coalesce into one IO-queue write-back op.

Shared properties:
* **Dirty-only** — when the previous checkpoint's manifest is supplied,
  chunks/ranges whose content hash is unchanged are skipped (§5: the
  runtime only writes back chunks that were actually modified).  A
  missing/corrupt previous manifest only disables the skip (warning),
  it never poisons the save.
* **Committed** — ``manifest.json`` is written last via atomic rename; a
  crash mid-save (``crash_at``, fail-stop, or a real crash) leaves the
  previous checkpoint intact (``latest_step`` only counts manifests and
  ``step_*.tmp`` directories are ignored).
* **Elastic / reshard-on-restore** — restore reassembles global arrays
  from the range tables regardless of writer count or mesh shape, so a
  run saved on an 8-device mesh can resume on 2, 1, or a pure-dp mesh;
  pass ``shardings=`` to place the restored leaves directly.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import warnings
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import DbMode, NULL_GUID, Runtime, spawn_main
from repro.monitoring import Registry

# legacy CkptStats field → its ckpt.* monitoring-registry slot and zero
_CKPT_FIELDS: Tuple[Tuple[str, str, Any], ...] = (
    ("chunks_total", "ckpt.chunks_total", 0),
    ("chunks_written", "ckpt.chunks_written", 0),
    ("chunks_skipped", "ckpt.chunks_skipped", 0),
    ("bytes_written", "ckpt.bytes_written", 0),
    # host-side full-leaf gathers of device-sharded arrays (the sharded
    # §6 path never performs one; the acceptance gate asserts 0)
    ("host_gathers", "ckpt.host_gathers", 0),
    # False when the save was halted (crash_at) before the manifest commit
    ("committed", "ckpt.committed", True),
    # §5 IO-queue counters of the save's runtime (virtual time)
    ("io_write_ops", "ckpt.io_write_ops", 0),
    ("io_coalesced_writes", "ckpt.io_coalesced_writes", 0),
    ("makespan", "ckpt.makespan", 0.0),
)


class CkptStats:
    """Field-compatible view over the ``ckpt.*`` registry namespace.

    Same refactor as ``core.runtime.Stats``: the former dataclass fields
    are properties onto dotted monitoring-registry slots.  ``save``
    binds the instance to the save-runtime's registry, so one mid-run
    ``Registry.snapshot()`` shows the checkpoint gauges next to the same
    run's ``io.*`` counters; standalone construction keeps a private
    registry (old dataclass behaviour).
    """

    __slots__ = ("registry",)

    def __init__(self, registry: Optional[Registry] = None):
        self.registry = Registry() if registry is None else registry
        declare = self.registry.declare
        for _field, name, default in _CKPT_FIELDS:
            declare(name, default)

    def snapshot(self) -> Dict[str, Any]:
        vals = self.registry._values
        return {field: vals[name] for field, name, _default in _CKPT_FIELDS}

    def __repr__(self) -> str:
        body = ", ".join(f"{k}={v!r}" for k, v in self.snapshot().items())
        return f"CkptStats({body})"


def _ckpt_property(name: str) -> property:
    def _get(self: CkptStats) -> Any:
        return self.registry._values[name]

    def _set(self: CkptStats, value: Any) -> None:
        self.registry._values[name] = value

    return property(_get, _set)


for _field, _name, _default in _CKPT_FIELDS:
    setattr(CkptStats, _field, _ckpt_property(_name))
del _field, _name, _default


def _flatten(tree: Any, prefix: str = "") -> List[Tuple[str, Any]]:
    """Leaves in sorted key-path order — *without* materializing them."""
    out: List[Tuple[str, Any]] = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}/{k}" if prefix else str(k)))
    else:
        out.append((prefix, tree))
    return out


def _unflatten(items: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for path, val in items.items():
        keys = path.split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = val
    return root


def _chunk_table(nbytes: int, chunk_bytes: int) -> List[Tuple[int, int]]:
    out = []
    off = 0
    while off < nbytes:
        size = min(chunk_bytes, nbytes - off)
        out.append((off, size))
        off += size
    return out or [(0, 0)]


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp") and \
                os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def _to_host(leaf: Any, stats: CkptStats) -> np.ndarray:
    """Materialize one full leaf on host, counting real device gathers."""
    shards = getattr(leaf, "addressable_shards", None)
    if shards is not None and len(shards) > 1:
        stats.host_gathers += 1
    return np.asarray(leaf)


def _load_prev_manifest(ckpt_dir: str) -> Tuple[Optional[str], Dict[str, Any]]:
    """Previous manifest for dirty-range skipping — fail-soft.

    A crashed or corrupt previous save (missing/garbled ``manifest.json``)
    must not poison later saves: dirty tracking is skipped with a warning
    and the save proceeds as a full write.
    """
    prev = latest_step(ckpt_dir)
    if prev is None:
        return None, {}
    prev_dir = os.path.join(ckpt_dir, f"step_{prev}")
    try:
        with open(os.path.join(prev_dir, "manifest.json")) as f:
            pm = json.load(f)
        prev_leaves = {l["path"]: l for l in pm["leaves"]}
    except (OSError, ValueError, KeyError, TypeError) as e:
        warnings.warn(
            f"checkpoint: previous manifest at {prev_dir} is unreadable "
            f"({type(e).__name__}: {e}); dirty-range skipping disabled "
            f"for this save")
        return None, {}
    return prev_dir, prev_leaves


# ------------------------------------------------------------ §6 range plans

@dataclasses.dataclass
class _RangePlan:
    """Write plan for one leaf: disjoint ranges, each owned by one node."""

    table: List[Tuple[int, int, int]]        # (node, offset, size)
    payloads: Dict[int, bytes]               # offset -> bytes to write
    sharded: bool


def _plan_sharded(leaf: Any, num_writers: int) -> Optional[_RangePlan]:
    """§6 plan for a ``NamedSharding``-carrying array; None for host leaves.

    Every distinct byte range is owned by the node of the *first* device
    holding it (replicas skip); payload bytes come from that device's own
    shard, never from a full-leaf gather.
    """
    sharding = getattr(leaf, "sharding", None)
    shards = getattr(leaf, "addressable_shards", None)
    if sharding is None or shards is None or not hasattr(sharding, "mesh"):
        return None
    from repro.dist.sharding import device_ranges_of
    per_dev = device_ranges_of(leaf.shape, leaf.dtype.itemsize, sharding)
    by_device = {s.device: s for s in shards}
    seen: set = set()
    table: List[Tuple[int, int, int]] = []
    payloads: Dict[int, bytes] = {}
    for dev_idx, (dev, ranges) in enumerate(per_dev):
        fresh = [(i, r) for i, r in enumerate(ranges) if r not in seen]
        if not fresh:
            continue                      # pure replica of earlier devices
        shard = by_device.get(dev)
        if shard is None:                 # non-addressable device (multihost)
            continue
        raw = np.asarray(shard.data).tobytes()
        node = dev_idx % num_writers
        for i, (off, size) in fresh:
            seen.add((off, size))
            # a shard's bytes split into equal run-sized pieces matching
            # its ranges in order (device_ranges_of emission order)
            payloads[off] = raw[i * size: (i + 1) * size]
            table.append((node, off, size))
    table.sort(key=lambda t: t[1])
    return _RangePlan(table=table, payloads=payloads, sharded=True)


def _plan_chunked(arr: np.ndarray, chunk_bytes: int,
                  num_writers: int) -> _RangePlan:
    """Fixed-size chunk plan for a host leaf.

    Chunks are assigned to writer nodes in contiguous blocks (not
    round-robin) so each node's dirty ranges are adjacent and its
    write-backs coalesce into one IO-queue op per node.
    """
    raw = arr.tobytes()
    chunks = [(off, size)
              for off, size in _chunk_table(arr.nbytes, chunk_bytes)
              if size > 0]
    table = []
    payloads = {}
    for ci, (off, size) in enumerate(chunks):
        table.append((ci * num_writers // len(chunks), off, size))
        payloads[off] = raw[off: off + size]
    return _RangePlan(table=table, payloads=payloads, sharded=False)


def _node_spans(ranges: Sequence[Tuple[int, int]]
                ) -> List[Tuple[int, int, List[Tuple[int, int]]]]:
    """Group sorted disjoint ranges into maximal contiguous spans."""
    spans: List[Tuple[int, int, List[Tuple[int, int]]]] = []
    for off, size in sorted(ranges):
        if spans and off == spans[-1][0] + spans[-1][1]:
            start, length, members = spans.pop()
            spans.append((start, length + size, members + [(off, size)]))
        else:
            spans.append((off, size, [(off, size)]))
    return spans


# ------------------------------------------------------------------- save

def save(ckpt_dir: str, state: Any, step: int, *, chunk_bytes: int = 1 << 22,
         num_writers: int = 4, dirty_skip: bool = True,
         io_latency: float = 1.0, io_mode: str = "async",
         crash_at: Optional[float] = None) -> CkptStats:
    """Write a checkpoint through §5 file-mapped blocks / §6 partitions.

    Leaves carrying a ``NamedSharding`` (jax arrays under a mesh) take the
    sharded path: each node writes exactly its own §6 byte ranges through
    EW partitions of the leaf's file-mapped chunk.  Host leaves take the
    fixed-size chunk path.  ``crash_at`` halts the save's runtime at that
    virtual time *before* the manifest commit (crash-consistency tests):
    the returned stats have ``committed=False`` and the ``step_N.tmp``
    directory is left behind, which ``latest_step``/``restore`` ignore.
    """
    leaves = _flatten(state)
    out_dir = os.path.join(ckpt_dir, f"step_{step}")
    tmp_dir = out_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)

    prev_dir: Optional[str] = None
    prev_leaves: Dict[str, Any] = {}
    if dirty_skip:
        prev_dir, prev_leaves = _load_prev_manifest(ckpt_dir)

    manifest: Dict[str, Any] = {
        "step": step, "chunk_bytes": chunk_bytes, "leaves": []}

    rt = Runtime(num_nodes=num_writers, io_latency=io_latency,
                 io_mode=io_mode)
    # the save's stats share the save-runtime's registry: ckpt.* gauges
    # land next to its io.* counters in one snapshot namespace
    stats = CkptStats(rt.registry)

    # (leaf_idx, offset) -> payload bytes, consulted by writer EDT bodies
    pending_payloads: Dict[Tuple[int, int], bytes] = {}
    pending_files: List[Tuple[str, str]] = []
    plans: List[Tuple[int, str, _RangePlan, List[str]]] = []

    for li, (path, leaf) in enumerate(leaves):
        plan = _plan_sharded(leaf, num_writers)
        if plan is None:
            arr = _to_host(leaf, stats)
            plan = _plan_chunked(arr, chunk_bytes, num_writers)
            shape, dtype, nbytes = list(arr.shape), str(arr.dtype), arr.nbytes
        else:
            shape, dtype = list(leaf.shape), str(leaf.dtype)
            nbytes = int(np.prod(leaf.shape)) * leaf.dtype.itemsize
        hashes = [hashlib.sha1(plan.payloads[off]).hexdigest()
                  for (_n, off, _s) in plan.table]
        fname = f"leaf_{li}.bin"
        entry = {
            "path": path, "file": fname, "shape": shape, "dtype": dtype,
            "nbytes": nbytes,
            "chunks": [[off, size] for (_n, off, size) in plan.table],
            "chunk_hashes": hashes,
        }
        if plan.sharded:
            entry["ranges"] = [[n, off, size] for (n, off, size) in plan.table]
        manifest["leaves"].append(entry)
        stats.chunks_total += len(plan.table)

        # dirty-range skipping: only against an identical table layout
        prev_entry = prev_leaves.get(path)
        prev_hashes: Optional[List[str]] = None
        if prev_entry is not None and prev_dir is not None and \
                [list(c) for c in prev_entry.get("chunks", [])] == \
                entry["chunks"]:
            prev_hashes = prev_entry.get("chunk_hashes")
        if prev_hashes == hashes and prev_hashes is not None:
            # §5 dirty tracking: nothing modified → reuse previous file
            stats.chunks_skipped += len(plan.table)
            pending_files.append((os.path.join(prev_dir, fname),
                                  os.path.join(tmp_dir, fname)))
            continue
        clean: List[bool] = [False] * len(plan.table)
        if prev_hashes is not None:
            # copy-forward unchanged ranges from the previous file; they
            # still go through a writer (the new file must be complete)
            # but do not count as dirty.  Seek-read only those ranges —
            # never the whole previous file.
            with open(os.path.join(prev_dir, fname), "rb") as f:
                for i, (_n, off, size) in enumerate(plan.table):
                    if i < len(prev_hashes) and prev_hashes[i] == hashes[i]:
                        f.seek(off)
                        plan.payloads[off] = f.read(size)
                        clean[i] = True
        for i, (_n, off, size) in enumerate(plan.table):
            key = (li, off)
            pending_payloads[key] = plan.payloads[off]
            if clean[i]:
                stats.chunks_skipped += 1
            else:
                stats.chunks_written += 1
                stats.bytes_written += size
        plans.append((li, os.path.join(tmp_dir, fname), plan))

    def writer(paramv, depv, api):
        (li, off, size) = paramv
        data = pending_payloads[(li, off)]
        depv[0].ptr[:size] = np.frombuffer(data, dtype=np.uint8)
        api.db_destroy(depv[0].guid)   # EW write-back happens here (§5)
        return NULL_GUID

    def opener(paramv, depv, api):
        """Per-(leaf, node) §6 writer fan-out, running *on* that node.

        Maps the node's contiguous spans as file chunks, partitions each
        span into the node's individual §6 ranges, and hangs one EW
        writer EDT off every partition — so each node writes exactly its
        own byte ranges, and adjacent ranges coalesce at write-back.
        """
        (li, node, ranges) = paramv
        fg = api.file_get_guid(depv[0].ptr)
        wt = api.edt_template_create(writer, 3, 1)
        for (span_off, span_size, members) in _node_spans(ranges):
            chunk = api.file_get_chunk(fg, span_off, span_size,
                                       write_only=True)
            parts = api.db_partition(
                chunk, [(off - span_off, size) for (off, size) in members])
            for part, (off, size) in zip(parts, members):
                api.edt_create(wt, paramv=[li, off, size], depv=[part],
                               dep_modes=[DbMode.EW], placement=node)
            api.db_destroy(chunk)      # deferred until partitions retire
        api.file_release(fg)
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        ot = api.edt_template_create(opener, 3, 1)
        for li, fpath, plan in plans:
            if not plan.table:
                with open(fpath, "wb"):
                    pass               # empty leaf: just create the file
                continue
            by_node: Dict[int, List[Tuple[int, int]]] = {}
            for (node, off, size) in plan.table:
                by_node.setdefault(node, []).append((off, size))
            for node, ranges in sorted(by_node.items()):
                fg, desc = api.file_open(fpath, "wb+")
                api.edt_create(ot, paramv=[li, node, ranges], depv=[desc],
                               placement=node)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run(until=crash_at)
    if crash_at is not None and not rt.quiescent():
        # simulated crash mid-flush: in-flight IO-queue writes are lost
        # and the manifest is never committed — step_N.tmp is dead weight
        stats.committed = False
        stats.io_write_ops = rt.stats.io_write_ops
        stats.io_coalesced_writes = rt.stats.io_coalesced_writes
        stats.makespan = rt.stats.makespan
        return stats

    for src, dst in pending_files:
        if os.path.abspath(src) != os.path.abspath(dst):
            with open(src, "rb") as f_in, open(dst, "wb") as f_out:
                f_out.write(f_in.read())

    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(out_dir):
        import shutil
        shutil.rmtree(out_dir)
    os.rename(tmp_dir, out_dir)          # commit point
    stats.io_write_ops = rt.stats.io_write_ops
    stats.io_coalesced_writes = rt.stats.io_coalesced_writes
    stats.makespan = rt.stats.makespan
    return stats


# ------------------------------------------------------------ cost model

def io_cost(shapes: Any, shardings: Any, *, io_latency: float = 1.0,
            num_writers: Optional[int] = None) -> Dict[str, float]:
    """Model a sharded checkpoint write under the §5 latency model.

    Pure arithmetic — no save runs.  Lowers every leaf to its §6 ranges
    (:func:`repro.dist.sharding.device_ranges_of`), dedups replicas,
    assigns ranges to writer nodes, coalesces each node's adjacent
    ranges, and charges ``io_latency`` per post-coalescing op on per-node
    disks: the virtual write time is the busiest node's op count × the
    latency.  ``launch.dryrun`` folds this into its roofline record so
    checkpoint IO is costed from the same model the runtime charges.
    """
    from repro.dist.sharding import device_ranges_of
    shape_leaves = _flatten(shapes)
    sh_by_path = dict(_flatten(shardings))
    ranges_total = 0
    bytes_total = 0
    ops_per_node: Dict[int, int] = {}
    for path, leaf in shape_leaves:
        sharding = sh_by_path.get(path)
        if sharding is None or not hasattr(sharding, "mesh"):
            continue
        if num_writers is None:
            num_writers = int(sharding.mesh.size)
        itemsize = np.dtype(leaf.dtype).itemsize
        per_dev = device_ranges_of(leaf.shape, itemsize, sharding)
        seen: set = set()
        by_node: Dict[int, List[Tuple[int, int]]] = {}
        for dev_idx, (_dev, ranges) in enumerate(per_dev):
            fresh = [r for r in ranges if r not in seen]
            if not fresh:
                continue
            seen.update(fresh)
            by_node.setdefault(dev_idx % num_writers, []).extend(fresh)
        for node, ranges in by_node.items():
            ranges_total += len(ranges)
            bytes_total += sum(s for _o, s in ranges)
            ops_per_node[node] = ops_per_node.get(node, 0) \
                + len(_node_spans(ranges))
    ops = sum(ops_per_node.values())
    return {
        "ranges": ranges_total,
        "io_write_ops": ops,
        "io_coalesced_writes": ranges_total - ops,
        "bytes": bytes_total,
        "nodes": len(ops_per_node),
        "write_time_virtual": (max(ops_per_node.values()) * io_latency
                               if ops_per_node else 0.0),
    }


# ------------------------------------------------------------- async save

class _SaveHandle:
    """Join-able result of :func:`async_save` (thread-API compatible)."""

    def __init__(self, stats: CkptStats):
        self.stats = stats

    def join(self, timeout: Optional[float] = None) -> None:
        return None

    def is_alive(self) -> bool:
        return False


def async_save(ckpt_dir: str, state: Any, step: int, **kw) -> _SaveHandle:
    """Issue-now/resolve-later (§3) save through the §5 IO queue.

    Mutable host leaves are snapshot at issue time (device arrays are
    immutable and pass through untouched — no gather), then the write
    rides the runtime's asynchronous IO queue: overlap is modeled by the
    latency-charged subsystem itself rather than an ad-hoc host thread.
    Note the *wall-clock* call is synchronous — the returned handle is
    already complete and ``join()`` is a no-op kept for API parity.
    """
    snap = {p: (np.array(a, copy=True) if isinstance(a, np.ndarray)
                else a)
            for p, a in _flatten(state)}
    return _SaveHandle(save(ckpt_dir, _unflatten(snap), step, **kw))


# ---------------------------------------------------------------- restore

def restore(ckpt_dir: str, step: Optional[int] = None,
            num_readers: int = 4, io_latency: float = 1.0,
            shardings: Any = None) -> Tuple[Any, int]:
    """Reassemble the checkpoint tree (elastic: any reader count or mesh).

    The §6 range manifest lets any mesh shape restore from any other:
    ranges are read back as §5 chunks and reassembled into full leaves;
    pass ``shardings`` (a pytree of ``NamedSharding`` matching the saved
    tree) to place each leaf directly onto a — possibly different — mesh.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    items: Dict[str, Any] = {}
    rt = Runtime(num_nodes=num_readers, io_latency=io_latency)
    buffers: Dict[int, bytearray] = {}

    def reader(paramv, depv, api):
        (li, off, size) = paramv
        buffers[li][off: off + size] = bytes(depv[0].ptr[:size])
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def after_open(paramv, depv, api):
        # §5 pattern: runs only once the descriptor DB is satisfied
        li = paramv[0]
        leaf = manifest["leaves"][li]
        fg = api.file_get_guid(depv[0].ptr)
        tmpl = api.edt_template_create(reader, 3, 1)
        for ci, (off, size) in enumerate(leaf["chunks"]):
            if size == 0:
                continue
            chunk = api.file_get_chunk(fg, off, size)
            api.edt_create(tmpl, paramv=[li, off, size], depv=[chunk],
                           dep_modes=[DbMode.RO],
                           placement=ci % num_readers)
        api.file_release(fg)
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        otmpl = api.edt_template_create(after_open, 1, 1)
        for li, leaf in enumerate(manifest["leaves"]):
            buffers[li] = bytearray(leaf["nbytes"])
            if leaf["nbytes"] == 0:
                continue
            _, desc = api.file_open(os.path.join(d, leaf["file"]), "rb")
            api.edt_create(otmpl, paramv=[li], depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()

    sh_by_path: Dict[str, Any] = {}
    if shardings is not None:
        sh_by_path = dict(_flatten(shardings))
    for li, leaf in enumerate(manifest["leaves"]):
        arr = np.frombuffer(bytes(buffers[li]),
                            dtype=np.dtype(leaf["dtype"]))
        arr = arr.reshape(leaf["shape"])
        sh = sh_by_path.get(leaf["path"])
        if sh is not None:
            import jax
            arr = jax.device_put(arr, sh)
        items[leaf["path"]] = arr
    return _unflatten(items), manifest["step"]
