"""Chunked sharded checkpointing on the paper's §5 file-mapped data blocks.

Layout of a checkpoint at ``<dir>/step_<N>/``:
  leaf_<i>.bin     one file per pytree leaf
  manifest.json    tree paths, shapes, dtypes, chunk tables, content hashes

Properties:
* **Chunked** — every leaf is written as disjoint (offset, size) chunks by
  parallel writer EDTs acquiring their chunk data blocks in EW mode;
  non-overlap is *enforced by the runtime* (§5 ``ocrFileGetChunk``), so a
  buggy writer cannot corrupt a neighbour's range.
* **Dirty-only** — when the previous checkpoint's manifest is supplied,
  chunks whose content hash is unchanged are skipped (§5: the runtime only
  writes back chunks that were actually modified).
* **Committed** — ``manifest.json`` is written last via atomic rename; a
  crash mid-save leaves the previous checkpoint intact (``latest_step``
  only counts manifests).
* **Elastic** — restore reassembles global arrays from chunk tables
  regardless of the writer count, so a run may resume on a different mesh.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core import DbMode, NULL_GUID, Runtime, spawn_main


@dataclasses.dataclass
class CkptStats:
    chunks_total: int = 0
    chunks_written: int = 0
    chunks_skipped: int = 0
    bytes_written: int = 0


def _flatten(tree: Any, prefix: str = "") -> List[Tuple[str, np.ndarray]]:
    out: List[Tuple[str, np.ndarray]] = []
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.extend(_flatten(tree[k], f"{prefix}/{k}" if prefix else str(k)))
    else:
        out.append((prefix, np.asarray(tree)))
    return out


def _unflatten(items: Dict[str, np.ndarray]) -> Any:
    root: Dict[str, Any] = {}
    for path, val in items.items():
        keys = path.split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = val
    return root


def _chunk_table(nbytes: int, chunk_bytes: int) -> List[Tuple[int, int]]:
    out = []
    off = 0
    while off < nbytes:
        size = min(chunk_bytes, nbytes - off)
        out.append((off, size))
        off += size
    return out or [(0, 0)]


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and \
                os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def save(ckpt_dir: str, state: Any, step: int, *, chunk_bytes: int = 1 << 22,
         num_writers: int = 4, dirty_skip: bool = True) -> CkptStats:
    """Write a checkpoint through §5 file-mapped chunk data blocks."""
    leaves = _flatten(state)
    out_dir = os.path.join(ckpt_dir, f"step_{step}")
    tmp_dir = out_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    stats = CkptStats()

    # previous manifest for dirty-chunk skipping
    prev_hashes: Dict[str, List[str]] = {}
    prev_dir = None
    if dirty_skip:
        prev = latest_step(ckpt_dir)
        if prev is not None:
            prev_dir = os.path.join(ckpt_dir, f"step_{prev}")
            with open(os.path.join(prev_dir, "manifest.json")) as f:
                pm = json.load(f)
            if pm.get("chunk_bytes") == chunk_bytes:
                prev_hashes = {l["path"]: l["chunk_hashes"]
                               for l in pm["leaves"]}

    manifest: Dict[str, Any] = {
        "step": step, "chunk_bytes": chunk_bytes, "leaves": []}

    rt = Runtime(num_nodes=num_writers)

    def writer(paramv, depv, api):
        (leaf_idx, off, size) = paramv
        _, arr = leaves[leaf_idx]
        raw = arr.tobytes()
        depv[0].ptr[:size] = np.frombuffer(raw[off: off + size], dtype=np.uint8)
        api.db_destroy(depv[0].guid)   # EW write-back happens here (§5)
        return NULL_GUID

    pending_files = []

    def main(paramv, depv, api):
        wt = api.edt_template_create(writer, 3, 1)
        for li, (path, arr) in enumerate(leaves):
            nbytes = arr.nbytes
            fname = f"leaf_{li}.bin"
            fpath = os.path.join(tmp_dir, fname)
            table = _chunk_table(nbytes, chunk_bytes)
            raw = arr.tobytes()
            hashes = [hashlib.sha1(raw[o: o + s]).hexdigest()
                      for (o, s) in table]
            manifest["leaves"].append({
                "path": path, "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype), "nbytes": nbytes,
                "chunks": table, "chunk_hashes": hashes})
            stats.chunks_total += len(table)

            unchanged = prev_hashes.get(path)
            all_skip = (unchanged == hashes and prev_dir is not None)
            if all_skip:
                # §5 dirty tracking: nothing modified → reuse previous file
                stats.chunks_skipped += len(table)
                pending_files.append((os.path.join(prev_dir, fname), fpath))
                continue

            fg, _desc = api.file_open(fpath, "wb+")
            if nbytes == 0:
                api.file_release(fg)
                continue
            for ci, (off, size) in enumerate(table):
                if unchanged and ci < len(unchanged) and \
                        unchanged[ci] == hashes[ci] and prev_dir is not None:
                    # copy-forward unchanged chunk from the previous file
                    with open(os.path.join(prev_dir, fname), "rb") as f:
                        f.seek(off)
                        data = f.read(size)
                    chunk = api.file_get_chunk(fg, off, size)
                    db = api.rt.lookup(chunk)
                    api.rt._materialize(db)[:size] = np.frombuffer(
                        data, dtype=np.uint8)
                    db.dirty = True
                    api.db_destroy(chunk)
                    stats.chunks_skipped += 1
                    continue
                chunk = api.file_get_chunk(fg, off, size)
                api.edt_create(wt, paramv=[li, off, size], depv=[chunk],
                               dep_modes=[DbMode.EW],
                               placement=ci % num_writers)
                stats.chunks_written += 1
                stats.bytes_written += size
            api.file_release(fg)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()

    for src, dst in pending_files:
        if os.path.abspath(src) != os.path.abspath(dst):
            with open(src, "rb") as f_in, open(dst, "wb") as f_out:
                f_out.write(f_in.read())

    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(out_dir):
        import shutil
        shutil.rmtree(out_dir)
    os.rename(tmp_dir, out_dir)          # commit point
    return stats


def async_save(ckpt_dir: str, state: Any, step: int, **kw) -> threading.Thread:
    """Issue-now/resolve-later (§3): snapshot to host and write off-thread."""
    snap = [(p, np.array(a, copy=True)) for p, a in _flatten(state)]
    tree = _unflatten(dict(snap))
    t = threading.Thread(target=save, args=(ckpt_dir, tree, step), kwargs=kw)
    t.start()
    return t


def restore(ckpt_dir: str, step: Optional[int] = None,
            num_readers: int = 4) -> Tuple[Any, int]:
    """Reassemble the checkpoint tree (elastic: any reader count)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    items: Dict[str, np.ndarray] = {}
    rt = Runtime(num_nodes=num_readers)
    buffers: Dict[int, bytearray] = {}

    def reader(paramv, depv, api):
        (li, off, size) = paramv
        buffers[li][off: off + size] = bytes(depv[0].ptr[:size])
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def after_open(paramv, depv, api):
        # §5 pattern: runs only once the descriptor DB is satisfied
        li = paramv[0]
        leaf = manifest["leaves"][li]
        fg = api.file_get_guid(depv[0].ptr)
        tmpl = api.edt_template_create(reader, 3, 1)
        for ci, (off, size) in enumerate(leaf["chunks"]):
            chunk = api.file_get_chunk(fg, off, size)
            api.edt_create(tmpl, paramv=[li, off, size], depv=[chunk],
                           dep_modes=[DbMode.RO],
                           placement=ci % num_readers)
        api.file_release(fg)
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        otmpl = api.edt_template_create(after_open, 1, 1)
        for li, leaf in enumerate(manifest["leaves"]):
            buffers[li] = bytearray(leaf["nbytes"])
            if leaf["nbytes"] == 0:
                continue
            _, desc = api.file_open(os.path.join(d, leaf["file"]), "rb")
            api.edt_create(otmpl, paramv=[li], depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()

    for li, leaf in enumerate(manifest["leaves"]):
        arr = np.frombuffer(bytes(buffers[li]),
                            dtype=np.dtype(leaf["dtype"]))
        items[leaf["path"]] = arr.reshape(leaf["shape"])
    return _unflatten(items), manifest["step"]
