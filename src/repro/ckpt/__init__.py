from .checkpoint import save, restore, async_save, latest_step, CkptStats
