from .checkpoint import (CkptStats, async_save, io_cost, latest_step,
                         restore, save)
