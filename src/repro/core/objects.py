"""Runtime object model: events, EDTs, templates, data blocks, maps, files.

Data blocks carry the §6 partitioning state (parent / live partitions /
static flag) and the §5 file binding (file guid + offset + dirty bit).
Locking state implements the acquire-mode semantics that make partitioning
observable: RO/CONST are shared, RW/EW are exclusive *per data block* — so
two tasks in EW on two disjoint partitions run in parallel while the same
two tasks in RW on the whole parent serialize.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

import numpy as np

from .guid import (DbMode, EventKind, GUID_SHARD_BITS, Guid, Lid, NULL_GUID,
                   ObjectKind)

UNSET = object()  # pre-slot not yet satisfied
_MISSING = object()


class OcrError(RuntimeError):
    pass


class PartitionOverlapError(OcrError):
    pass


class PartitionDeadlockError(OcrError):
    pass


class PartitionStaticError(OcrError):
    pass


class ChunkOverlapError(OcrError):
    pass


class FileModeError(OcrError):
    pass


class _Shard:
    """One ``(kind, seq-range)`` shard of a node's GUID table.

    ``objs`` keys by the bare ``seq`` int: within a per-node, per-kind table
    a Guid's seq is unique, so probes never hash or compare full Guid
    triples — int keys keep every dict operation at C level.  ``destroyed``
    counts objects removed from this shard over its lifetime; ``spilled``
    counts members whose buffers currently live in the node's spill file;
    ``tombstones`` counts fired ONCE-event tombstones still parked in
    ``objs`` (see :meth:`ObjectTable.retire_event_shards`).
    """

    __slots__ = ("objs", "destroyed", "spilled", "tombstones")

    def __init__(self) -> None:
        self.objs: Dict[int, Any] = {}
        self.destroyed = 0
        self.spilled = 0
        self.tombstones = 0

    def hot(self) -> bool:
        """A shard is hot while it holds any buffer-resident live object."""
        return len(self.objs) > self.spilled


class ObjectTable:
    """Per-node GUID table, sharded by ``(ObjectKind, seq-range)``.

    The paper's GUIDs encode creation-time structure (§2) precisely so the
    runtime can exploit it; this table is that exploitation on the storage
    side.  Routing is O(1) arithmetic on fields the :class:`Guid` already
    carries — ``kind`` picks the kind map, ``seq >> shard_bits`` picks the
    shard — so lookups avoid both the Guid tuple hash and the Python-level
    ``Guid.__eq__`` a flat ``Dict[Guid, Any]`` pays on every probe of a
    message-decoded (non-identical) identifier.  Hot working sets stay in
    a handful of small int-keyed dicts instead of scattering across one
    multi-million-entry map, empty shards are reclaimed wholesale, and a
    fail-stop drops the whole table in O(shards), not O(objects).

    Per-shard live (``len(shard.objs)``) / ``destroyed`` / ``spilled``
    counts drive the ``Stats.table_shards`` / ``table_hot_shards`` /
    ``spilled_objects`` gauges and the cold-object spill policy
    (``Runtime(spill_threshold=…)``).
    """

    __slots__ = ("_kinds", "_bits", "_destroyed_dropped", "_retired_events")

    def __init__(self, shard_bits: int = GUID_SHARD_BITS) -> None:
        self._bits = shard_bits
        self._kinds: Dict[ObjectKind, Dict[int, _Shard]] = \
            {k: {} for k in ObjectKind}
        # destroyed counts of shards already reclaimed, aggregated per kind
        self._destroyed_dropped: Dict[ObjectKind, int] = \
            {k: 0 for k in ObjectKind}
        # retired ONCE-event shards compacted to {shard idx: {seq: (guid,
        # payload)}}; a late dependence on a retired event synthesizes its
        # tombstone from this alone (see retire_event_shards)
        self._retired_events: Dict[int, Dict[int, Tuple[Guid, Any]]] = {}

    @property
    def shard_bits(self) -> int:
        return self._bits

    # ------------------------------------------------------------ hot path

    def insert(self, obj: Any) -> None:
        """Insert ``obj`` under ``obj.guid`` (every runtime object has one)."""
        gid = obj.guid
        seq = gid.seq
        shards = self._kinds[gid.kind]
        idx = seq >> self._bits
        sh = shards.get(idx)
        if sh is None:
            sh = shards[idx] = _Shard()
        sh.objs[seq] = obj

    def get(self, gid: Guid, default: Any = None) -> Any:
        seq = gid.seq
        try:
            obj = self._kinds[gid.kind][seq >> self._bits].objs.get(seq, _MISSING)
        except (KeyError, AttributeError):
            # unknown shard, or a non-Guid probe (e.g. an unresolved Lid)
            # — same "not found" answer the flat dict gave
            obj = _MISSING
        if obj is not _MISSING:
            return obj
        if self._retired_events and gid.__class__ is Guid \
                and gid.kind is ObjectKind.EVENT:
            obj = self._retired_hit(seq)
            if obj is not _MISSING:
                return obj
        return default

    def _retired_hit(self, seq: int, remove: bool = False) -> Any:
        """Synthesize the tombstone of a retired ONCE event (or _MISSING)."""
        idx = seq >> self._bits
        r = self._retired_events.get(idx)
        if r is None or seq not in r:
            return _MISSING
        guid, payload = r.pop(seq) if remove else r[seq]
        if remove and not r:
            del self._retired_events[idx]
        return EventObj(guid, EventKind.ONCE,
                        satisfied=True, payload=payload, destroyed=True)

    def pop(self, gid: Guid, default: Any = None) -> Any:
        try:
            seq = gid.seq
            shards = self._kinds[gid.kind]
            idx = seq >> self._bits
            sh = shards[idx]
            obj = sh.objs.pop(seq)
        except (KeyError, AttributeError):
            if self._retired_events and gid.__class__ is Guid \
                    and gid.kind is ObjectKind.EVENT:
                obj = self._retired_hit(gid.seq, remove=True)
                if obj is not _MISSING:
                    return obj   # already counted destroyed at retirement
            return default
        sh.destroyed += 1
        if not sh.objs:
            # reclaim the empty shard; its destroyed count survives in the
            # per-kind aggregate
            self._destroyed_dropped[gid.kind] += sh.destroyed
            del shards[idx]
        return obj

    # ----------------------------------------------------- dict-compat API

    def __getitem__(self, gid: Guid) -> Any:
        obj = self.get(gid, _MISSING)
        if obj is _MISSING:
            raise KeyError(gid)
        return obj

    def __setitem__(self, gid: Guid, obj: Any) -> None:
        self.insert(obj)

    def __contains__(self, gid: Guid) -> bool:
        return self.get(gid, _MISSING) is not _MISSING

    def __len__(self) -> int:
        return sum(len(sh.objs) for shards in self._kinds.values()
                   for sh in shards.values())

    def values(self) -> Iterator[Any]:
        for shards in self._kinds.values():
            for idx in sorted(shards):
                yield from shards[idx].objs.values()

    def items(self) -> Iterator[Tuple[Guid, Any]]:
        for obj in self.values():
            yield obj.guid, obj

    def __iter__(self) -> Iterator[Guid]:
        for obj in self.values():
            yield obj.guid

    def clear(self) -> None:
        """Drop every shard wholesale (fail-stop: O(shards), not O(objects))."""
        for kind, shards in self._kinds.items():
            for sh in shards.values():
                self._destroyed_dropped[kind] += sh.destroyed + len(sh.objs)
            shards.clear()
        # retired entries were already counted destroyed at retirement
        self._retired_events.clear()

    # ------------------------------------------------- shard introspection

    def shards(self, kind: ObjectKind) -> List[Tuple[int, _Shard]]:
        """Live shards of ``kind`` in ascending seq-range order (oldest
        first — the cold end the spill policy scans from)."""
        shards = self._kinds[kind]
        return [(idx, shards[idx]) for idx in sorted(shards)]

    def shard_count(self) -> int:
        return sum(len(shards) for shards in self._kinds.values())

    def hot_shard_count(self) -> int:
        """Data-block shards still holding ≥1 buffer-resident block.

        Only DATABLOCK shards are counted: other kinds hold no buffers,
        so "hot" (= spill has not drained it) is meaningless for them —
        counting them would make ``Stats.table_hot_shards`` track shard
        population instead of memory residency.
        """
        return sum(1 for sh in self._kinds[ObjectKind.DATABLOCK].values()
                   if sh.hot())

    def live_count(self, kind: ObjectKind) -> int:
        """Live objects of ``kind`` (O(shards of that kind), not O(1) —
        callers poll it per spill check, not per table op)."""
        return sum(len(sh.objs) for sh in self._kinds[kind].values())

    def destroyed_count(self, kind: ObjectKind) -> int:
        """Objects of ``kind`` destroyed over the table's lifetime
        (including those whose shard was since reclaimed)."""
        return self._destroyed_dropped[kind] + \
            sum(sh.destroyed for sh in self._kinds[kind].values())

    def note_tombstone(self, gid: Guid) -> None:
        """A ONCE event in this table fired and became a tombstone (§3)."""
        sh = self._kinds[gid.kind].get(gid.seq >> self._bits)
        if sh is not None:
            sh.tombstones += 1

    def retire_event_shards(self) -> int:
        """Compact fully-tombstoned ONCE-event shards (ROADMAP follow-on).

        A fired ONCE event leaves a satisfiable tombstone in the table so
        reordered late dependences still receive the payload — but a shard
        holding *only* tombstones pays per-object dict storage for what is
        semantically a satisfied-set.  Once such a shard's fan-out has
        quiesced (every member is a tombstone), its ``{seq: (guid,
        payload)}`` map replaces the shard: late dependences synthesize
        the tombstone from it, everything else sees the events as
        destroyed.  Returns the number of shards retired by this call;
        the runtime accumulates it into ``Stats.tombstone_shards_retired``.
        """
        shards = self._kinds[ObjectKind.EVENT]
        retired = 0
        for idx in [i for i, sh in shards.items()
                    if sh.objs and sh.tombstones >= len(sh.objs)]:
            sh = shards[idx]
            # tombstones can overcount if a tombstone was later popped
            # (explicit destroy): verify before compacting, resync if stale
            if not all(isinstance(o, EventObj) and o.destroyed and o.satisfied
                       and o.kind == EventKind.ONCE
                       for o in sh.objs.values()):
                sh.tombstones = sum(
                    1 for o in sh.objs.values()
                    if isinstance(o, EventObj) and o.destroyed
                    and o.satisfied and o.kind == EventKind.ONCE)
                continue
            self._retired_events[idx] = {
                seq: (o.guid, o.payload) for seq, o in sh.objs.items()}
            self._destroyed_dropped[ObjectKind.EVENT] += \
                sh.destroyed + len(sh.objs)
            del shards[idx]
            retired += 1
        return retired

    def note_spilled(self, gid: Guid) -> None:
        sh = self._kinds[gid.kind].get(gid.seq >> self._bits)
        if sh is not None:
            sh.spilled += 1

    def note_unspilled(self, gid: Guid) -> None:
        sh = self._kinds[gid.kind].get(gid.seq >> self._bits)
        if sh is not None and sh.spilled > 0:
            sh.spilled -= 1


def spans_overlap(spans) -> bool:
    """True if any of the half-open ``(start, end)`` spans intersect.

    Shared by the §6.3 copy batching (runtime) and the fused kernel
    wrapper (kernels.ops) so destination-disjointness means the same
    thing everywhere; touching spans (``end == start``) do not overlap.
    """
    ordered = sorted(spans)
    return any(b[0] < a[1] for a, b in zip(ordered, ordered[1:]))


@dataclasses.dataclass
class EventObj:
    guid: Guid
    kind: EventKind
    # (dest guid, slot, mode) registered before satisfaction
    dependents: List[Tuple[Guid, int, DbMode]] = dataclasses.field(default_factory=list)
    satisfied: bool = False
    payload: Any = NULL_GUID  # db guid delivered on satisfaction
    latch_count: int = 0
    destroyed: bool = False


@dataclasses.dataclass
class TemplateObj:
    guid: Guid
    func: Callable[..., Any]
    paramc: int
    depc: int
    destroyed: bool = False


@dataclasses.dataclass
class EdtObj:
    guid: Guid
    template: Guid
    paramv: Tuple[Any, ...]
    depc: int
    node: int
    slots: List[Any] = dataclasses.field(default_factory=list)       # db guid | NULL_GUID | UNSET
    modes: List[DbMode] = dataclasses.field(default_factory=list)
    pending: int = 0
    output_event: Optional[Guid] = None
    duration: float = 1.0
    state: str = "created"   # created -> ready -> running -> done
    # stamped at the created→ready transition when monitoring is on, so
    # the grant-wait histogram (start_time - ready_time) measures virtual
    # time spent ready-but-ungranted behind locks / IO deferrals
    ready_time: float = -1.0
    start_time: float = -1.0
    end_time: float = -1.0
    destroyed: bool = False
    # §6.2 ancestor-deadlock check runs once per EDT per partition epoch:
    # slots are frozen when the task becomes ready, so retries skip it
    # unless a zero-copy partition copy changed some ancestry since
    # (Runtime._partition_epoch)
    deadlock_epoch: int = -1
    # the blocking DB guid whose waiter queue this EDT currently sits in
    waiting_on: Optional[Guid] = None
    # RO waiters granted past this EDT while it was a blocked FIFO head;
    # capped at Runtime.reader_batch_bound over the EDT's whole wait (EDTs
    # run once, so the cap needs no reset) — bounded barging, no starvation
    barged_past: int = 0


@dataclasses.dataclass
class DbObj:
    guid: Guid
    size: int
    node: int
    buffer: Optional[np.ndarray] = None            # uint8 view or owned array
    no_acquire: bool = False                       # DB_PROP_NO_ACQUIRE (§6.3)
    # --- partitioning state (§6) ---
    parent: Optional[Guid] = None
    offset_in_parent: int = 0
    partitions: Dict[Guid, Tuple[int, int]] = dataclasses.field(default_factory=dict)
    static_partitioning: bool = False
    is_view: bool = False                          # zero-copy partition view
    # --- file binding (§5) ---
    file_guid: Optional[Guid] = None
    file_offset: int = 0
    dirty: bool = False
    lazy_file_read: bool = False                   # contents read at first acquire
    io_pending: bool = False                       # async §5 read in flight
    # --- cold-object spill state ---
    spilling: bool = False                         # spill write-back in flight
    spilled: bool = False                          # buffer lives in the spill file
    spill_offset: int = -1                         # offset in the node's spill file
    # virtual time of the last grant touching this block: the spill policy
    # evicts least-recently-granted first (a hot old block — e.g. a serve
    # session's archive — outlives colder younger ones)
    last_touch: float = 0.0
    # bumped whenever the buffer can change (RW/EW grant, copy into this
    # block): a spill completion whose snapshot predates the current
    # version aborts instead of dropping fresher bytes
    version: int = 0
    # --- lock state ---
    readers: int = 0
    writer: Optional[Guid] = None                  # holding EDT guid
    destroyed: bool = False
    pending_destroy: bool = False                  # destroy deferred until release

    def overlaps(self, offset: int, size: int) -> bool:
        for (o, s) in self.partitions.values():
            if offset < o + s and o < offset + size:
                return True
        return False

    def locked(self) -> bool:
        return self.readers > 0 or self.writer is not None

    def available(self, mode: DbMode) -> bool:
        """Can an acquisition in ``mode`` be granted right now (locally)?"""
        if mode == DbMode.NULL:
            return True
        if mode in (DbMode.RO, DbMode.CONST):
            return self.writer is None
        return self.readers == 0 and self.writer is None


@dataclasses.dataclass
class MapObj:
    """Labeled-GUID map (§4)."""

    guid: Guid
    size: int
    creator: Callable[..., Any]
    paramv: Tuple[Any, ...]
    guidv: Tuple[Any, ...]
    entries: Dict[int, Guid] = dataclasses.field(default_factory=dict)
    creator_calls: int = 0
    destroyed: bool = False


@dataclasses.dataclass
class FileObj:
    """File-mapped data block source (§5)."""

    guid: Guid
    path: str
    mode: str                   # "rb" | "rb+" | "wb+"
    size: int = 0
    descriptor_db: Optional[Guid] = None
    chunks: Dict[Guid, Tuple[int, int]] = dataclasses.field(default_factory=dict)
    released: bool = False
    closed: bool = False

    @property
    def writable(self) -> bool:
        return "+" in self.mode or self.mode.startswith("w")

    def chunk_overlaps(self, offset: int, size: int) -> bool:
        for (o, s) in self.chunks.values():
            if offset < o + s and o < offset + size:
                return True
        return False


@dataclasses.dataclass
class DepEntry:
    """What an EDT body sees per pre-slot (``ocrEdtDep_t``)."""

    guid: Any                    # db guid or NULL_GUID
    ptr: Optional[np.ndarray]    # buffer view honouring the acquire mode
    mode: DbMode = DbMode.RO
