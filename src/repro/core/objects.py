"""Runtime object model: events, EDTs, templates, data blocks, maps, files.

Data blocks carry the §6 partitioning state (parent / live partitions /
static flag) and the §5 file binding (file guid + offset + dirty bit).
Locking state implements the acquire-mode semantics that make partitioning
observable: RO/CONST are shared, RW/EW are exclusive *per data block* — so
two tasks in EW on two disjoint partitions run in parallel while the same
two tasks in RW on the whole parent serialize.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .guid import DbMode, EventKind, Guid, Lid, NULL_GUID

UNSET = object()  # pre-slot not yet satisfied


class OcrError(RuntimeError):
    pass


class PartitionOverlapError(OcrError):
    pass


class PartitionDeadlockError(OcrError):
    pass


class PartitionStaticError(OcrError):
    pass


class ChunkOverlapError(OcrError):
    pass


class FileModeError(OcrError):
    pass


def spans_overlap(spans) -> bool:
    """True if any of the half-open ``(start, end)`` spans intersect.

    Shared by the §6.3 copy batching (runtime) and the fused kernel
    wrapper (kernels.ops) so destination-disjointness means the same
    thing everywhere; touching spans (``end == start``) do not overlap.
    """
    ordered = sorted(spans)
    return any(b[0] < a[1] for a, b in zip(ordered, ordered[1:]))


@dataclasses.dataclass
class EventObj:
    guid: Guid
    kind: EventKind
    # (dest guid, slot, mode) registered before satisfaction
    dependents: List[Tuple[Guid, int, DbMode]] = dataclasses.field(default_factory=list)
    satisfied: bool = False
    payload: Any = NULL_GUID  # db guid delivered on satisfaction
    latch_count: int = 0
    destroyed: bool = False


@dataclasses.dataclass
class TemplateObj:
    guid: Guid
    func: Callable[..., Any]
    paramc: int
    depc: int
    destroyed: bool = False


@dataclasses.dataclass
class EdtObj:
    guid: Guid
    template: Guid
    paramv: Tuple[Any, ...]
    depc: int
    node: int
    slots: List[Any] = dataclasses.field(default_factory=list)       # db guid | NULL_GUID | UNSET
    modes: List[DbMode] = dataclasses.field(default_factory=list)
    pending: int = 0
    output_event: Optional[Guid] = None
    duration: float = 1.0
    state: str = "created"   # created -> ready -> running -> done
    start_time: float = -1.0
    end_time: float = -1.0
    destroyed: bool = False
    # §6.2 ancestor-deadlock check runs once per EDT per partition epoch:
    # slots are frozen when the task becomes ready, so retries skip it
    # unless a zero-copy partition copy changed some ancestry since
    # (Runtime._partition_epoch)
    deadlock_epoch: int = -1
    # the blocking DB guid whose waiter queue this EDT currently sits in
    waiting_on: Optional[Guid] = None
    # RO waiters granted past this EDT while it was a blocked FIFO head;
    # capped at Runtime.reader_batch_bound over the EDT's whole wait (EDTs
    # run once, so the cap needs no reset) — bounded barging, no starvation
    barged_past: int = 0


@dataclasses.dataclass
class DbObj:
    guid: Guid
    size: int
    node: int
    buffer: Optional[np.ndarray] = None            # uint8 view or owned array
    no_acquire: bool = False                       # DB_PROP_NO_ACQUIRE (§6.3)
    # --- partitioning state (§6) ---
    parent: Optional[Guid] = None
    offset_in_parent: int = 0
    partitions: Dict[Guid, Tuple[int, int]] = dataclasses.field(default_factory=dict)
    static_partitioning: bool = False
    is_view: bool = False                          # zero-copy partition view
    # --- file binding (§5) ---
    file_guid: Optional[Guid] = None
    file_offset: int = 0
    dirty: bool = False
    lazy_file_read: bool = False                   # contents read at first acquire
    io_pending: bool = False                       # async §5 read in flight
    # --- lock state ---
    readers: int = 0
    writer: Optional[Guid] = None                  # holding EDT guid
    destroyed: bool = False
    pending_destroy: bool = False                  # destroy deferred until release

    def overlaps(self, offset: int, size: int) -> bool:
        for (o, s) in self.partitions.values():
            if offset < o + s and o < offset + size:
                return True
        return False

    def locked(self) -> bool:
        return self.readers > 0 or self.writer is not None

    def available(self, mode: DbMode) -> bool:
        """Can an acquisition in ``mode`` be granted right now (locally)?"""
        if mode == DbMode.NULL:
            return True
        if mode in (DbMode.RO, DbMode.CONST):
            return self.writer is None
        return self.readers == 0 and self.writer is None


@dataclasses.dataclass
class MapObj:
    """Labeled-GUID map (§4)."""

    guid: Guid
    size: int
    creator: Callable[..., Any]
    paramv: Tuple[Any, ...]
    guidv: Tuple[Any, ...]
    entries: Dict[int, Guid] = dataclasses.field(default_factory=dict)
    creator_calls: int = 0
    destroyed: bool = False


@dataclasses.dataclass
class FileObj:
    """File-mapped data block source (§5)."""

    guid: Guid
    path: str
    mode: str                   # "rb" | "rb+" | "wb+"
    size: int = 0
    descriptor_db: Optional[Guid] = None
    chunks: Dict[Guid, Tuple[int, int]] = dataclasses.field(default_factory=dict)
    released: bool = False
    closed: bool = False

    @property
    def writable(self) -> bool:
        return "+" in self.mode or self.mode.startswith("w")

    def chunk_overlaps(self, offset: int, size: int) -> bool:
        for (o, s) in self.chunks.values():
            if offset < o + s and o < offset + size:
                return True
        return False


@dataclasses.dataclass
class DepEntry:
    """What an EDT body sees per pre-slot (``ocrEdtDep_t``)."""

    guid: Any                    # db guid or NULL_GUID
    ptr: Optional[np.ndarray]    # buffer view honouring the acquire mode
    mode: DbMode = DbMode.RO
