"""Identifiers for the OCR-extensions runtime.

The paper (§2) assumes GUIDs may encode creation-time information (owning
node, sequence number, object kind) and therefore cannot be pre-allocated
locally.  We implement exactly that representation: a ``Guid`` is a
``(node, seq, kind)`` triple.  A ``Lid`` (§3) is a *local identifier* — a
future for a GUID, valid only for API calls made by the creating task; it
carries the issuing node and a node-local sequence number.
"""
from __future__ import annotations

import dataclasses
import enum


class ObjectKind(enum.Enum):
    EDT = "edt"
    EVENT = "event"
    DATABLOCK = "db"
    TEMPLATE = "template"
    MAP = "map"
    FILE = "file"


class IdType(enum.Enum):
    """Result of ``ocrGetIdType`` (paper §3)."""

    GUID = "guid"
    LID = "lid"
    UNK = "unk"


class EventKind(enum.Enum):
    ONCE = "once"      # satisfied once, then auto-destroyed after fan-out
    STICKY = "sticky"  # stays satisfied; later dependences fire immediately
    LATCH = "latch"    # satisfied when its counter reaches zero


class DbMode(enum.Enum):
    """Data block acquire modes (OCR spec §1.0 + paper §6)."""

    RO = "ro"        # shared read
    CONST = "const"  # shared read, immutable for the whole task graph epoch
    RW = "rw"        # exclusive read/write (runtime must assume full aliasing)
    EW = "ew"        # exclusive write — exclusive, but *disjoint partitions*
    #                  acquired in EW run in parallel (the point of §6)
    NULL = "null"    # pure control dependence, no data access


@dataclasses.dataclass(frozen=True, eq=False)
class Guid:
    node: int
    seq: int
    kind: ObjectKind

    def __lt__(self, other: "Guid") -> bool:
        return (self.node, self.seq, self.kind.value) < \
            (other.node, other.seq, other.kind.value)

    def __post_init__(self) -> None:
        # guids key every object table and waiter queue — precompute the
        # hash once instead of re-hashing the (int, int, enum) tuple per probe
        object.__setattr__(self, "_hash", hash((self.node, self.seq, self.kind)))

    def __hash__(self) -> int:
        return self._hash  # type: ignore[attr-defined]

    def __eq__(self, other: object) -> bool:
        if other is self:
            return True
        if not isinstance(other, Guid):
            return NotImplemented
        return (self.node == other.node and self.seq == other.seq
                and self.kind is other.kind)

    def __repr__(self) -> str:  # compact, stable for traces
        return f"G({self.node}:{self.seq}:{self.kind.value})"


@dataclasses.dataclass(frozen=True, eq=False)
class Lid:
    """A future for a :class:`Guid` (paper §3).

    Only meaningful on ``node``; the runtime patches messages that carry a
    ``Lid`` once the corresponding ``M_map`` resolution arrives.
    """

    node: int
    seq: int

    def __lt__(self, other: "Lid") -> bool:
        return (self.node, self.seq) < (other.node, other.seq)

    def __post_init__(self) -> None:
        object.__setattr__(self, "_hash", hash((self.node, self.seq)))

    def __hash__(self) -> int:
        return self._hash  # type: ignore[attr-defined]

    def __eq__(self, other: object) -> bool:
        if other is self:
            return True
        if not isinstance(other, Lid):
            return NotImplemented
        return self.node == other.node and self.seq == other.seq

    def __repr__(self) -> str:
        return f"L({self.node}:{self.seq})"


# --------------------------------------------------------------- shard routing
#
# GUIDs encode creation-time structure — (node, seq, kind) — precisely so the
# runtime can exploit it (§2).  The per-node object tables
# (``repro.core.objects.ObjectTable``) shard by kind, then by fixed-width seq
# range: routing a Guid to its shard is pure arithmetic on fields the
# identifier already carries (one shift), never a hash of the full triple.

GUID_SHARD_BITS = 8          # 2**8 = 256 seqs per shard


def shard_index(seq: int, bits: int = GUID_SHARD_BITS) -> int:
    """Index of the seq-range shard holding ``seq`` (O(1), one shift)."""
    return seq >> bits


def shard_span(index: int, bits: int = GUID_SHARD_BITS) -> "tuple[int, int]":
    """Half-open ``[lo, hi)`` seq range covered by shard ``index``."""
    return (index << bits, (index + 1) << bits)


def shard_of(gid: Guid, bits: int = GUID_SHARD_BITS) -> "tuple[ObjectKind, int]":
    """The ``(kind, seq-range)`` shard key a Guid routes to."""
    return (gid.kind, gid.seq >> bits)


# Sentinels (mirroring NULL_GUID / UNINITIALIZED_GUID in the paper's listings).
NULL_GUID = Guid(-1, -1, ObjectKind.EVENT)
UNINITIALIZED_GUID = Guid(-2, -2, ObjectKind.EVENT)

OcrId = object  # Guid | Lid | sentinel — informal union alias


def id_type(x: object) -> IdType:
    """``ocrGetIdType`` — classify an identifier (paper §3)."""
    if isinstance(x, Guid):
        return IdType.GUID
    if isinstance(x, Lid):
        return IdType.LID
    return IdType.UNK


def is_null(x: object) -> bool:
    return isinstance(x, Guid) and x == NULL_GUID


# Creation property flags (paper §3/§4 listings).
EDT_PROP_NONE = 0x0
EDT_PROP_LID = 0x1      # return a LID instead of blocking for a GUID
EDT_PROP_MAPPED = 0x2   # GUID parameter is in-out: a map-provided LID to bind
DB_PROP_NO_ACQUIRE = 0x4  # do not allocate/acquire at creation (§6.3)
OCR_DB_PARTITION_STATIC = 0x1  # §6.2: partitioning fixed until all destroyed

# ocrDbCopy copy types (§6.3).
DB_COPY_PLAIN = 0
DB_COPY_PARTITION = 1        # dst becomes a (possibly zero-copy) partition view
DB_COPY_PARTITION_BACK = 2   # write partition back; entails destruction of src
