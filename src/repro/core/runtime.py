"""The OCR-extensions runtime (paper §2–§6).

A deterministic, virtual-time, multi-node simulation of a message-based
distributed OCR implementation:

* Every API call translates to messages (paper §2).  Remote deliveries cost
  ``net_latency`` of virtual time; an optional seeded ``jitter`` perturbs
  delivery order so property tests can explore interleavings.
* **LIDs (§3)** — object-creating calls with ``EDT_PROP_LID`` return a local
  identifier immediately; messages referencing unresolved LIDs are *deferred*
  on the issuing node, patched when the ``MMap`` resolution arrives, and only
  then submitted (the M_create/M_dep/M_map protocol of §3).  ``get_guid`` is
  the single blocking call; each forced resolution costs one round-trip
  (2 × ``net_latency``) and is counted in :class:`Stats`.
* **Labeled maps (§4)** — ``map_get`` returns a fresh LID instantly; the map
  owner runs the creator function exactly once per index, and all LIDs for
  an index resolve to the same GUID.
* **File IO (§5)** — file-mapped data blocks with asynchronously-filled
  descriptor blocks, non-overlapping chunks, dirty-only write-back.  Chunk
  reads/writes ride per-node virtual-time IO queues (``io_queue.IoQueue``):
  reads stream ahead of first acquire, grants defer on IO-pending blocks,
  and adjacent dirty ranges coalesce into one write-back op
  (``Runtime(io_mode="sync")`` keeps the blocking per-chunk baseline).
* **Partitioning (§6)** — disjoint EW partitions of one data block execute
  in parallel; the parent is quiescent while partitions live; parent+child
  in one task raises :class:`PartitionDeadlockError`; ``db_copy`` implements
  the §6.3 zero-copy / copy-on-write path.

Virtual time gives crisp, noise-free benchmarks: a task occupies
``[start, start + duration + blocking_time]``, locks are held for that
interval, and ``Stats.makespan`` is the completion time of the whole graph.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import itertools
import os
import random
import struct
import tempfile
from typing import Any, Callable, Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .guid import (
    DB_COPY_PARTITION,
    DB_COPY_PARTITION_BACK,
    DB_COPY_PLAIN,
    DB_PROP_NO_ACQUIRE,
    EDT_PROP_LID,
    EDT_PROP_MAPPED,
    GUID_SHARD_BITS,
    OCR_DB_PARTITION_STATIC,
    DbMode,
    EventKind,
    Guid,
    IdType,
    Lid,
    NULL_GUID,
    ObjectKind,
    UNINITIALIZED_GUID,
    id_type,
    is_null,
)
from .io_queue import IoQueue
from ..monitoring import Monitor, Registry
from .messages import (
    MCreate,
    MDbCopy,
    MDep,
    MDestroy,
    MFileOpened,
    MIoDone,
    MMap,
    MMapGet,
    MSatisfy,
    Message,
)
from .objects import (
    ChunkOverlapError,
    DbObj,
    DepEntry,
    EdtObj,
    EventObj,
    FileModeError,
    FileObj,
    MapObj,
    ObjectTable,
    OcrError,
    PartitionDeadlockError,
    PartitionOverlapError,
    PartitionStaticError,
    TemplateObj,
    UNSET,
    spans_overlap,
)

__all__ = [
    "Runtime",
    "TaskCtx",
    "Stats",
    "OcrError",
    "PartitionOverlapError",
    "PartitionDeadlockError",
    "PartitionStaticError",
    "ChunkOverlapError",
    "FileModeError",
]


# Every legacy Stats field, its dotted registry name, and its zero value.
# Declaration order is the dataclass field order Stats used to have, so
# Stats.snapshot() keys come out identical to the old dataclasses.asdict.
_STATS_FIELDS: Tuple[Tuple[str, str, Any], ...] = (
    ("messages_sent", "runtime.messages_sent", 0),
    ("messages_remote", "runtime.messages_remote", 0),
    ("messages_deferred", "runtime.messages_deferred", 0),
    ("deferred_patched", "runtime.deferred_patched", 0),
    ("deferred_rescans", "runtime.deferred_rescans", 0),
    ("blocking_roundtrips", "runtime.blocking_roundtrips", 0),
    ("creator_calls", "runtime.creator_calls", 0),
    ("tasks_executed", "runtime.tasks_executed", 0),
    ("waiter_wakeups", "runtime.waiter_wakeups", 0),
    ("reader_batch_grants", "runtime.reader_batch_grants", 0),
    ("bytes_copied", "copy.bytes_copied", 0),
    ("bytes_zero_copy", "copy.bytes_zero_copy", 0),
    ("file_bytes_read", "io.file_bytes_read", 0),
    ("file_bytes_written", "io.file_bytes_written", 0),
    ("fused_copies", "copy.fused_copies", 0),
    ("io_read_ops", "io.read_ops", 0),
    ("io_write_ops", "io.write_ops", 0),
    ("io_reads_inflight_max", "io.reads_inflight_max", 0),
    ("io_coalesced_writes", "io.coalesced_writes", 0),
    ("io_overlap_ticks", "io.overlap_ticks", 0.0),
    # GUID-table gauges (refreshed when run() returns): live shards across
    # all nodes, shards still holding a buffer-resident object, and data
    # blocks whose buffers currently live in a node spill file
    ("table_shards", "table.shards", 0),
    ("table_hot_shards", "table.hot_shards", 0),
    ("spilled_objects", "spill.objects", 0),
    # fully-tombstoned ONCE-event shards compacted into per-shard
    # satisfied-sets (cumulative — see ObjectTable.retire_event_shards)
    ("tombstone_shards_retired", "table.tombstone_shards_retired", 0),
    # reclaimed-but-uncompacted bytes across all node spill files (the
    # free-list holes), refreshed when run() returns
    ("spill_frag_bytes", "spill.frag_bytes", 0),
    # sanitizer gauges (Runtime(sanitize=...) / REPRO_SANITIZE=1): trace
    # events recorded, hb-races among them, total hard findings, and
    # quiescence advisories (leaks / dangling slots)
    ("san_events", "san.events", 0),
    ("san_races", "san.races", 0),
    ("san_findings", "san.findings", 0),
    ("san_advisories", "san.advisories", 0),
    # spill-file slots handed back out of the free list instead of growing
    # the file (slot reuse — see Runtime._spill_shard)
    ("spill_slots_reused", "spill.slots_reused", 0),
    # on-line spill-file compaction sweeps completed (see
    # Runtime._finish_compact; enabled by spill_compact_threshold)
    ("spill_compactions", "spill.compactions", 0),
    # MoE dispatch gauges (stamped by the Trainer from the last step's
    # metrics): (token, choice) pairs dropped on bucket overflow, their
    # fraction of all routed pairs, and the per-device bytes the two
    # capacity-bucket all_to_all exchanges move per layer
    ("moe_dropped_tokens", "moe.dropped_tokens", 0),
    ("moe_overflow_rate", "moe.overflow_rate", 0.0),
    ("moe_a2a_bytes", "moe.a2a_bytes", 0),
    ("makespan", "runtime.makespan", 0.0),
)


class Stats:
    """Field-compatible view over the ``repro.monitoring`` registry.

    Formerly a dataclass of ~35 counters refreshed only at ``run()``
    return; now every field is a property reading/writing one dotted
    registry slot (``messages_sent`` ↔ ``runtime.messages_sent``), so
    the existing increment sites and committed bench snapshots keep
    working bit-identically while ``Registry.snapshot()`` sees the
    same numbers live, mid-run.  Standalone construction (``Stats()``)
    makes a private registry, preserving the old dataclass behaviour.
    """

    __slots__ = ("registry",)

    def __init__(self, registry: Optional[Registry] = None):
        self.registry = Registry() if registry is None else registry
        declare = self.registry.declare
        for _field, name, default in _STATS_FIELDS:
            declare(name, default)

    def snapshot(self) -> Dict[str, float]:
        vals = self.registry._values
        return {field: vals[name] for field, name, _default in _STATS_FIELDS}

    def __repr__(self) -> str:
        body = ", ".join(f"{k}={v!r}" for k, v in self.snapshot().items())
        return f"Stats({body})"


def _stats_property(name: str) -> property:
    def _get(self: Stats) -> Any:
        return self.registry._values[name]

    def _set(self: Stats, value: Any) -> None:
        self.registry._values[name] = value

    return property(_get, _set)


for _field, _name, _default in _STATS_FIELDS:
    setattr(Stats, _field, _stats_property(_name))
del _field, _name, _default


@dataclasses.dataclass
class _Node:
    idx: int
    alive: bool = True
    guid_seq: int = 0
    lid_seq: int = 0
    # GUID table sharded by (kind, seq-range) — see objects.ObjectTable
    objects: ObjectTable = dataclasses.field(default_factory=ObjectTable)
    lid_table: Dict[Lid, Optional[Guid]] = dataclasses.field(default_factory=dict)
    # --- cold-object spill (one private spill file per node) ---
    spill_path: Optional[str] = None
    spill_tail: int = 0               # high-water mark of the spill file
    # freed spill-file holes as (offset, size), first-fit reused by the
    # next spill instead of bumping the tail forever
    spill_free: List[Tuple[int, int]] = dataclasses.field(default_factory=list)
    spilled: int = 0                  # blocks currently spilled on this node
    spill_inflight: int = 0           # victims with a spill write in flight
    compact_inflight: bool = False    # a compaction sweep op is on the disk
    spill_scan_at: float = -1.0       # last fruitless-scan timestamp guard
    # blocks owning their buffer (not views, not spilled/unread): kept
    # incrementally so the spill threshold check is O(1), not O(objects)
    resident_dbs: int = 0
    # messages held locally until all their unresolved LIDs are patched;
    # a message is indexed under *every* unresolved LID it references, so
    # one MMap patch releases it iff it was the last unresolved one — no
    # re-deferral rescans (see Message._blocked_on)
    deferred: Dict[Lid, List[Message]] = dataclasses.field(default_factory=dict)
    # count of LIDs allocated on this node that are still unresolved; lets
    # send() skip the lids() allocation+scan entirely on the common path
    unresolved_lids: int = 0


class Runtime:
    """A virtual-time multi-node OCR runtime."""

    def __init__(
        self,
        num_nodes: int = 1,
        net_latency: float = 1.0,
        io_latency: float = 1.0,
        seed: int = 0,
        jitter: float = 0.0,
        trace: bool = False,
        copy_backend: str = "numpy",
        reader_batch_bound: int = 8,
        io_mode: str = "async",
        read_ahead: bool = True,
        spill_threshold: Optional[int] = None,
        spill_compact_threshold: Optional[float] = None,
        shard_bits: int = GUID_SHARD_BITS,
        sanitize: Any = None,
        monitor: Any = None,
    ):
        self.num_nodes = num_nodes
        self.net_latency = float(net_latency)
        self.io_latency = float(io_latency)
        self.jitter = float(jitter)
        self.rng = random.Random(seed)
        self.trace = trace
        self.copy_backend = copy_backend  # "numpy" | "pallas" (§6.3 fallback)
        # §5 file IO discipline: "async" puts chunk reads/writes on the
        # per-node IO queues (overlap with compute, write coalescing);
        # "sync" drives the same latency model blocking, per chunk
        if io_mode not in ("async", "sync"):
            raise ValueError(f"io_mode must be 'async' or 'sync', not {io_mode!r}")
        self.io_mode = io_mode
        # async mode: issue the lazy read already at file_get_chunk time
        # (ahead of the first acquire) instead of at the first grant attempt
        self.read_ahead = read_ahead
        # max RO waiters granted past a blocked FIFO head per wake (bounded
        # barging: 0 disables; keeps writers from starving behind readers)
        self.reader_batch_bound = reader_batch_bound
        # cold-object spill: when a node holds more than this many
        # buffer-resident data blocks, idle unlocked ones spill to the
        # node's spill file through the §5 IO queue (None disables)
        self.spill_threshold = spill_threshold
        # on-line spill-file compaction: when a node's free-list holes
        # exceed this fraction of its bump pointer, live slots rewrite
        # through one IO-queue sweep and the tail shrinks (None disables)
        self.spill_compact_threshold = spill_compact_threshold
        self.shard_bits = shard_bits
        self.nodes = [_Node(i, objects=ObjectTable(shard_bits))
                      for i in range(num_nodes)]
        # one monitoring registry per runtime; Stats is a property view
        # over it, so counters land in the registry whether or not the
        # Monitor hooks below are enabled
        self.registry = Registry()
        self.stats = Stats(self.registry)
        self.clock = 0.0
        self._heap: List[Tuple[float, int, str, Any]] = []
        self._tick = itertools.count()
        self._cancelled: set = set()
        self._placement_rr = 0
        self.shutdown_requested = False
        # lid -> in-flight message that will bind it (for forced resolution)
        self._pending_lid_msg: Dict[Lid, Message] = {}
        # per-DB FIFO waiter queues: blocking db guid -> deque of EdtObj;
        # a release wakes only waiters of the DB whose state changed.
        # EdtObj.waiting_on marks which queue an EDT currently sits in
        # (dedup + O(1) staleness checks without hashing guids).
        self._db_waiters: Dict[Guid, Deque[EdtObj]] = {}
        # db guid -> ancestor chain (parent links only change when a
        # zero-copy §6.3 partition copy assigns one, which invalidates)
        self._ancestor_cache: Dict[Guid, Tuple[Guid, ...]] = {}
        # bumped when a zero-copy partition copy rewires ancestry; EDTs
        # re-run the §6.2 deadlock check lazily when their epoch is stale
        self._partition_epoch = 0
        # §6.3 same-timestamp copy batching (flushed through one fused
        # kernel launch per (src, dst) pair when a partition set materializes)
        self._copy_batch: List[MDbCopy] = []
        self._copy_flush_scheduled = False
        # registry so file descriptors can be decoded from raw pointers (§5)
        self.file_registry: List[Guid] = []
        # §5 async IO subsystem: per-node virtual-time disk queues
        self.io = IoQueue(self)
        # tasks currently occupying a virtual-time window (for
        # Stats.io_overlap_ticks: time IO and compute were both in flight)
        self._running_tasks = 0
        # --- ocrsan (repro.analysis): None when off, so every hook site is
        # one attribute check on the fast path.  The explicit parameter
        # wins over the REPRO_SANITIZE environment variable; "1"/"strict"
        # raise OcrSanError at run() return, anything else truthy records.
        if sanitize is None:
            sanitize = os.environ.get("REPRO_SANITIZE", "")
        self._san = None
        mode = str(sanitize).lower()
        if mode not in ("", "0", "false", "none", "off"):
            from ..analysis.trace import Sanitizer
            self._san = Sanitizer(self, strict=mode in ("1", "strict"))
        # --- monitoring (repro.monitoring): same wiring as the sanitizer —
        # None when off, so live-gauge and histogram hook sites are one
        # attribute check and virtual metrics stay bit-identical either way.
        # The explicit parameter wins over REPRO_MONITOR.
        if monitor is None:
            monitor = os.environ.get("REPRO_MONITOR", "")
        self._mon = None
        mmode = str(monitor).lower()
        if mmode not in ("", "0", "false", "none", "off"):
            self._mon = Monitor(self.registry)

    def san_report(self):
        """The sanitizer's findings so far (``repro.analysis.SanitizerReport``).

        Quiescence lints (lost wakeups, leaks, dangling slots) are
        included only when the event heap is empty.  Raises
        :class:`OcrError` if the runtime was built without ``sanitize``.
        """
        if self._san is None:
            raise OcrError(
                "sanitizer not enabled: pass Runtime(sanitize=True) "
                "or set REPRO_SANITIZE=1")
        return self._san.report()

    # ------------------------------------------------------------------ util

    def _log(self, *args: Any) -> None:
        if self.trace:
            print(f"[t={self.clock:8.2f}]", *args)

    def node(self, i: int) -> _Node:
        return self.nodes[i]

    def _alloc_guid(self, node: int, kind: ObjectKind) -> Guid:
        n = self.nodes[node]
        n.guid_seq += 1
        return Guid(node, n.guid_seq, kind)

    def _alloc_lid(self, node: int) -> Lid:
        n = self.nodes[node]
        n.lid_seq += 1
        lid = Lid(node, n.lid_seq)
        n.lid_table[lid] = None
        n.unresolved_lids += 1
        if self._san is not None:
            self._san.on_lid_alloc(lid)
        return lid

    def _pick_node(self, hint: Optional[int]) -> int:
        if hint is not None:
            n = hint % self.num_nodes
            if not self.nodes[n].alive:
                raise OcrError(
                    f"placement on node {n}: node fail-stopped")
            return n
        for _ in range(self.num_nodes):
            self._placement_rr = (self._placement_rr + 1) % self.num_nodes
            if self.nodes[self._placement_rr].alive:
                return self._placement_rr
        raise OcrError("no alive nodes to place on")

    def lookup(self, gid: Guid) -> Any:
        node = self.nodes[gid.node]
        obj = node.objects.get(gid)
        if obj is None:
            if not node.alive:
                raise OcrError(
                    f"object {gid} lost: node {gid.node} fail-stopped")
            raise OcrError(f"unknown or destroyed object {gid}")
        return obj

    def try_lookup(self, gid: Guid) -> Any:
        return self.nodes[gid.node].objects.get(gid)

    def resolve(self, x: Any) -> Any:
        """LID → GUID if already resolved, else the LID itself."""
        if isinstance(x, Lid):
            g = self.nodes[x.node].lid_table.get(x)
            return g if g is not None else x
        return x

    # ------------------------------------------------------ message transport

    def send(self, msg: Message, src: int, dst: int, at: Optional[float] = None) -> None:
        if self._san is not None:
            self._san.on_send(msg)
        msg.stamp(src, dst)
        when = self.clock if at is None else at
        node = self.nodes[src]
        # Fast path: a node with no outstanding LIDs can never defer, so the
        # lids() allocation+scan is skipped entirely (the common case).
        if node.unresolved_lids == 0:
            self._transmit(msg, when)
            return
        # §3: messages referencing a locally-unresolved LID are deferred on
        # the issuing node.  The *binding* lid of MCreate/MMapGet travels.
        binding = getattr(msg, "lid", None)
        unresolved = {
            l for l in msg.lids()
            if l != binding and l.node == src and node.lid_table.get(l) is None
        }
        if unresolved:
            self.stats.messages_deferred += 1
            self._log("DEFER", type(msg).__name__, "on", sorted(unresolved))
            # index under *every* unresolved lid: the patch that empties
            # _blocked_on transmits; the others just shrink the set
            msg._blocked_on = unresolved       # type: ignore[attr-defined]
            msg._deliver_at = when             # type: ignore[attr-defined]
            for l in unresolved:
                node.deferred.setdefault(l, []).append(msg)
            return
        self._transmit(msg, when)

    def _transmit(self, msg: Message, when: float) -> None:
        self.stats.messages_sent += 1
        lat = 0.0
        if msg.src_node != msg.dst_node:
            self.stats.messages_remote += 1
            lat = self.net_latency
        if self.jitter:
            lat += self.rng.uniform(0.0, self.jitter)
        binding = getattr(msg, "lid", None)
        if binding is not None and isinstance(msg, (MCreate, MMapGet)):
            self._pending_lid_msg[binding] = msg
        heapq.heappush(self._heap, (when + lat, next(self._tick), "msg", msg))

    # --------------------------------------------------------------- run loop

    def run(self, until: Optional[float] = None) -> Stats:
        """Process events until quiescent, shutdown, or ``until``."""
        while self._heap and not self.shutdown_requested:
            t, tick, kind, payload = heapq.heappop(self._heap)
            if until is not None and t > until:
                # preserve the original tick: a fresh one would reorder the
                # event against same-timestamp peers on resume
                heapq.heappush(self._heap, (t, tick, kind, payload))
                break
            if t > self.clock and self.io.inflight > 0 \
                    and self._running_tasks > 0:
                # both a disk op and a task occupy this interval: the IO
                # was hidden behind compute (the §5 overlap the async
                # queue exists to buy)
                self.stats.io_overlap_ticks += t - self.clock
            self.clock = max(self.clock, t)
            if kind == "msg":
                if payload.uid in self._cancelled:
                    continue
                self._dispatch(payload)
            elif kind == "task_end":
                self._task_end(payload)
            elif kind == "task_compute":
                # a sync-mode task finished blocking on its charged IO
                # and is computing from here on
                self._running_tasks += 1
            elif kind == "copy_flush":
                self._flush_copy_batch()
            elif kind == "io_flush":
                self.io.flush_writes()
            elif kind == "failstop_wake":
                # a survivor EDT stranded on a fail-stopped node's DB:
                # retrying the grant reaches _execute's lookup of the lost
                # block, which raises the clean fail-stop OcrError
                if payload.state == "ready" and payload.waiting_on is None \
                        and self.nodes[payload.node].alive:
                    self._try_grant(payload)
            elif kind == "db_copy":
                self._do_db_copy(payload)
        self.stats.makespan = self.clock
        self._refresh_table_stats()
        if self._san is not None:
            self._san.on_run_return()
        return self.stats

    def _refresh_table_stats(self) -> None:
        shards = hot = frag = 0
        for n in self.nodes:
            self.stats.tombstone_shards_retired += \
                n.objects.retire_event_shards()
            shards += n.objects.shard_count()
            hot += n.objects.hot_shard_count()
            frag += sum(sz for _, sz in n.spill_free)
        self.stats.table_shards = shards
        self.stats.table_hot_shards = hot
        self.stats.spill_frag_bytes = frag

    def close(self) -> None:
        """Release host resources (per-node spill files)."""
        for node in self.nodes:
            if node.spill_path is not None:
                try:
                    os.unlink(node.spill_path)
                except OSError:
                    pass
                node.spill_path = None

    def __del__(self) -> None:  # best-effort; close() is the real API
        try:
            self.close()
        except Exception:
            pass

    def quiescent(self) -> bool:
        return not self._heap

    def kill_node(self, idx: int) -> None:
        """Fail-stop a node: lose its objects and all in-flight traffic to it.

        Fail-stop actually *loses* the node's objects: the GUID table is
        dropped wholesale (O(shards) — the sharded layout's bulk path),
        the LID table and deferred messages go with it, survivors looking
        the objects up get a clean :class:`OcrError` naming the dead node,
        and the node's spill file is reclaimed from disk.
        """
        if self._san is not None:
            self._san.on_kill_node(idx)
        node = self.nodes[idx]
        node.alive = False
        node.objects.clear()
        node.lid_table.clear()
        node.deferred.clear()
        node.unresolved_lids = 0
        # spilled buffers die with the node: fix the gauge and reclaim disk
        self.stats.spilled_objects -= node.spilled
        node.spilled = 0
        node.spill_inflight = 0
        node.compact_inflight = False
        node.resident_dbs = 0
        node.spill_tail = 0
        node.spill_free.clear()
        if node.spill_path is not None:
            try:
                os.unlink(node.spill_path)
            except OSError:
                pass
            node.spill_path = None
        # waiter queues keyed by the dead node's DBs can never be granted:
        # surviving EDTs parked there are woken so their next grant attempt
        # surfaces the clean fail-stop OcrError instead of hanging silently
        for g in [g for g in self._db_waiters if g.node == idx]:
            for edt in self._db_waiters.pop(g):
                if edt.waiting_on != g or not self.nodes[edt.node].alive:
                    continue
                edt.waiting_on = None
                heapq.heappush(self._heap, (self.clock, next(self._tick),
                                            "failstop_wake", edt))

    # ---------------------------------------------------------- msg dispatch

    def _dispatch(self, msg: Message) -> None:
        if not self.nodes[msg.dst_node].alive:
            if isinstance(msg, MIoDone):
                # the disk died with its node: the op's bytes are lost
                # (crash semantics), but the inflight accounting is not
                self.io.complete(msg.op)
            self._log("DROP (dead node)", type(msg).__name__)
            return
        handler = getattr(self, f"_on_{type(msg).__name__}")
        if self._san is None:
            handler(msg)
            return
        # the handler runs under the sender's clock snapshot (the §2
        # receive edge); handlers never own a vector-clock component
        tok = self._san.msg_begin(msg)
        try:
            handler(msg)
        finally:
            self._san.ctx_end(tok)

    # -- creation ----------------------------------------------------------

    def _on_MCreate(self, msg: MCreate) -> None:
        guid = self._create_object(msg.dst_node, msg.kind, msg.payload)
        if msg.lid is not None:
            self._pending_lid_msg.pop(msg.lid, None)
            self.send(MMap(lid=msg.lid, guid=guid), msg.dst_node, msg.lid.node)

    def _create_object(self, node: int, kind: str, payload: Dict[str, Any]) -> Guid:
        if kind == "edt":
            return self._create_edt(node, payload)
        if kind == "db":
            return self._create_db(node, payload).guid
        if kind == "event":
            return self._create_event(node, payload).guid
        raise OcrError(
            f"unsupported remote-create kind {kind!r}: only EDTs, data "
            f"blocks and events can be created on a remote node — create "
            f"the {kind} locally (or on its owner via placement at the "
            f"API call) and publish its guid, e.g. through a labeled map")

    def _create_db(self, node: int, p: Dict[str, Any]) -> DbObj:
        guid = self._alloc_guid(node, ObjectKind.DATABLOCK)
        size = p["size"]
        no_acq = bool(p.get("props", 0) & DB_PROP_NO_ACQUIRE)
        db = DbObj(guid=guid, size=size, node=node, no_acquire=no_acq)
        db.ready = True
        db.pending_deps = []
        if not no_acq:
            db.buffer = np.zeros(size, dtype=np.uint8)
            self.nodes[node].resident_dbs += 1
        self.nodes[node].objects.insert(db)
        return db

    def _create_event(self, node: int, p: Dict[str, Any]) -> EventObj:
        guid = self._alloc_guid(node, ObjectKind.EVENT)
        ev = EventObj(guid, p.get("kind", EventKind.ONCE),
                      latch_count=p.get("latch_count", 0))
        self.nodes[node].objects.insert(ev)
        return ev

    def _create_edt(self, node: int, p: Dict[str, Any]) -> Guid:
        guid = self._alloc_guid(node, ObjectKind.EDT)
        tmpl_id = self.resolve(p["template"])
        depv = [self.resolve(d) for d in p.get("depv") or []]
        depc = p["depc"]
        edt = EdtObj(
            guid=guid,
            template=tmpl_id,
            paramv=tuple(p.get("paramv") or ()),
            depc=depc,
            node=node,
            slots=[UNSET] * depc,
            modes=[DbMode.RO] * depc,
            pending=depc,
            duration=p.get("duration", 1.0),
        )
        if p.get("output_event") is not None:
            edt.output_event = p["output_event"]
        self.nodes[node].objects.insert(edt)
        if self._san is not None:
            # base clock = creation context; slot satisfies join in later
            # (NULL creation-time deps satisfy during the wiring below)
            self._san.on_task_created(guid)
        # wire creation-time dependences
        modes = p.get("dep_modes") or [DbMode.RO] * len(depv)
        for slot, (dep, mode) in enumerate(zip(depv, modes)):
            if dep is UNSET or dep == UNINITIALIZED_GUID:
                continue
            edt.modes[slot] = mode
            if is_null(dep):
                self._satisfy_slot(edt, slot, NULL_GUID)
            else:
                if isinstance(dep, Guid) and not self.nodes[dep.node].alive:
                    raise OcrError(
                        f"dependence on {dep}: node {dep.node} fail-stopped "
                        f"and its objects are lost")
                self.send(MDep(source=dep, dest=guid, slot=slot, mode=mode),
                          node, dep.node if isinstance(dep, Guid) else node)
        if edt.pending == 0 and edt.state == "created":
            edt.state = "ready"
            if self._mon is not None:
                edt.ready_time = self.clock
            self._try_grant(edt)
        return guid

    def _on_MMap(self, msg: MMap) -> None:
        self._apply_lid_binding(msg.lid, msg.guid)

    def _apply_lid_binding(self, lid: Lid, guid: Guid) -> None:
        if self._san is not None:
            self._san.on_lid_bound(lid, guid)
        node = self.nodes[lid.node]
        if node.lid_table.get(lid) is None and lid in node.lid_table:
            node.unresolved_lids -= 1
        node.lid_table[lid] = guid
        waiting = node.deferred.pop(lid, [])
        for m in waiting:
            self.stats.deferred_patched += 1
            m.patch({lid: guid})
            blocked = m._blocked_on  # type: ignore[attr-defined]
            blocked.discard(lid)
            if blocked:
                # still parked under its remaining lids — no rescan needed
                self.stats.deferred_rescans += 1
            else:
                self._transmit(m, max(self.clock, getattr(m, "_deliver_at", self.clock)))

    # -- dependences & satisfaction -----------------------------------------

    def _on_MDep(self, msg: MDep) -> None:
        src = self.resolve(msg.source)
        if isinstance(src, Lid):
            # §3: a cross-node dependence can reach dispatch before the
            # LID's binding message lands — sender-side deferral only
            # covers the *sender's* unresolved LIDs.  Park the dep at the
            # LID's home node; the binding patch retransmits it.
            home = self.nodes[src.node]
            if src in home.lid_table:
                self.stats.messages_deferred += 1
                msg._blocked_on = {src}            # type: ignore[attr-defined]
                msg._deliver_at = self.clock       # type: ignore[attr-defined]
                home.deferred.setdefault(src, []).append(msg)
                return
        if is_null(src):
            dest = self.resolve(msg.dest)
            self.send(MSatisfy(target=dest, slot=msg.slot, db=NULL_GUID, ),
                      msg.dst_node, dest.node if isinstance(dest, Guid) else msg.dst_node)
            return
        obj = self.lookup(src)
        if isinstance(obj, EventObj):
            if obj.destroyed and not obj.satisfied:
                raise OcrError(f"dependence on destroyed event {src}")
            if obj.satisfied:
                # sticky/latch by definition; once-events via tombstone
                if self._san is not None:
                    # the late dependent inherits the event's full history
                    self._san.on_event_replay(obj.guid)
                self.send(MSatisfy(target=msg.dest, slot=msg.slot, db=obj.payload),
                          msg.dst_node, self._owner(msg.dest))
            else:
                obj.dependents.append((msg.dest, msg.slot, msg.mode))
        elif isinstance(obj, DbObj):
            # §5: descriptor blocks delay satisfaction until the file opens
            if not getattr(obj, "ready", True):
                obj.pending_deps.append((msg.dest, msg.slot, msg.mode))
            else:
                self.send(MSatisfy(target=msg.dest, slot=msg.slot, db=src),
                          msg.dst_node, self._owner(msg.dest))
        else:
            raise OcrError(f"invalid dependence source {src}")
        # record the mode on the destination slot
        dest = self.resolve(msg.dest)
        if isinstance(dest, Guid) and dest.kind == ObjectKind.EDT:
            edt = self.try_lookup(dest)
            if edt is not None and msg.slot < len(edt.modes):
                edt.modes[msg.slot] = msg.mode

    def _owner(self, x: Any) -> int:
        x = self.resolve(x)
        if isinstance(x, Guid):
            return x.node
        if isinstance(x, Lid):
            return x.node
        raise OcrError(f"cannot route to {x}")

    def _on_MSatisfy(self, msg: MSatisfy) -> None:
        target = self.resolve(msg.target)
        obj = self.lookup(target)
        db = self.resolve(msg.db)
        if isinstance(obj, EventObj):
            self._satisfy_event(obj, db)
        elif isinstance(obj, EdtObj):
            self._satisfy_slot(obj, msg.slot, db)
        else:
            raise OcrError(f"cannot satisfy {target}")

    def _satisfy_event(self, ev: EventObj, db: Any) -> None:
        if self._san is not None:
            # accumulate every satisfier's clock (latch decrements included
            # — the fan-out must carry the join of all of them)
            self._san.on_event_satisfied(ev)
        if ev.kind == EventKind.LATCH:
            ev.latch_count -= 1
            if ev.latch_count > 0:
                return
        if ev.satisfied and ev.kind == EventKind.STICKY:
            return
        ev.satisfied = True
        ev.payload = db
        for (dest, slot, _mode) in ev.dependents:
            self.send(MSatisfy(target=dest, slot=slot, db=db),
                      ev.guid.node, self._owner(dest))
        if ev.kind == EventKind.ONCE:
            # fire-once, then leave a satisfiable tombstone: a dependence
            # added after the fire (reordered delivery) still receives the
            # payload instead of racing against destruction
            if not ev.destroyed:
                self.nodes[ev.guid.node].objects.note_tombstone(ev.guid)
            ev.dependents = []
            ev.destroyed = True

    def _satisfy_slot(self, edt: EdtObj, slot: int, db: Any) -> None:
        if edt.slots[slot] is not UNSET:
            raise OcrError(f"slot {slot} of {edt.guid} satisfied twice")
        if self._san is not None:
            # dependence edge: the task's base clock joins this context
            self._san.on_slot_satisfied(edt.guid)
        edt.slots[slot] = db
        edt.pending -= 1
        if edt.pending == 0:
            edt.state = "ready"
            if self._mon is not None:
                edt.ready_time = self.clock
            self._try_grant(edt)

    # -- locks & execution ---------------------------------------------------

    def _dep_dbs(self, edt: EdtObj) -> List[Tuple[DbObj, DbMode]]:
        out = []
        for s, mode in zip(edt.slots, edt.modes):
            if isinstance(s, Guid) and s.kind == ObjectKind.DATABLOCK and mode != DbMode.NULL:
                db = self.try_lookup(s)
                if db is not None:
                    out.append((db, mode))
        return out

    def _ancestors(self, db: DbObj) -> Tuple[Guid, ...]:
        # parent links are fixed at creation and a parent outlives its
        # partitions, so the chain is computed once per DB and cached
        cached = self._ancestor_cache.get(db.guid)
        if cached is not None:
            return cached
        out: List[Guid] = []
        cur = db
        while cur.parent is not None:
            out.append(cur.parent)
            cur = self.lookup(cur.parent)
        chain = tuple(out)
        self._ancestor_cache[db.guid] = chain
        return chain

    def _check_deadlock(self, deps: List[Tuple[DbObj, DbMode]]) -> None:
        guids = {d.guid for d, _ in deps}
        for d, _ in deps:
            if guids.intersection(self._ancestors(d)):
                raise PartitionDeadlockError(
                    f"task acquires data block {d.guid} and one of its ancestors "
                    f"— §6.2 forbids parent+partition in one task (deadlock)")

    def _try_grant(self, edt: EdtObj) -> Optional[Guid]:
        """Grant all locks and execute, or park on the first blocking DB.

        Returns the blocking DB's guid, or None if the task was granted.
        The deadlock check runs once per EDT per partition epoch: slots
        are frozen by the time the task is ready, so the result can only
        change when a zero-copy partition copy rewires ancestry (which
        bumps ``_partition_epoch``).
        """
        deps = self._dep_dbs(edt)
        if edt.deadlock_epoch != self._partition_epoch:
            self._check_deadlock(deps)
            edt.deadlock_epoch = self._partition_epoch
        for db, mode in deps:
            # §6.2 quiescence: a partitioned block is unavailable in any mode
            if db.partitions or not db.available(mode):
                self._enqueue_waiter(edt, db.guid)
                return db.guid
            # §5 async IO: a block whose lazy read has not landed — or
            # whose buffer was spilled cold — defers the grant through the
            # same waiter queue; the grant attempt itself issues the read
            # (file range or spill range) if read-ahead did not already
            if self.io_mode == "async" and db.buffer is None \
                    and (db.io_pending or db.lazy_file_read or db.spilled):
                self._start_read(db)
                self._enqueue_waiter(edt, db.guid)
                return db.guid
        for db, mode in deps:
            db.last_touch = self.clock      # access recency for the spill policy
            if mode in (DbMode.RO, DbMode.CONST):
                db.readers += 1
            elif mode in (DbMode.RW, DbMode.EW):
                db.writer = edt.guid
                db.dirty = True
                db.version += 1     # an in-flight spill snapshot is now stale
        if self._san is not None:
            # birth of the task's vector-clock activity: base = creation ∨
            # slot satisfies ∨ acquired locks' release clocks; its accesses
            # are recorded against the §6 root blocks here
            self._san.on_grant(edt, deps)
        self._execute(edt)
        return None

    def _enqueue_waiter(self, edt: EdtObj, db_guid: Guid) -> None:
        if edt.waiting_on is not None:
            return
        edt.waiting_on = db_guid
        self._db_waiters.setdefault(db_guid, collections.deque()).append(edt)

    def _wake_waiters(self, db_guid: Guid) -> None:
        """Retry waiters of one DB in FIFO order after its state changed.

        Stops at the first waiter that re-blocks on this same DB: the head
        keeps its place (no starvation of writers behind a reader stream)
        and the tail is not pointlessly retried — one release wakes O(1)
        grantable tasks instead of re-running _try_grant for every waiter.
        """
        # re-fetch the queue every iteration: granting a waiter runs its
        # task body synchronously, which can re-enter _wake_waiters for
        # this same DB and replace (or delete) the deque under us
        while True:
            queue = self._db_waiters.get(db_guid)
            if not queue:
                break
            edt = queue[0]
            if edt.waiting_on != db_guid:
                queue.popleft()        # stale: re-queued elsewhere meanwhile
                continue
            queue.popleft()
            edt.waiting_on = None
            if edt.state != "ready":
                continue
            if not self.nodes[edt.node].alive:
                continue               # a fail-stopped node's EDT never runs
            self.stats.waiter_wakeups += 1
            if self._try_grant(edt) == db_guid:
                # re-blocked: _enqueue_waiter appended it; restore its FIFO
                # head position, then stop retrying the rest — except for a
                # bounded batch of RO waiters that can share the block now
                queue = self._db_waiters.get(db_guid)
                if queue and queue[-1] is edt:
                    queue.pop()
                    queue.appendleft(edt)
                self._reader_batch_grant(db_guid)
                break
        queue = self._db_waiters.get(db_guid)
        if queue is not None and not queue:
            self._db_waiters.pop(db_guid, None)

    def _waits_ro_only(self, edt: EdtObj, db_guid: Guid) -> bool:
        modes = [m for s, m in zip(edt.slots, edt.modes)
                 if isinstance(s, Guid) and s == db_guid]
        return bool(modes) and all(m in (DbMode.RO, DbMode.CONST)
                                   for m in modes)

    def _reader_batch_grant(self, db_guid: Guid) -> None:
        """Bounded reader barging (ROADMAP "waiter-queue mode awareness").

        The FIFO head just re-blocked — typically a writer waiting out the
        current readers.  If the DB is readable right now, RO waiters
        queued *behind* that head could share it without delaying the head
        at all (readers don't conflict with readers).  The cap is per
        blocked *head*, not per wake: ``head.barged_past`` accumulates
        across wakes, so at most ``reader_batch_bound`` readers ever
        overtake one waiting task no matter how sustained the reader
        stream is — bounded barging, no starvation.  Each grant counts in
        ``Stats.reader_batch_grants``.
        """
        bound = self.reader_batch_bound
        if bound <= 0:
            return
        db = self.try_lookup(db_guid)
        if db is None or db.partitions or not db.available(DbMode.RO):
            return
        queue = self._db_waiters.get(db_guid)
        if queue is None or len(queue) < 2:
            return
        head = queue[0]
        if head.barged_past >= bound:
            return
        granted = 0
        bound = bound - head.barged_past
        # snapshot a bounded window: grants run task bodies synchronously,
        # which can re-enter the wake machinery and mutate the live deque
        window = list(queue)[1: 1 + 8 * bound]
        for cand in window:
            if granted >= bound:
                break
            if cand.waiting_on != db_guid or cand.state != "ready" \
                    or not self.nodes[cand.node].alive \
                    or not self._waits_ro_only(cand, db_guid):
                continue
            live = self._db_waiters.get(db_guid)
            if live is None:
                break
            try:
                live.remove(cand)
            except ValueError:
                continue
            cand.waiting_on = None
            self.stats.waiter_wakeups += 1
            blocked_on = self._try_grant(cand)
            if blocked_on is None:
                granted += 1
                head.barged_past += 1
                self.stats.reader_batch_grants += 1
            elif blocked_on == db_guid:
                break          # a reentrant wake changed the DB's state
            # else: parked on a different DB; keep scanning
            db = self.try_lookup(db_guid)
            if db is None or db.partitions or not db.available(DbMode.RO):
                break

    def _start_read(self, db: DbObj) -> None:
        """Enqueue the §5 lazy read of ``db`` on its node's IO queue.

        A spilled block re-materializes through the same machinery: the
        read targets the node's spill file instead of a §5 user file, and
        waiters wake from the same ``MIoDone`` an IO-pending chunk uses.
        """
        if db.io_pending or db.buffer is not None:
            return
        if db.spilled:
            node = self.nodes[db.guid.node]
            self.io.submit_read(db, None, path=node.spill_path,
                                offset=db.spill_offset)
            self._log("IO unspill", db.guid, f"[{db.spill_offset},+{db.size})")
            return
        if db.file_guid is None:
            return
        f: FileObj = self.lookup(db.file_guid)
        self.io.submit_read(db, f)
        self._log("IO read", db.guid, f"[{db.file_offset},+{db.size})")

    def _materialize(self, db: DbObj) -> np.ndarray:
        """Synchronous materialization (zero virtual-time charge).

        EDT acquisitions never reach this with an unread file chunk or a
        spilled buffer — the grant defers until the async read lands (or,
        in sync mode, ``_execute`` charges the read to the task's blocking
        time).  The remaining callers (§6.3 copies, ``db_partition``,
        descriptor fill) keep the seed's immediate-read semantics.
        """
        if db.buffer is None:
            if db.spilled:
                node = self.nodes[db.guid.node]
                db.buffer = _read_file_region(node.spill_path,
                                              db.spill_offset, db.size)
                self._clear_spill(db)
            elif db.lazy_file_read and db.file_guid is not None:
                f: FileObj = self.lookup(db.file_guid)
                db.buffer = _read_file_region(f.path, db.file_offset, db.size)
                self.stats.file_bytes_read += db.size
                db.lazy_file_read = False
            else:
                db.buffer = np.zeros(db.size, dtype=np.uint8)
            # views never reach here (they alias a live parent buffer),
            # so the block now owns its buffer
            self.nodes[db.guid.node].resident_dbs += 1
        return db.buffer

    def _clear_spill(self, db: DbObj) -> None:
        """Drop ``db``'s spilled status (re-materialized or destroyed) and
        return its spill-file slot to the node's free list."""
        db.spilled = False
        if self._san is not None:
            self._san.on_unspill(db.guid)
        node = self.nodes[db.guid.node]
        node.spilled = max(0, node.spilled - 1)
        node.objects.note_unspilled(db.guid)
        self.stats.spilled_objects -= 1
        if db.spill_offset >= 0:
            self._spill_release(node, db.spill_offset, db.size)
            db.spill_offset = -1

    def _execute(self, edt: EdtObj) -> None:
        edt.state = "running"
        edt.start_time = self.clock
        tmpl: TemplateObj = self.lookup(edt.template)
        depv = []
        io_wait = 0.0
        for s, mode in zip(edt.slots, edt.modes):
            if isinstance(s, Guid) and s.kind == ObjectKind.DATABLOCK:
                db = self.lookup(s)
                if self.io_mode == "sync" and db.buffer is None:
                    # sync baseline: the reads happen inside the task's
                    # window, charged per chunk to its blocking time.
                    # charge_sync returns (op done - now): ops on one
                    # node's disk queue already serialize against each
                    # other, so the task blocks until the *latest* one —
                    # max, not sum (summing double-counts the queueing).
                    # Spilled blocks charge their spill-file read the
                    # same way, keeping the sync-vs-async comparison fair
                    if db.spilled:
                        sn = self.nodes[db.guid.node]
                        io_wait = max(io_wait, self.io.charge_sync(
                            db, None, "read", path=sn.spill_path,
                            offset=db.spill_offset))
                    elif db.lazy_file_read and db.file_guid is not None:
                        f: FileObj = self.lookup(db.file_guid)
                        io_wait = max(io_wait,
                                      self.io.charge_sync(db, f, "read"))
                buf = self._materialize(db)
                if mode in (DbMode.RO, DbMode.CONST):
                    view = buf.view()
                    view.setflags(write=False)
                else:
                    view = buf
                depv.append(DepEntry(guid=s, ptr=view, mode=mode))
            else:
                depv.append(DepEntry(guid=s if isinstance(s, Guid) else NULL_GUID,
                                     ptr=None, mode=mode))
        ctx = TaskCtx(self, edt.node, edt)
        ctx.blocking_time += io_wait
        if io_wait > 0:
            # the task spends [now, now + io_wait) blocked on its own
            # charged IO — that is not compute, so it must not count
            # toward io_overlap_ticks until the wait elapses
            heapq.heappush(self._heap, (self.clock + io_wait,
                                        next(self._tick), "task_compute", None))
        else:
            self._running_tasks += 1
        self._log("RUN", edt.guid, tmpl.func.__name__)
        if self._san is None:
            ret = tmpl.func(list(edt.paramv), depv, ctx)
        else:
            # the body runs under its own activity; nested synchronous
            # grants (API calls that grant immediately) stack correctly
            tok = self._san.task_begin(edt.guid)
            try:
                ret = tmpl.func(list(edt.paramv), depv, ctx)
            finally:
                self._san.ctx_end(tok)
        self.stats.tasks_executed += 1
        end = edt.start_time + edt.duration + ctx.blocking_time
        edt.end_time = end
        if self._mon is not None:
            # per-EDT-class latency histograms: virtual time spent
            # ready-but-ungranted, and the task's occupied window
            self._mon.on_edt(
                tmpl.func.__name__,
                edt.start_time - edt.ready_time if edt.ready_time >= 0.0
                else 0.0,
                end - edt.start_time)
        heapq.heappush(self._heap, (end, next(self._tick), "task_end", (edt.guid, ret)))

    def _task_end(self, payload: Tuple[Guid, Any]) -> None:
        guid, ret = payload
        self._running_tasks = max(0, self._running_tasks - 1)
        edt: Optional[EdtObj] = self.try_lookup(guid)
        if edt is None:
            # the EDT's node fail-stopped mid-execution (e.g. the body
            # itself called kill_node): nothing retires, nothing satisfies
            # — locks it held on surviving nodes' blocks stay held, the
            # standard fail-stop hazard a recovery layer must handle
            if self._san is not None:
                self._san.task_lost(guid)
            return
        if self._san is None:
            self._task_retire(guid, ret, edt)
            return
        # retirement (lock releases, output-event satisfy, wakes) runs
        # under the task's clock, one tick past the body; the clock then
        # folds into the driver's join set at run() return
        tok = self._san.task_end_begin(guid)
        try:
            self._task_retire(guid, ret, edt)
        finally:
            self._san.task_end_finish(guid, tok)

    def _task_retire(self, guid: Guid, ret: Any, edt: EdtObj) -> None:
        released: List[DbObj] = []
        for db, mode in self._dep_dbs(edt):
            if mode in (DbMode.RO, DbMode.CONST):
                db.readers = max(0, db.readers - 1)
                if self._san is not None:
                    self._san.on_release(db, False)
            elif db.writer == guid:
                db.writer = None
                if self._san is not None:
                    self._san.on_release(db, True)
            if db.pending_destroy and not db.locked():
                self._destroy_db(db)   # wakes its waiters itself
            else:
                released.append(db)
        edt.state = "done"
        # releases can turn blocks spillable: invalidate the fruitless-scan
        # guard of every node whose lock state just changed, and run the
        # spill check there too — a pure data-holder node whose blocks are
        # only ever locked by remote tasks has no retirements of its own
        spill_nodes = {edt.node}
        for db in released:
            self.nodes[db.guid.node].spill_scan_at = -1.0
            spill_nodes.add(db.guid.node)
        if edt.output_event is not None:
            ret_r = self.resolve(ret) if ret is not None else NULL_GUID
            if isinstance(ret_r, Guid) and ret_r.kind == ObjectKind.EVENT and not is_null(ret_r):
                self.send(MDep(source=ret_r, dest=edt.output_event, slot=0,
                               mode=DbMode.RO), edt.node, ret_r.node)
            else:
                self.send(MSatisfy(target=edt.output_event, slot=0,
                                   db=ret_r if isinstance(ret_r, Guid) else NULL_GUID),
                          edt.node, self._owner(edt.output_event))
        self.nodes[edt.node].objects.pop(guid, None)
        # wake only waiters of the DBs whose lock state actually changed
        for db in released:
            self._wake_waiters(db.guid)
        # task retirement is the spill checkpoint: blocks it released are
        # idle now, and no task body is mid-execution anywhere (the DES
        # runs bodies atomically), so buffers snapshot consistently
        for n in sorted(spill_nodes):
            self._maybe_spill(n)

    # -- cold-object spill ---------------------------------------------------

    def spill_check(self, node_idx: int) -> None:
        """Public eviction hook: re-run the spill policy on ``node_idx`` now.

        The serve engine calls this after demoting a session's pages into
        its archive block — the archive is brand-new resident memory the
        task-retirement trigger hasn't seen yet."""
        self.nodes[node_idx].spill_scan_at = -1.0
        self._maybe_spill(node_idx)

    def _maybe_spill(self, node_idx: int) -> None:
        """Spill cold data blocks if ``node_idx`` is over ``spill_threshold``.

        Policy: when a node holds more buffer-resident data blocks than the
        threshold, idle unlocked ones (no lock holders, no waiters, no live
        partitions, not a §6 view, no IO in flight) are written back to the
        node's private spill file, least-recently-granted first, until the
        resident count is back under the threshold or no candidates remain.
        Contiguously-placed victims share one IO-queue write op.  The
        buffer is dropped only when the spill op *completes*, so a halted
        ``run(until)`` or a fail-stop loses exactly the in-flight spill
        ops, never object payloads (PR 3's IO crash contract).
        """
        thr = self.spill_threshold
        if thr is None:
            return
        node = self.nodes[node_idx]
        if not node.alive:
            return
        if node.compact_inflight:
            # a compaction sweep owns the file layout (it will clear the
            # free list and shrink the tail at completion); new spills
            # wait for the sweep's MIoDone rather than allocating into it
            return
        # resident_dbs counts blocks owning their buffer (views alias a
        # parent's memory; spilled/unread/write_only/no_acquire hold none)
        # and is maintained incrementally, so this threshold check is O(1)
        # per task retirement; blocks with a spill op already in flight are
        # being drained and don't count against the threshold again
        need = node.resident_dbs - node.spill_inflight - thr
        if need <= 0:
            return
        if node.spill_scan_at == self.clock:
            # the last scan at this timestamp found nothing spillable and
            # nothing was released since (releases clear the guard) —
            # skip the O(objects) victim walk
            return
        # access-recency policy: least-recently-granted first (ties broken
        # by creation order, the old oldest-seq policy).  A hot old block —
        # a long-lived serve session's pages — now outlives colder younger
        # ones instead of being evicted for merely being old.
        cands = []
        for _idx, shard in node.objects.shards(ObjectKind.DATABLOCK):
            cands.extend(o for o in shard.objs.values() if self._spillable(o))
        if not cands:
            node.spill_scan_at = self.clock
            return
        cands.sort(key=lambda d: (d.last_touch, d.guid.seq))
        self._spill_shard(node, cands[:need])   # never spill below threshold

    def _spillable(self, db: Any) -> bool:
        return (isinstance(db, DbObj) and db.buffer is not None
                and not db.spilled and not db.spilling and not db.io_pending
                and not db.locked() and not db.partitions and not db.is_view
                and not db.pending_destroy and not db.destroyed
                and getattr(db, "ready", True)
                and not self._db_waiters.get(db.guid))

    def _spill_alloc(self, node: _Node, size: int) -> int:
        """Place ``size`` spill bytes: first-fit from the free list of
        holes left by re-materialized/destroyed victims, else bump the
        tail.  Reuse counts in ``Stats.spill_slots_reused``."""
        for i, (off, sz) in enumerate(node.spill_free):
            if sz >= size:
                if sz == size:
                    node.spill_free.pop(i)
                else:
                    node.spill_free[i] = (off + size, sz - size)
                self.stats.spill_slots_reused += 1
                return off
        off = node.spill_tail
        node.spill_tail += size
        return off

    def _spill_release(self, node: _Node, off: int, size: int) -> None:
        """Return a spill-file range to the free list, coalescing adjacent
        holes; a hole ending at the tail shrinks the high-water mark."""
        if off < 0 or size <= 0:
            return
        holes = sorted(node.spill_free + [(off, size)])
        merged: List[Tuple[int, int]] = []
        for o, s in holes:
            if merged and merged[-1][0] + merged[-1][1] == o:
                merged[-1] = (merged[-1][0], merged[-1][1] + s)
            else:
                merged.append((o, s))
        if merged and merged[-1][0] + merged[-1][1] == node.spill_tail:
            node.spill_tail = merged.pop()[0]
        node.spill_free = merged
        if self.spill_compact_threshold is not None:
            self._maybe_compact(node)

    def _spill_shard(self, node: _Node, victims: List[DbObj]) -> None:
        """Serialize cold blocks into the node's spill file through the §5
        IO queue.  Offsets come from the free list first (slot reuse),
        then the tail; victims placed contiguously share one disk op."""
        if node.spill_path is None:
            fd, path = tempfile.mkstemp(prefix=f"ocr-spill-n{node.idx}-",
                                        suffix=".bin")
            os.close(fd)
            node.spill_path = path
        placed: List[Tuple[DbObj, int, bytes]] = []
        for db in victims:
            data = db.buffer.tobytes()
            placed.append((db, self._spill_alloc(node, len(data)), data))
            db.spilling = True
        node.spill_inflight += len(victims)
        placed.sort(key=lambda t: t[1])

        def _flush(run: List[Tuple[DbObj, int, bytes]]) -> None:
            meta = [(db.guid, off, len(data), db.version)
                    for db, off, data in run]
            self.io.submit_spill(node.idx, node.spill_path, run[0][1],
                                 b"".join(d for _, _, d in run), meta)

        run: List[Tuple[DbObj, int, bytes]] = []
        for entry in placed:
            if run and run[-1][1] + len(run[-1][2]) != entry[1]:
                _flush(run)
                run = []
            run.append(entry)
        if run:
            _flush(run)
        if self._san is not None:
            self._san.on_spill(len(victims), node.idx)
        self._log("SPILL", len(victims), "blocks ->", node.spill_path)

    def _finish_spill(self, op: Any) -> None:
        """A shard's spill op completed: the OS write happens now, and each
        victim that stayed cold drops its buffer.  Victims that got hot
        again (acquired, destroyed, re-versioned by a write or copy) abort
        — their bytes in the spill file are simply never referenced."""
        if not op.performed and op.data is not None:
            _write_file_region(op.path, op.offset,
                               np.frombuffer(op.data, dtype=np.uint8))
        for gid, off, _size, version in op.victims:
            node = self.nodes[gid.node]
            node.spill_inflight = max(0, node.spill_inflight - 1)
            db = self.try_lookup(gid)
            if db is None or not isinstance(db, DbObj) or not db.spilling:
                if node.alive:      # reclaim the slot reserved at submit
                    self._spill_release(node, off, _size)
                continue
            db.spilling = False
            if (db.version != version or db.locked() or db.partitions
                    or db.buffer is None or db.pending_destroy
                    or self._db_waiters.get(gid)):
                # hot again: keep the live buffer, free the reserved slot
                self._spill_release(node, off, _size)
                continue
            db.buffer = None
            db.spilled = True
            db.spill_offset = off
            node.spilled += 1
            node.resident_dbs -= 1
            node.objects.note_spilled(gid)
            self.stats.spilled_objects += 1
        self._log("SPILLED", len(op.victims), "victims (op done)")

    def _maybe_compact(self, node: _Node) -> None:
        """On-line spill-file compaction (the ROADMAP 'remaining' item):
        when the free-list holes exceed ``spill_compact_threshold`` as a
        fraction of the bump pointer, submit one IO-queue sweep that will
        rewrite every live slot packed from offset 0 and shrink the tail.

        The plan is snapshotted at submit (guid, old offset, new offset,
        size, version per victim) and only attempted when the node is
        quiescent on the spill front — no spill writes in flight, no
        unspill read pending on any live slot — so the sweep either
        applies exactly or aborts wholesale at completion."""
        thr = self.spill_compact_threshold
        if (thr is None or node.compact_inflight or not node.alive
                or node.spilled == 0 or node.spill_inflight > 0
                or node.spill_path is None or node.spill_tail <= 0):
            return
        frag = sum(sz for _off, sz in node.spill_free)
        if frag <= 0 or frag < thr * node.spill_tail:
            return
        live: List[DbObj] = []
        for _idx, shard in node.objects.shards(ObjectKind.DATABLOCK):
            for o in shard.objs.values():
                if isinstance(o, DbObj) and o.spilled and not o.destroyed:
                    if o.io_pending:
                        return      # an unspill read is mid-flight: retry
                    live.append(o)  # on the next release
        if not live:
            return
        live.sort(key=lambda d: d.spill_offset)
        plan: List[Tuple[Guid, int, int, int, int]] = []
        cursor = 0
        for db in live:
            plan.append((db.guid, db.spill_offset, cursor, db.size,
                         db.version))
            cursor += db.size
        if all(old == new for _g, old, new, _s, _v in plan):
            return
        node.compact_inflight = True
        self.io.submit_compact(node.idx, node.spill_path, plan, cursor)
        self._log("COMPACT", node.idx,
                  f"{frag}B holes / {node.spill_tail}B tail,"
                  f" {len(plan)} live slots")

    def _finish_compact(self, op: Any) -> None:
        """The compaction sweep's disk slot completed: re-verify the plan
        (every victim still spilled at its snapshot offset and version,
        no read in flight — any mismatch aborts the whole sweep, since a
        concurrent unspill may be reading the old layout), then move live
        slots down in offset order (moves are strictly downward, so
        in-place is safe), clear the free list, and shrink the tail."""
        node = self.nodes[op.node]
        node.compact_inflight = False
        if not node.alive or node.spill_path is None:
            return
        moves: List[Tuple[DbObj, int, int, int]] = []
        for gid, old, new, size, version in op.victims:
            db = self.try_lookup(gid)
            if (db is None or not isinstance(db, DbObj) or not db.spilled
                    or db.io_pending or db.spill_offset != old
                    or db.version != version):
                self._log("COMPACT abort", node.idx, gid)
                # the layout changed under the sweep (a victim was
                # destroyed or is being read back); re-plan immediately
                # against the current free list — if a read is still in
                # flight the re-plan defers to that read's release
                self._maybe_compact(node)
                return
            moves.append((db, old, new, size))
        for db, old, new, size in moves:
            if new != old:
                data = _read_file_region(node.spill_path, old, size)
                _write_file_region(node.spill_path, new, data)
                db.spill_offset = new
        node.spill_free = []
        node.spill_tail = op.size
        try:
            with open(node.spill_path, "r+b") as f:
                f.truncate(op.size)
        except OSError:
            pass
        self.stats.spill_compactions += 1
        self._refresh_table_stats()
        self._log("COMPACTED", node.idx, f"tail -> {op.size}B")
        # spills deferred while the sweep was in flight can go now
        node.spill_scan_at = -1.0
        self._maybe_spill(node.idx)

    # -- destruction ---------------------------------------------------------

    def _on_MDestroy(self, msg: MDestroy) -> None:
        self.destroy(self.resolve(msg.target))

    def destroy(self, gid: Guid) -> None:
        obj = self.try_lookup(gid)
        if obj is None:
            return
        if isinstance(obj, DbObj):
            if obj.locked() or obj.partitions:
                # acquired by a running task, or has live partitions (§6.2):
                # defer destruction until release / last partition destroyed
                obj.pending_destroy = True
                return
            self._destroy_db(obj)
        else:
            obj.destroyed = True
            self.nodes[gid.node].objects.pop(gid, None)

    def _destroy_db(self, db: DbObj) -> None:
        if db.partitions:
            raise OcrError(f"destroying {db.guid} while partitions are live")
        if self._san is not None:
            # checks §6.2 child-first order against the sanitizer's own
            # registry; a destroyed partition folds its lock history into
            # the parent's release clock (quiescence edge)
            self._san.on_db_destroyed(db)
        if db.spilled:
            if db.file_guid is not None and db.dirty:
                # a dirty §5 chunk must write back its real contents below:
                # re-materialize from the spill file first
                self._materialize(db)
            else:
                self._clear_spill(db)   # accounting only; bytes are dead
        # copies issued before a same-timestamp destroy must land first
        # (batching must not reorder them past the destruction)
        if self._copy_batch and any(
                db.guid in (self.resolve(m.src), self.resolve(m.dst))
                for m in self._copy_batch):
            self._flush_copy_batch()
        # unlink from parent partition table
        if db.parent is not None:
            parent = self.try_lookup(db.parent)
            if parent is not None:
                parent.partitions.pop(db.guid, None)
                if not parent.partitions:
                    parent.static_partitioning = False
                    if parent.pending_destroy and not parent.locked():
                        self._destroy_db(parent)
                    else:
                        # last partition gone: the parent is acquirable again
                        self._wake_waiters(parent.guid)
        # §5 write-back: dirty chunks flush; enlarging chunks enlarge.
        # Async mode enqueues the write on the node's IO queue (adjacent
        # dirty ranges coalesce; the OS write lands at completion time);
        # sync mode writes here, charging the same per-chunk latency.
        if db.file_guid is not None:
            f: FileObj = self.lookup(db.file_guid)
            if db.dirty and f.writable and db.buffer is not None:
                if self.io_mode == "async":
                    self.io.submit_write(db, f)
                else:
                    self.io.charge_sync(db, f, "write")
                    _write_file_region(f.path, db.file_offset, db.buffer)
                    self.stats.file_bytes_written += db.size
            elif f.writable and db.file_offset + db.size > _file_size(f.path):
                _enlarge_file(f.path, db.file_offset + db.size)
            f.chunks.pop(db.guid, None)
            if f.released and not f.chunks:
                f.closed = True
        db.destroyed = True
        if db.buffer is not None and not db.is_view:
            self.nodes[db.guid.node].resident_dbs -= 1
        self.nodes[db.guid.node].objects.pop(db.guid, None)
        self._ancestor_cache.pop(db.guid, None)
        # waiters parked on a destroyed DB retry with the dep dropped
        self._wake_waiters(db.guid)

    # -- labeled maps (§4) ----------------------------------------------------

    def _on_MMapGet(self, msg: MMapGet) -> None:
        map_id = self.resolve(msg.map_id)
        m = self.try_lookup(map_id) if isinstance(map_id, Guid) else None
        # a map_get racing a map_destroy must fail clean, not touch the
        # destroyed map's entries/creator (AttributeError / stale creator)
        if m is None or not isinstance(m, MapObj) or m.destroyed:
            raise OcrError(
                f"map_get on destroyed or unknown map {map_id} "
                f"(index {msg.index}): the map was destroyed before the "
                f"get arrived")
        if not (0 <= msg.index < m.size):
            raise OcrError(f"map index {msg.index} out of range [0,{m.size})")
        created = msg.index not in m.entries
        if msg.index not in m.entries:
            # exactly-once creation, synchronized at the owning node
            m.creator_calls += 1
            self.stats.creator_calls += 1
            object_lid = self._alloc_lid(m.guid.node)
            ctx = TaskCtx(self, m.guid.node, None)
            ctx._mapped_lid = object_lid
            m.creator(ctx, object_lid, msg.index, list(m.paramv), list(m.guidv))
            bound = self.nodes[m.guid.node].lid_table.get(object_lid)
            if bound is None:
                raise OcrError(
                    "creator function must create the object with "
                    "EDT_PROP_MAPPED binding the provided LID")
            m.entries[msg.index] = bound
        guid = m.entries[msg.index]
        if self._san is not None:
            # §4: exactly-once creation, memoized reuse per index
            self._san.on_map_get(m, msg.index, created, guid)
        if msg.lid is not None:
            self._pending_lid_msg.pop(msg.lid, None)
            self.send(MMap(lid=msg.lid, guid=guid), msg.dst_node, msg.lid.node)

    # -- db copy (§6.3) --------------------------------------------------------

    def _on_MDbCopy(self, msg: MDbCopy) -> None:
        # Materialized range copies (plain, or §6.3 partition copies that do
        # not take the zero-copy view path) are batched: all copies landing
        # at the same virtual timestamp flush together, one fused kernel
        # launch per (src, dst) pair, instead of one launch per partition.
        if self._is_batchable_copy(msg):
            self._copy_batch.append(msg)
            if not self._copy_flush_scheduled:
                self._copy_flush_scheduled = True
                heapq.heappush(self._heap,
                               (self.clock, next(self._tick), "copy_flush", None))
            return
        # a non-batchable copy (zero-copy view, PARTITION_BACK) executes
        # immediately; land earlier-arrived batched copies first so the
        # batch cannot be reordered past it (arrival-order semantics)
        if self._copy_batch:
            self._flush_copy_batch()
        self._do_db_copy(msg)

    def _is_batchable_copy(self, msg: MDbCopy) -> bool:
        if msg.copy_type == DB_COPY_PARTITION_BACK:
            return False       # entails destruction of src: keep synchronous
        if msg.copy_type == DB_COPY_PARTITION:
            dst: DbObj = self.lookup(self.resolve(msg.dst))
            whole_dst = msg.dst_offset == 0 and msg.size == dst.size
            if dst.no_acquire and whole_dst and dst.buffer is None:
                return False   # zero-copy view path: no bytes move
        return True

    def _flush_copy_batch(self) -> None:
        batch, self._copy_batch = self._copy_batch, []
        self._copy_flush_scheduled = False
        if not batch:
            return
        resolved = [(self.resolve(m.src), self.resolve(m.dst), m)
                    for m in batch]
        # Grouping by (src, dst) reorders copies across groups, which is
        # only sound when arrival order cannot matter: no copy reads a DB
        # another copy writes, and no destination byte is written twice.
        # Otherwise replay the batch sequentially (seed semantics:
        # last-writer-wins in arrival order, reads see earlier writes).
        dst_ids = {d for _, d, _ in resolved}
        ordered = any(s in dst_ids for s, _, _ in resolved)
        if not ordered:
            by_dst: Dict[Guid, List[Tuple[int, int]]] = {}
            for _, d, m in resolved:
                by_dst.setdefault(d, []).append(
                    (m.dst_offset, m.dst_offset + m.size))
            ordered = any(spans_overlap(s) for s in by_dst.values())
        if ordered:
            for src_id, dst_id, m in resolved:
                tok = self._san.copy_begin(m) if self._san is not None else None
                try:
                    src = self.lookup(src_id)
                    dst = self.lookup(dst_id)
                    if self._san is not None:
                        self._san.on_copy_access(src, m.src_offset, m.size, False)
                        self._san.on_copy_access(dst, m.dst_offset, m.size, True)
                    sbuf = self._materialize(src)
                    dbuf = self._materialize(dst)
                    dst.version += 1
                    dbuf[m.dst_offset: m.dst_offset + m.size] = \
                        sbuf[m.src_offset: m.src_offset + m.size]
                    self._copy_done(m)
                finally:
                    if tok is not None:
                        self._san.copy_end(tok)
            return
        groups: Dict[Tuple[Guid, Guid], List[MDbCopy]] = {}
        for src_id, dst_id, msg in resolved:
            groups.setdefault((src_id, dst_id), []).append(msg)
        for (src_id, dst_id), msgs in groups.items():
            src: DbObj = self.lookup(src_id)
            dst: DbObj = self.lookup(dst_id)
            sbuf = self._materialize(src)
            dbuf = self._materialize(dst)
            dst.version += 1
            ranges = [(m.dst_offset, m.src_offset, m.size) for m in msgs]
            if not self._fused_copy(dbuf, sbuf, ranges):
                for (d_off, s_off, size) in ranges:
                    dbuf[d_off: d_off + size] = sbuf[s_off: s_off + size]
            for m in msgs:
                if self._san is None:
                    self._copy_done(m)
                    continue
                tok = self._san.copy_begin(m)
                try:
                    self._san.on_copy_access(src, m.src_offset, m.size, False)
                    self._san.on_copy_access(dst, m.dst_offset, m.size, True)
                    self._copy_done(m)
                finally:
                    self._san.copy_end(tok)

    def _copy_done(self, m: MDbCopy) -> None:
        self.stats.bytes_copied += m.size
        ev = self.resolve(m.completion_event)
        if isinstance(ev, Guid) and not is_null(ev):
            self.send(MSatisfy(target=ev, slot=0, db=NULL_GUID),
                      m.dst_node, ev.node)

    def _fused_copy(self, dbuf: np.ndarray, sbuf: np.ndarray,
                    ranges: List[Tuple[int, int, int]]) -> bool:
        """Route a multi-range copy through the fused Pallas kernel.

        Returns False (caller falls back to numpy) unless the backend is
        enabled, the batch is big enough to amortize a launch, every range
        is lane-aligned (128 B) and non-empty, destinations are disjoint
        (overlaps need the sequential last-writer-wins semantics of the
        numpy path), and jax is importable.
        """
        if self.copy_backend != "pallas" or len(ranges) < 2:
            return False
        if any(d % 128 or s % 128 or n % 128 or n <= 0 for d, s, n in ranges):
            return False
        if spans_overlap((d, d + n) for d, _, n in ranges):
            return False
        try:
            from ..kernels import ops
        except Exception:       # jax unavailable: gate, don't require it
            return False
        out = ops.multi_partition_copy_bytes(dbuf, sbuf, tuple(ranges))
        dbuf[:] = np.asarray(out)
        self.stats.fused_copies += 1
        return True

    def _do_db_copy(self, msg: MDbCopy) -> None:
        if self._san is None:
            self._do_db_copy_inner(msg)
            return
        tok = self._san.copy_begin(msg)
        try:
            self._do_db_copy_inner(msg)
        finally:
            self._san.copy_end(tok)

    def _do_db_copy_inner(self, msg: MDbCopy) -> None:
        dst: DbObj = self.lookup(self.resolve(msg.dst))
        src: DbObj = self.lookup(self.resolve(msg.src))
        if msg.copy_type == DB_COPY_PARTITION:
            whole_dst = msg.dst_offset == 0 and msg.size == dst.size
            if dst.no_acquire and whole_dst and dst.buffer is None:
                # zero-copy: dst becomes a partition view of src (COW)
                if src.overlaps(msg.src_offset, msg.size):
                    raise PartitionOverlapError(
                        f"copy-partition [{msg.src_offset},+{msg.size}) overlaps "
                        f"a live partition of {src.guid}")
                buf = self._materialize(src)
                dst.buffer = buf[msg.src_offset: msg.src_offset + msg.size]
                dst.is_view = True
                dst.parent = src.guid
                dst.offset_in_parent = msg.src_offset
                src.partitions[dst.guid] = (msg.src_offset, msg.size)
                if self._san is not None:
                    # no bytes move: register the §6 child, no access
                    self._san.on_partition_create(
                        src, [(dst.guid, msg.src_offset, msg.size)],
                        zero_copy=True)
                # the view can mutate src's bytes without touching src's
                # lock state: an in-flight spill snapshot of src is stale
                src.version += 1
                self.stats.bytes_zero_copy += msg.size
                # dst gained an ancestor: cached chains keyed by (or passing
                # through) dst are stale, and every EDT's cached §6.2 result
                # may be too — bump the epoch so retries re-check lazily
                self._ancestor_cache = {
                    g: ch for g, ch in self._ancestor_cache.items()
                    if g != dst.guid and dst.guid not in ch}
                self._partition_epoch += 1
            else:
                if self._san is not None:
                    self._san.on_copy_access(src, msg.src_offset, msg.size, False)
                    self._san.on_copy_access(dst, msg.dst_offset, msg.size, True)
                sbuf = self._materialize(src)
                dbuf = self._materialize(dst)
                dst.version += 1
                dbuf[msg.dst_offset: msg.dst_offset + msg.size] = \
                    sbuf[msg.src_offset: msg.src_offset + msg.size]
                self.stats.bytes_copied += msg.size
        elif msg.copy_type == DB_COPY_PARTITION_BACK:
            aligned_view = (
                src.is_view and src.parent == dst.guid
                and src.offset_in_parent == msg.dst_offset and msg.size == src.size)
            if aligned_view:
                self.stats.bytes_zero_copy += msg.size  # nothing moves
            else:
                if self._san is not None:
                    self._san.on_copy_access(src, msg.src_offset, msg.size, False)
                    self._san.on_copy_access(dst, msg.dst_offset, msg.size, True)
                sbuf = self._materialize(src)
                dbuf = self._materialize(dst)
                dst.version += 1
                dbuf[msg.dst_offset: msg.dst_offset + msg.size] = \
                    sbuf[msg.src_offset: msg.src_offset + msg.size]
                self.stats.bytes_copied += msg.size
            self._destroy_db(src)  # PARTITION_BACK entails destruction of src
        else:
            if self._san is not None:
                self._san.on_copy_access(src, msg.src_offset, msg.size, False)
                self._san.on_copy_access(dst, msg.dst_offset, msg.size, True)
            sbuf = self._materialize(src)
            dbuf = self._materialize(dst)
            dst.version += 1
            dbuf[msg.dst_offset: msg.dst_offset + msg.size] = \
                sbuf[msg.src_offset: msg.src_offset + msg.size]
            self.stats.bytes_copied += msg.size
        ev = self.resolve(msg.completion_event)
        if isinstance(ev, Guid) and not is_null(ev):
            self.send(MSatisfy(target=ev, slot=0, db=NULL_GUID),
                      msg.dst_node, ev.node)

    # -- file IO (§5) -----------------------------------------------------------

    def _on_MIoDone(self, msg: MIoDone) -> None:
        """One async disk op completed: perform the OS IO, wake waiters."""
        op = msg.op
        self.io.complete(op)
        if op.kind == "read":
            db = self.try_lookup(op.db)
            if db is None:
                return                       # destroyed while in flight
            db.io_pending = False
            if not op.performed and db.buffer is None:
                if db.spilled and op.file is None:
                    # re-materialization of a spilled block (spill-file read)
                    db.buffer = _read_file_region(op.path, op.offset, op.size)
                    self._clear_spill(db)
                    self.nodes[db.guid.node].resident_dbs += 1
                elif db.lazy_file_read:
                    db.buffer = _read_file_region(op.path, op.offset, op.size)
                    db.lazy_file_read = False
                    self.stats.file_bytes_read += op.size
                    self.nodes[db.guid.node].resident_dbs += 1
            self._log("IO done (read)", op.db)
            # grants deferred on the IO-pending block retry now
            self._wake_waiters(db.guid)
        elif op.kind == "spill":
            self._finish_spill(op)
        elif op.kind == "compact":
            self._finish_compact(op)
        else:
            if not op.performed and op.data is not None:
                _write_file_region(op.path, op.offset,
                                   np.frombuffer(op.data, dtype=np.uint8))
                self.stats.file_bytes_written += op.size
                self._log("IO done (write)",
                          f"{op.path}[{op.offset},+{op.size}) x{op.chunks}")

    def _on_MFileOpened(self, msg: MFileOpened) -> None:
        f: FileObj = self.lookup(msg.file_guid)
        f.size = msg.size
        desc: DbObj = self.lookup(self.resolve(msg.descriptor_db))
        buf = self._materialize(desc)
        key = len(self.file_registry)
        self.file_registry.append(f.guid)
        buf[:16] = np.frombuffer(struct.pack("<QQ", msg.size, key), dtype=np.uint8)
        desc.ready = True
        pend = desc.pending_deps
        desc.pending_deps = []
        for (dest, slot, _mode) in pend:
            self.send(MSatisfy(target=dest, slot=slot, db=desc.guid),
                      desc.guid.node, self._owner(dest))

    # -- forced LID resolution (§3 ocrGetGuid — the one blocking call) -----------

    def force_resolve(self, lid: Lid, ctx: Optional["TaskCtx"] = None) -> Guid:
        node = self.nodes[lid.node]
        g = node.lid_table.get(lid)
        if g is not None:
            return g
        self.stats.blocking_roundtrips += 1
        if ctx is not None:
            ctx.blocking_time += 2 * self.net_latency
        msg = self._pending_lid_msg.pop(lid, None)
        if msg is None:
            # the message may itself be deferred on another lid — resolve those
            for other, queue in list(node.deferred.items()):
                for m in queue:
                    if getattr(m, "lid", None) == lid:
                        self.force_resolve(other, ctx)
                        return self.force_resolve(lid, ctx)
            raise OcrError(f"no pending creation for {lid}")
        self._cancelled.add(msg.uid)
        if not self.nodes[msg.dst_node].alive:
            raise OcrError(
                f"cannot resolve {lid}: its creation targets node "
                f"{msg.dst_node}, which fail-stopped")
        # resolve any other lids the creation itself depends on
        for l in msg.lids():
            if l != lid and isinstance(l, Lid):
                self.force_resolve(l, ctx)
                msg.patch({l: self.nodes[l.node].lid_table[l]})
        if isinstance(msg, MCreate):
            guid = self._create_object(msg.dst_node, msg.kind, msg.payload)
        elif isinstance(msg, MMapGet):
            saved, msg.lid = msg.lid, None
            self._on_MMapGet(msg)
            m: MapObj = self.lookup(self.resolve(msg.map_id))
            guid = m.entries[msg.index]
            msg.lid = saved
        else:
            raise OcrError(f"cannot force-resolve via {type(msg).__name__}")
        self._apply_lid_binding(lid, guid)
        return guid


# ---------------------------------------------------------------- file helpers


def _file_size(path: str) -> int:
    try:
        return os.path.getsize(path)
    except OSError:
        return 0


def _read_file_region(path: str, offset: int, size: int) -> np.ndarray:
    buf = np.zeros(size, dtype=np.uint8)
    try:
        with open(path, "rb") as f:
            f.seek(offset)
            data = f.read(size)
        buf[: len(data)] = np.frombuffer(data, dtype=np.uint8)
    except OSError:
        pass
    return buf


def _write_file_region(path: str, offset: int, buf: np.ndarray) -> None:
    mode = "r+b" if os.path.exists(path) else "w+b"
    with open(path, mode) as f:
        f.seek(offset)
        f.write(buf.tobytes())


def _enlarge_file(path: str, new_size: int) -> None:
    mode = "r+b" if os.path.exists(path) else "w+b"
    with open(path, mode) as f:
        f.truncate(max(new_size, _file_size(path)))


# ------------------------------------------------------------------- Task API


class TaskCtx:
    """The OCR API surface bound to (runtime, node, current task) — the
    ``api`` argument every EDT body receives.  Mirrors the paper's functions
    with pythonic names; all calls are non-blocking except :meth:`get_guid`.
    """

    def __init__(self, rt: Runtime, node: int, edt: Optional[EdtObj]):
        self.rt = rt
        self.node = node
        self.edt = edt
        self.blocking_time = 0.0
        self._mapped_lid: Optional[Lid] = None

    # -- time of the current API call within the task's execution window
    @property
    def now(self) -> float:
        return self.rt.clock + self.blocking_time

    def _ref(self, x: Any) -> Any:
        """§3 scope check (sanitizer): an unbound LID referenced outside
        the scope that allocated it is an escape."""
        if self.rt._san is not None:
            self.rt._san.on_ref(x)
        return x

    # -- templates / EDTs ------------------------------------------------------

    def edt_template_create(self, func: Callable, paramc: int, depc: int) -> Guid:
        g = self.rt._alloc_guid(self.node, ObjectKind.TEMPLATE)
        self.rt.nodes[self.node].objects.insert(TemplateObj(g, func, paramc, depc))
        return g

    def edt_template_destroy(self, tmpl: Guid) -> None:
        self.rt.destroy(tmpl)

    def edt_create(
        self,
        template: Any,
        paramv: Sequence[Any] = (),
        depv: Optional[Sequence[Any]] = None,
        props: int = 0,
        output_event: bool = False,
        placement: Optional[int] = None,
        duration: float = 1.0,
        dep_modes: Optional[Sequence[DbMode]] = None,
        mapped_id: Optional[Lid] = None,
    ) -> Tuple[Any, Optional[Guid]]:
        """``ocrEdtCreate``.  Returns ``(id, output_event_guid)``.

        * default: blocks for the GUID when the target node is remote
          (cost: one round-trip of virtual time);
        * ``EDT_PROP_LID``: returns a LID immediately (§3);
        * ``EDT_PROP_MAPPED``: binds the map-provided ``mapped_id`` (§4).
        """
        tmpl = self.rt.resolve(self._ref(template))
        for d in depv or ():
            self._ref(d)
        depc = None
        t_obj = self.rt.try_lookup(tmpl) if isinstance(tmpl, Guid) else None
        if t_obj is not None:
            depc = t_obj.depc
        if depc is None:
            depc = len(depv or [])
        target = self.rt._pick_node(placement)
        out_ev = None
        if output_event:
            out_ev = self.event_create(EventKind.ONCE)
        payload = dict(template=tmpl, paramv=tuple(paramv), depv=list(depv or []),
                       depc=depc, output_event=out_ev, duration=duration,
                       dep_modes=list(dep_modes) if dep_modes else None)
        if props & EDT_PROP_MAPPED:
            lid = mapped_id if mapped_id is not None else self._mapped_lid
            if lid is None:
                raise OcrError("EDT_PROP_MAPPED requires the map-provided LID")
            guid = self.rt._create_edt(self.node if target is None else target, payload)
            self.rt._apply_lid_binding(lid, guid)
            return lid, out_ev
        if target == self.node:
            # local creation: a real GUID is free (§3: "the runtime may be
            # able to return a real GUID ... even without communication")
            guid = self.rt._create_edt(self.node, payload)
            return guid, out_ev
        if props & EDT_PROP_LID:
            lid = self.rt._alloc_lid(self.node)
            self.rt.send(MCreate(kind="edt", lid=lid, payload=payload),
                         self.node, target, at=self.now)
            return lid, out_ev
        # blocking GUID path: one synchronous round-trip
        self.rt.stats.blocking_roundtrips += 1
        self.blocking_time += 2 * self.rt.net_latency
        guid = self.rt._create_edt(target, payload)
        return guid, out_ev

    # -- events ---------------------------------------------------------------

    def _remote_create(self, kind: str, payload: Dict[str, Any],
                       target: int, props: int) -> Any:
        """§3 remote creation: ``EDT_PROP_LID`` returns a LID immediately
        (the ``MCreate`` travels with it), otherwise the call blocks one
        round-trip for the real GUID — shared by db/event creation."""
        if props & EDT_PROP_LID:
            lid = self.rt._alloc_lid(self.node)
            self.rt.send(MCreate(kind=kind, lid=lid, payload=payload),
                         self.node, target, at=self.now)
            return lid
        self.rt.stats.blocking_roundtrips += 1
        self.blocking_time += 2 * self.rt.net_latency
        return self.rt._create_object(target, kind, payload)

    def event_create(self, kind: EventKind = EventKind.ONCE, latch_count: int = 0,
                     placement: Optional[int] = None, props: int = 0) -> Any:
        """``ocrEventCreate``.  Local by default; with a remote ``placement``
        the event is created through the §3 ``MCreate`` path — ``EDT_PROP_LID``
        returns a LID immediately, otherwise one blocking round-trip."""
        payload = dict(kind=kind, latch_count=latch_count)
        target = self.node if placement is None \
            else self.rt._pick_node(placement)
        if target == self.node:
            return self.rt._create_event(self.node, payload).guid
        return self._remote_create("event", payload, target, props)

    def event_satisfy(self, event: Any, db: Any = NULL_GUID) -> None:
        tgt = self.rt.resolve(self._ref(event))
        self._ref(db)
        self.rt.send(MSatisfy(target=tgt, slot=0, db=self.rt.resolve(db)),
                     self.node, self.rt._owner(tgt), at=self.now)

    def event_destroy(self, event: Any) -> None:
        self.rt.send(MDestroy(target=self.rt.resolve(self._ref(event))),
                     self.node, self.rt._owner(event), at=self.now)

    def add_dependence(self, source: Any, dest: Any, slot: int,
                       mode: DbMode = DbMode.RO) -> None:
        src = self.rt.resolve(self._ref(source))
        dst = self.rt.resolve(self._ref(dest))
        if isinstance(src, Guid) and not is_null(src) \
                and not self.rt.nodes[src.node].alive:
            raise OcrError(
                f"dependence on {src}: node {src.node} fail-stopped "
                f"and its objects are lost")
        route = self.node if (is_null(src) or not isinstance(src, Guid)) \
            else src.node
        self.rt.send(MDep(source=src, dest=dst, slot=slot, mode=mode),
                     self.node, route, at=self.now)

    # -- data blocks ------------------------------------------------------------

    def db_create(self, size: int, props: int = 0,
                  placement: Optional[int] = None,
                  mapped_id: Optional[Lid] = None) -> Tuple[Any, Optional[np.ndarray]]:
        """``ocrDbCreate``.  Returns ``(id, ptr)``.

        Local by default.  With a remote ``placement`` the block is created
        on the target node through the §3 ``MCreate`` path and ``ptr`` is
        None (remote memory is only reachable through an acquire):
        ``EDT_PROP_LID`` returns a LID immediately, otherwise the call
        blocks one round-trip for the GUID.  ``EDT_PROP_MAPPED`` binds the
        map-provided ``mapped_id`` (§4) — a labeled-map creator can hand
        out data blocks (e.g. serve-engine request slots), not just EDTs.
        """
        payload = dict(size=size, props=props)
        target = self.node if placement is None \
            else self.rt._pick_node(placement)
        if props & EDT_PROP_MAPPED:
            lid = mapped_id if mapped_id is not None else self._mapped_lid
            if lid is None:
                raise OcrError("EDT_PROP_MAPPED requires the map-provided LID")
            db = self.rt._create_db(target, payload)
            self.rt._apply_lid_binding(lid, db.guid)
            return lid, db.buffer if target == self.node else None
        if target == self.node:
            db = self.rt._create_db(self.node, payload)
            return db.guid, db.buffer
        return self._remote_create("db", payload, target, props), None

    def db_release(self, db: Any) -> None:
        d: DbObj = self.rt.lookup(self.rt.resolve(self._ref(db)))
        if self.edt is not None and d.writer == self.edt.guid:
            d.writer = None
            if self.rt._san is not None:
                self.rt._san.on_release(d, True)
            self.rt.nodes[d.guid.node].spill_scan_at = -1.0
            if d.pending_destroy and not d.locked():
                self.rt._destroy_db(d)   # wakes its waiters itself
            else:
                self.rt._wake_waiters(d.guid)

    def db_destroy(self, db: Any) -> None:
        self.rt.send(MDestroy(target=self.rt.resolve(self._ref(db))),
                     self.node, self.rt._owner(db), at=self.now)

    def db_partition(self, db: Any, parts: Sequence[Tuple[int, int]],
                     props: int = 0) -> List[Guid]:
        """``ocrDbPartition`` (§6.2): split into disjoint contiguous partitions."""
        parent: DbObj = self.rt.lookup(self.rt.resolve(self._ref(db)))
        if parent.destroyed:
            raise OcrError(f"partitioning destroyed block {parent.guid}")
        if parent.static_partitioning and parent.partitions:
            raise PartitionStaticError(
                f"{parent.guid} has static partitioning; destroy all partitions first")
        # validate: in-bounds, mutually disjoint, disjoint from live partitions
        for i, (o, s) in enumerate(parts):
            if s <= 0 or o < 0 or o + s > parent.size:
                raise PartitionOverlapError(
                    f"partition [{o},+{s}) out of bounds of {parent.guid} (size {parent.size})")
            if parent.overlaps(o, s):
                raise PartitionOverlapError(
                    f"partition [{o},+{s}) overlaps a live partition of {parent.guid}")
            for j, (o2, s2) in enumerate(parts):
                if i < j and o < o2 + s2 and o2 < o + s:
                    raise PartitionOverlapError(
                        f"requested partitions [{o},+{s}) and [{o2},+{s2}) overlap")
        buf = self.rt._materialize(parent)
        # children write through the parent's buffer without touching its
        # lock state or version: abort any in-flight spill snapshot
        parent.version += 1
        out = []
        for (o, s) in parts:
            g = self.rt._alloc_guid(parent.guid.node, ObjectKind.DATABLOCK)
            # partitions of a file-mapped block inherit the file binding:
            # each child writes back exactly its own §6 byte range when
            # destroyed dirty (the sharded-checkpoint write path), instead
            # of the parent rewriting the whole chunk
            child = DbObj(guid=g, size=s, node=parent.guid.node,
                          buffer=buf[o: o + s], parent=parent.guid,
                          offset_in_parent=o, is_view=True,
                          file_guid=parent.file_guid,
                          file_offset=parent.file_offset + o)
            child.ready = True
            child.pending_deps = []
            self.rt.nodes[parent.guid.node].objects.insert(child)
            parent.partitions[g] = (o, s)
            out.append(g)
        if props & OCR_DB_PARTITION_STATIC:
            parent.static_partitioning = True
        if self.rt._san is not None:
            self.rt._san.on_partition_create(
                parent, [(g, o, s) for g, (o, s) in zip(out, parts)])
        return out

    def db_copy(self, dst: Any, dst_offset: int, src: Any, src_offset: int,
                size: int, copy_type: int = DB_COPY_PLAIN) -> Guid:
        """``ocrDbCopy`` (§6.3): asynchronous copy; returns a completion event."""
        ev = self.event_create(EventKind.ONCE)
        self.rt.send(
            MDbCopy(dst=self.rt.resolve(self._ref(dst)), dst_offset=dst_offset,
                    src=self.rt.resolve(self._ref(src)), src_offset=src_offset, size=size,
                    copy_type=copy_type, completion_event=ev),
            self.node, self.rt._owner(src), at=self.now)
        return ev

    # -- labeled maps (§4) ---------------------------------------------------------

    def map_create(self, size: int, creator: Callable, paramv: Sequence[Any] = (),
                   guidv: Sequence[Any] = (), placement: Optional[int] = None) -> Guid:
        node = self.node if placement is None else self.rt._pick_node(placement)
        g = self.rt._alloc_guid(node, ObjectKind.MAP)
        self.rt.nodes[node].objects.insert(MapObj(
            guid=g, size=size, creator=creator,
            paramv=tuple(paramv), guidv=tuple(guidv)))
        return g

    def map_get(self, map_id: Any, index: int) -> Any:
        """``ocrMapGet``: returns a LID immediately; never blocks (§4)."""
        m = self.rt.resolve(self._ref(map_id))
        owner = self.rt._owner(m)
        lid = self.rt._alloc_lid(self.node)
        self.rt.send(MMapGet(map_id=m, index=index, lid=lid),
                     self.node, owner, at=self.now)
        return lid

    def map_destroy(self, map_id: Any) -> None:
        self.rt.send(MDestroy(target=self.rt.resolve(self._ref(map_id))),
                     self.node, self.rt._owner(map_id), at=self.now)

    # -- file IO (§5) -----------------------------------------------------------------

    def file_open(self, path: str, mode: str = "rb") -> Tuple[Guid, Guid]:
        """``ocrFileOpen``: returns (file guid, descriptor-db guid).  The
        descriptor satisfies dependences only once the (async) open completes."""
        if mode not in ("rb", "rb+", "wb+"):
            raise FileModeError(f"unsupported file mode {mode!r}")
        g = self.rt._alloc_guid(self.node, ObjectKind.FILE)
        f = FileObj(guid=g, path=path, mode=mode)
        if mode == "wb+":
            with open(path, "w+b"):
                pass
        self.rt.nodes[self.node].objects.insert(f)
        desc, _ = self.db_create(16)
        d: DbObj = self.rt.lookup(desc)
        d.ready = False
        f.descriptor_db = desc
        size = _file_size(path)
        self.rt.send(MFileOpened(file_guid=g, descriptor_db=desc, size=size),
                     self.node, self.node, at=self.now + self.rt.io_latency)
        return g, desc

    @staticmethod
    def file_get_size(descriptor_ptr: np.ndarray) -> int:
        size, _ = struct.unpack("<QQ", bytes(descriptor_ptr[:16]))
        return size

    def file_get_guid(self, descriptor_ptr: np.ndarray) -> Guid:
        _, key = struct.unpack("<QQ", bytes(descriptor_ptr[:16]))
        return self.rt.file_registry[key]

    def file_get_chunk(self, file: Any, offset: int, size: int,
                       write_only: bool = False) -> Guid:
        """``ocrFileGetChunk``: map a contiguous file range into a data block.

        ``write_only`` chunks skip the lazy read entirely (the caller
        promises to overwrite the whole range — e.g. checkpoint writers),
        so no read op is charged for ranges whose prior contents are dead.
        """
        f: FileObj = self.rt.lookup(self.rt.resolve(self._ref(file)))
        if f.closed:
            raise OcrError(f"file {f.guid} already closed")
        if f.chunk_overlaps(offset, size):
            raise ChunkOverlapError(
                f"chunk [{offset},+{size}) overlaps a live chunk of {f.guid}")
        if offset + size > f.size and not f.writable:
            raise FileModeError(
                f"chunk [{offset},+{size}) extends past EOF of read-only file")
        g = self.rt._alloc_guid(self.node, ObjectKind.DATABLOCK)
        db = DbObj(guid=g, size=size, node=self.node, file_guid=f.guid,
                   file_offset=offset, lazy_file_read=not write_only)
        db.ready = True
        db.pending_deps = []
        self.rt.nodes[self.node].objects.insert(db)
        f.chunks[g] = (offset, size)
        if db.lazy_file_read and self.rt.io_mode == "async" \
                and self.rt.read_ahead:
            # §5 read-ahead: the fetch streams on the node's IO queue from
            # the moment the mapping exists, ahead of the first acquire
            self.rt.io.submit_read(db, f, at=self.now)
        return g

    def file_release(self, file: Any) -> None:
        f: FileObj = self.rt.lookup(self.rt.resolve(file))
        f.released = True
        if not f.chunks:
            f.closed = True

    # -- identity (§3) -------------------------------------------------------------------

    @staticmethod
    def get_id_type(x: Any) -> IdType:
        return id_type(x)

    def get_guid(self, x: Any) -> Guid:
        """``ocrGetGuid`` — the single blocking call of the API (§3)."""
        if isinstance(x, Guid):
            return x
        if isinstance(x, Lid):
            self._ref(x)
            return self.rt.force_resolve(x, self)
        raise OcrError(f"not an identifier: {x!r}")

    # -- control --------------------------------------------------------------------------

    def shutdown(self) -> None:
        self.rt.shutdown_requested = True


def spawn_main(rt: Runtime, func: Callable, paramv: Sequence[Any] = (),
               node: int = 0, duration: float = 1.0) -> Guid:
    """Create and immediately schedule the ``mainEdt`` equivalent."""
    ctx = TaskCtx(rt, node, None)
    tmpl = ctx.edt_template_create(func, len(paramv), 0)
    guid, _ = ctx.edt_create(tmpl, paramv=paramv, depv=[], duration=duration,
                             placement=node)
    return guid
