"""Runtime messages (paper §2/§3).

Every OCR API call translates into one or more messages.  Messages that
reference an unresolved :class:`~repro.core.guid.Lid` are *deferred* on the
receiving side until the ``MMap`` resolution for that LID arrives, at which
point the runtime patches the LID to the real GUID and re-submits the
message — exactly the M_create / M_dep / M_map protocol of §3.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional

from .guid import DbMode, Guid, Lid

_msg_counter = itertools.count()


@dataclasses.dataclass
class Message:
    """Base class; ``uid`` makes scheduler ordering deterministic."""

    src_node: int = dataclasses.field(init=False, default=-1)
    dst_node: int = dataclasses.field(init=False, default=-1)
    uid: int = dataclasses.field(init=False, default=-1)
    # sanitizer-only: sender's vector-clock snapshot, stamped at send time
    # when ``Runtime(sanitize=...)`` is on (class attr keeps the off path
    # allocation-free)
    _san_clock = None

    def stamp(self, src: int, dst: int) -> "Message":
        self.src_node = src
        self.dst_node = dst
        self.uid = next(_msg_counter)
        return self

    def lids(self) -> List[Lid]:
        """LIDs this message references (for deferred patching)."""
        return [x for x in self._id_fields() if isinstance(x, Lid)]

    def _id_fields(self) -> List[Any]:
        return []

    def patch(self, mapping: Dict[Lid, Guid]) -> None:
        """Replace resolved LIDs with GUIDs in-place."""
        raise NotImplementedError


def _patch_one(x: Any, mapping: Dict[Lid, Guid]) -> Any:
    if isinstance(x, Lid) and x in mapping:
        return mapping[x]
    return x


@dataclasses.dataclass
class MCreate(Message):
    """Create an object on ``dst_node``; bind it to ``lid`` (if any)."""

    kind: str = ""                      # "edt" | "event" | "db" | "template" | "map" | "file"
    lid: Optional[Lid] = None           # identity future to resolve
    payload: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def _id_fields(self):
        # Creation payloads may embed ids (e.g. template guid, guidv array)
        out: List[Any] = []
        for v in self.payload.values():
            if isinstance(v, (Guid, Lid)):
                out.append(v)
            elif isinstance(v, (list, tuple)):
                out.extend(e for e in v if isinstance(e, (Guid, Lid)))
        return out

    def patch(self, mapping):
        for k, v in list(self.payload.items()):
            if isinstance(v, (Lid, Guid)):
                self.payload[k] = _patch_one(v, mapping)
            elif isinstance(v, list):
                self.payload[k] = [_patch_one(e, mapping) for e in v]
            elif isinstance(v, tuple):
                self.payload[k] = tuple(_patch_one(e, mapping) for e in v)


@dataclasses.dataclass
class MMap(Message):
    """LID → GUID resolution, sent back to the LID's issuing node (§3 step 3)."""

    lid: Optional[Lid] = None
    guid: Optional[Guid] = None

    def patch(self, mapping):
        pass


@dataclasses.dataclass
class MDep(Message):
    """ocrAddDependence: source (event/db) → dest pre-slot."""

    source: Any = None
    dest: Any = None
    slot: int = 0
    mode: DbMode = DbMode.RO

    def _id_fields(self):
        return [self.source, self.dest]

    def patch(self, mapping):
        self.source = _patch_one(self.source, mapping)
        self.dest = _patch_one(self.dest, mapping)


@dataclasses.dataclass
class MSatisfy(Message):
    """ocrEventSatisfy: deliver ``db`` to ``target``'s ``slot``."""

    target: Any = None
    slot: int = 0
    db: Any = None

    def _id_fields(self):
        return [self.target, self.db]

    def patch(self, mapping):
        self.target = _patch_one(self.target, mapping)
        self.db = _patch_one(self.db, mapping)


@dataclasses.dataclass
class MDestroy(Message):
    target: Any = None

    def _id_fields(self):
        return [self.target]

    def patch(self, mapping):
        self.target = _patch_one(self.target, mapping)


@dataclasses.dataclass
class MMapGet(Message):
    """ocrMapGet request: resolve (map, index) to a GUID, binding ``lid``."""

    map_id: Any = None
    index: int = 0
    lid: Optional[Lid] = None

    def _id_fields(self):
        return [self.map_id]

    def patch(self, mapping):
        self.map_id = _patch_one(self.map_id, mapping)


@dataclasses.dataclass
class MDbCopy(Message):
    """ocrDbCopy (§6.3)."""

    dst: Any = None
    dst_offset: int = 0
    src: Any = None
    src_offset: int = 0
    size: int = 0
    copy_type: int = 0
    completion_event: Any = None

    def _id_fields(self):
        return [self.dst, self.src, self.completion_event]

    def patch(self, mapping):
        self.dst = _patch_one(self.dst, mapping)
        self.src = _patch_one(self.src, mapping)
        self.completion_event = _patch_one(self.completion_event, mapping)


@dataclasses.dataclass
class MIoDone(Message):
    """Completion of one asynchronous §5 disk operation (io_queue.IoOp).

    Delivered on the owning node at the op's virtual completion time; the
    real OS read/write happens at delivery, so operations in flight on a
    fail-stopped node (or past a ``run(until)`` horizon) are lost — the
    crash semantics checkpoint commit is built on.
    """

    op: Any = None

    def patch(self, mapping):
        pass


@dataclasses.dataclass
class MFileOpened(Message):
    """Asynchronous completion of ocrFileOpen: fills the descriptor DB (§5)."""

    file_guid: Optional[Guid] = None
    descriptor_db: Any = None
    size: int = 0

    def _id_fields(self):
        return [self.descriptor_db]

    def patch(self, mapping):
        self.descriptor_db = _patch_one(self.descriptor_db, mapping)
