"""The paper's primary contribution: OCR extensions as a composable runtime.

Local identifiers (§3), labeled GUID maps with creator functions (§4),
file-mapped data blocks (§5), and data block partitioning (§6) — realized
as a deterministic virtual-time multi-node runtime that the higher layers
(trainer, checkpointing, pipeline schedule, serving cache) build on.
"""
from .guid import (
    DB_COPY_PARTITION,
    DB_COPY_PARTITION_BACK,
    DB_COPY_PLAIN,
    DB_PROP_NO_ACQUIRE,
    EDT_PROP_LID,
    EDT_PROP_MAPPED,
    EDT_PROP_NONE,
    GUID_SHARD_BITS,
    OCR_DB_PARTITION_STATIC,
    DbMode,
    EventKind,
    Guid,
    IdType,
    Lid,
    NULL_GUID,
    ObjectKind,
    UNINITIALIZED_GUID,
    id_type,
    is_null,
    shard_index,
    shard_of,
    shard_span,
)
from .objects import (
    ChunkOverlapError,
    DepEntry,
    FileModeError,
    ObjectTable,
    OcrError,
    PartitionDeadlockError,
    PartitionOverlapError,
    PartitionStaticError,
)
from .runtime import Runtime, Stats, TaskCtx, spawn_main

__all__ = [
    "Runtime", "TaskCtx", "Stats", "spawn_main",
    "Guid", "Lid", "IdType", "ObjectKind", "EventKind", "DbMode",
    "NULL_GUID", "UNINITIALIZED_GUID", "id_type", "is_null",
    "GUID_SHARD_BITS", "shard_index", "shard_of", "shard_span",
    "ObjectTable",
    "EDT_PROP_NONE", "EDT_PROP_LID", "EDT_PROP_MAPPED",
    "DB_PROP_NO_ACQUIRE", "OCR_DB_PARTITION_STATIC",
    "DB_COPY_PLAIN", "DB_COPY_PARTITION", "DB_COPY_PARTITION_BACK",
    "OcrError", "PartitionOverlapError", "PartitionDeadlockError",
    "PartitionStaticError", "ChunkOverlapError", "FileModeError",
    "DepEntry",
]
