"""Latency-modeled asynchronous file IO (§5) — the per-node IO queue.

The paper's §5 file IO builds on data blocks precisely so an implementation
can overlap IO with compute and write back lazily.  This module is that
implementation: every chunk read/write becomes an :class:`IoOp` on the
owning node's virtual-time disk queue instead of a blocking call inside
``Runtime._materialize`` / ``Runtime._destroy_db``.

Model
-----
* Each node owns one disk.  An operation occupies the disk for
  ``Runtime.io_latency`` of virtual time (the per-chunk seek/roundtrip
  cost); requests queue FIFO per node (``start = max(now, disk_free)``).
* **Reads** are issued ahead of use ("read-ahead"): at ``file_get_chunk``
  time when ``Runtime.read_ahead`` is on, else at the first grant attempt
  of an acquiring EDT.  A data block with a read in flight is *IO-pending*:
  EDT grants defer on it through the ordinary waiter queues and resume
  when the :class:`~repro.core.messages.MIoDone` completion lands.
* **Writes** (dirty write-back at release/destroy) buffer for the current
  virtual timestamp and flush together, coalescing *adjacent* dirty ranges
  of one file on one node into a single disk operation — m chunk
  write-backs pay one ``io_latency`` instead of m
  (``Stats.io_coalesced_writes`` counts the absorbed chunks).  An
  *elevator pass* extends the coalescing window past the timestamp: a
  flushed range adjacent to a *queued-but-unstarted* write op of the same
  (node, file) merges into that op instead of paying its own
  ``io_latency`` — staggered write-backs under disk backlog coalesce the
  same way an IO elevator absorbs requests into its pending sweep.
* The **real** OS read/write happens when the completion is delivered, so
  a fail-stopped node (``kill_node``) or a halted run (``run(until)``)
  loses exactly the in-flight operations — the crash semantics the
  checkpoint layer's commit protocol is tested against.

``io_mode="sync"`` drives the same latency model without the overlap: the
read is charged to the acquiring task's blocking time at execution and the
write-back is charged (and performed) synchronously at destroy, one
operation per chunk, no coalescing.  That is the baseline
``benchmarks/bench_fileio.py`` compares the async path against.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple, TYPE_CHECKING

if TYPE_CHECKING:                                       # pragma: no cover
    from .guid import Guid
    from .runtime import Runtime

__all__ = ["IoOp", "IoQueue"]


@dataclasses.dataclass
class IoOp:
    """One disk operation (post-coalescing) on a node's IO queue."""

    kind: str                         # "read" | "write" | "spill" | "compact"
    node: int
    path: str
    offset: int
    size: int
    db: Optional["Guid"] = None       # read target data block
    file: Optional["Guid"] = None     # None for spill-file ops
    data: Optional[bytes] = None      # write payload, snapshot at enqueue
    chunks: int = 1                   # chunk write-backs merged into this op
    performed: bool = False           # sync mode: OS IO already done
    # "spill" only: the shard's victims as (db guid, spill offset, size,
    # db.version at snapshot) — a stale version aborts that victim
    victims: Optional[List[Tuple]] = None
    enqueued_at: float = 0.0
    start: float = 0.0                # disk busy interval [start, done)
    done: float = 0.0


class IoQueue:
    """Per-node virtual-time disk queues (§5 async IO subsystem)."""

    def __init__(self, rt: "Runtime"):
        self.rt = rt
        # node -> virtual time its disk becomes free
        self._free_at: Dict[int, float] = {}
        # write-back coalescing window: ops enqueued at the current
        # timestamp flush together (mirrors the §6.3 copy batching)
        self._write_buffer: List[IoOp] = []
        self._flush_scheduled = False
        # elevator pass: submitted write ops whose disk slot hasn't started
        # yet, indexed by (node, path) — later flushes merge into them
        self._pending_writes: Dict[Tuple[int, str], List[IoOp]] = {}
        self.inflight = 0                 # ops submitted, completion not seen
        self.reads_inflight = 0
        # monitoring only (rt._mon is not None): per-node start times of
        # submitted ops, so queue_depth() can count ops still waiting for
        # the disk without scanning the event heap
        self._queued_starts: Dict[int, List[float]] = {}

    # ------------------------------------------------------------ plumbing

    def _service(self, op: IoOp, at: float) -> float:
        """Occupy ``op.node``'s disk for one ``io_latency``; return done."""
        free = self._free_at.get(op.node, 0.0)
        op.enqueued_at = at
        op.start = max(at, free)
        op.done = op.start + self.rt.io_latency
        self._free_at[op.node] = op.done
        return op.done

    def _submit(self, op: IoOp, at: float) -> float:
        from .messages import MIoDone
        done = self._service(op, at)
        self.inflight += 1
        if op.kind == "read":
            self.rt.stats.io_read_ops += 1
            self.reads_inflight += 1
            if self.reads_inflight > self.rt.stats.io_reads_inflight_max:
                self.rt.stats.io_reads_inflight_max = self.reads_inflight
        else:
            self.rt.stats.io_write_ops += 1
        self.rt.send(MIoDone(op=op), op.node, op.node, at=done)
        if op.kind == "write" and not op.performed:
            self._pending_writes.setdefault((op.node, op.path),
                                            []).append(op)
        if self.rt._mon is not None:
            # publish the io.* gauges live at submit (not at run() return)
            self._queued_starts.setdefault(op.node, []).append(op.start)
            self.rt._mon.on_io(self)
        return done

    def complete(self, op: IoOp) -> None:
        """Bookkeeping when an op's MIoDone is delivered (or dropped)."""
        if self.rt._san is not None:
            self.rt._san.on_io_done(op)
        self.inflight = max(0, self.inflight - 1)
        if op.kind == "read":
            self.reads_inflight = max(0, self.reads_inflight - 1)
        elif op.kind == "write":
            pend = self._pending_writes.get((op.node, op.path))
            if pend is not None:
                if op in pend:
                    pend.remove(op)
                if not pend:
                    del self._pending_writes[(op.node, op.path)]
        if self.rt._mon is not None:
            lst = self._queued_starts.get(op.node)
            if lst is not None:
                try:
                    lst.remove(op.start)
                except ValueError:
                    pass
                if not lst:
                    del self._queued_starts[op.node]
            self.rt._mon.on_io(self)

    def queue_depth(self, node: Optional[int] = None) -> int:
        """Submitted ops whose disk service hasn't started yet (queued
        behind the platter, as opposed to ``inflight`` which also counts
        the op currently being serviced).  Monitoring-only — the start
        lists are maintained iff ``Runtime(monitor=...)`` is on."""
        now = self.rt.clock
        if node is not None:
            return sum(1 for s in self._queued_starts.get(node, ()) if s > now)
        return sum(1 for lst in self._queued_starts.values()
                   for s in lst if s > now)

    # --------------------------------------------------------------- reads

    def submit_read(self, db, f, at: Optional[float] = None,
                    path: Optional[str] = None,
                    offset: Optional[int] = None) -> float:
        """Enqueue the §5 lazy read of ``db``'s file range (idempotent).

        With ``path``/``offset`` overrides (``f`` may then be None) the read
        targets the node's spill file instead of a §5 user file — the
        re-materialization of a spilled block rides the same queue, defers
        grants the same way, and wakes waiters through the same ``MIoDone``.
        """
        if db.io_pending:
            return 0.0
        db.io_pending = True
        op = IoOp(kind="read", node=db.node,
                  path=f.path if path is None else path,
                  offset=db.file_offset if offset is None else offset,
                  size=db.size, db=db.guid,
                  file=None if f is None else f.guid)
        return self._submit(op, self.rt.clock if at is None else at)

    # -------------------------------------------------------------- spill

    def submit_spill(self, node: int, path: str, offset: int, data: bytes,
                     victims: List[Tuple], at: Optional[float] = None) -> float:
        """Enqueue one shard's cold-object write-back (one disk op for the
        whole shard's victims; payloads are concatenated at ``offset``).

        Accounted as a write op (``Stats.io_write_ops``) but kept out of
        the §5 elevator/coalescing registries: spill ops target the node's
        private spill file and never merge with user-file write-backs.
        """
        op = IoOp(kind="spill", node=node, path=path, offset=offset,
                  size=len(data), data=data, victims=victims,
                  chunks=len(victims))
        return self._submit(op, self.rt.clock if at is None else at)

    def submit_compact(self, node: int, path: str, plan: List[Tuple],
                       live_bytes: int, at: Optional[float] = None) -> float:
        """Enqueue a spill-file compaction sweep: one disk op for the
        whole rewrite (the elevator's bulk-sweep analogue).  ``plan``
        holds (db guid, old offset, new offset, size, version) per live
        slot; ``Runtime._finish_compact`` re-verifies it at completion.
        Accounted as a write op, kept out of the §5 elevator like spills.
        """
        op = IoOp(kind="compact", node=node, path=path, offset=0,
                  size=live_bytes, victims=plan, chunks=len(plan))
        return self._submit(op, self.rt.clock if at is None else at)

    # -------------------------------------------------------------- writes

    def submit_write(self, db, f, at: Optional[float] = None) -> None:
        """Buffer a dirty-range write-back for same-timestamp coalescing."""
        op = IoOp(kind="write", node=db.node, path=f.path,
                  offset=db.file_offset, size=db.size,
                  db=db.guid, file=f.guid, data=db.buffer.tobytes())
        self._write_buffer.append(op)
        if not self._flush_scheduled:
            self._flush_scheduled = True
            heapq.heappush(self.rt._heap,
                           (self.rt.clock if at is None else at,
                            next(self.rt._tick), "io_flush", None))

    def _elevator_merge(self, op: IoOp) -> bool:
        """Absorb ``op`` into a queued-but-unstarted write of the same
        (node, file) when the ranges are adjacent (ROADMAP
        "cross-timestamp write coalescing").

        Only ops whose disk slot is strictly in the future are candidates:
        an op with ``start <= now`` is already on the platter.  The merged
        op's completion is untouched — the absorbed chunks ride the
        already-charged ``io_latency``, exactly like same-timestamp
        coalescing, and count in ``Stats.io_coalesced_writes``.

        Ordering hazard (the same class the §6.3 copy batching replays
        sequentially): if any pending write op overlaps ``op``'s range —
        a re-written chunk whose stale write-back is still queued — the
        newest payload must land *last*, so ``op`` takes a fresh disk
        slot (FIFO per node puts it behind every queued op) instead of
        riding an earlier one.
        """
        now = self.rt.clock
        pend = self._pending_writes.get((op.node, op.path), ())
        for prior in pend:
            if prior.offset < op.offset + op.size and \
                    op.offset < prior.offset + prior.size:
                return False
        for prior in pend:
            if prior.performed or prior.data is None or prior.start <= now:
                continue
            if op.offset == prior.offset + prior.size:
                prior.data = prior.data + (op.data or b"")
            elif op.offset + op.size == prior.offset:
                prior.data = (op.data or b"") + prior.data
                prior.offset = op.offset
            else:
                continue
            prior.size += op.size
            prior.chunks += op.chunks
            self.rt.stats.io_coalesced_writes += op.chunks
            return True
        return False

    def flush_writes(self) -> None:
        """Coalesce the buffered write-backs and put them on the disks.

        Ranges are adjacent-merged per ``(node, path)``: §5 chunks of one
        file never overlap, so a sorted linear sweep suffices, and the
        merged payload is the concatenation in offset order.  A merged run
        then takes the elevator: if it is adjacent to a queued-but-
        unstarted write op from an earlier timestamp it joins that op
        instead of occupying its own disk slot.
        """
        buf, self._write_buffer = self._write_buffer, []
        self._flush_scheduled = False
        if not buf:
            return
        groups: Dict[Tuple[int, str], List[IoOp]] = {}
        for op in buf:
            groups.setdefault((op.node, op.path), []).append(op)
        for (_node, _path), ops in sorted(groups.items()):
            ops.sort(key=lambda o: o.offset)
            merged = ops[0]
            for op in ops[1:]:
                if op.offset == merged.offset + merged.size:
                    merged.data = (merged.data or b"") + (op.data or b"")
                    merged.size += op.size
                    merged.chunks += op.chunks
                    self.rt.stats.io_coalesced_writes += op.chunks
                else:
                    if not self._elevator_merge(merged):
                        self._submit(merged, self.rt.clock)
                    merged = op
            if not self._elevator_merge(merged):
                self._submit(merged, self.rt.clock)

    # ---------------------------------------------------------- sync mode

    def charge_sync(self, db, f, kind: str, path: Optional[str] = None,
                    offset: Optional[int] = None) -> float:
        """``io_mode="sync"``: same disk model, no overlap, no coalescing.

        The caller performs the OS IO immediately; this occupies the disk
        and returns the virtual time the caller must block
        (``done - now``).  The pre-``performed`` completion still flows
        through the queue so the makespan covers the disk busy interval.
        ``path``/``offset`` overrides (``f`` then None) charge a spill-file
        read the same way the async path does.
        """
        op = IoOp(kind=kind, node=db.node,
                  path=f.path if path is None else path,
                  offset=db.file_offset if offset is None else offset,
                  size=db.size, db=db.guid,
                  file=None if f is None else f.guid, performed=True)
        done = self._submit(op, self.rt.clock)
        return done - self.rt.clock
