"""``repro.monitoring`` — the live observability plane.

One deterministic metrics registry (counters, gauges, fixed-edge
latency histograms) that every layer publishes into: ``Runtime.stats``
and ``CkptStats`` are field-compatible views over it, the IO queue
refreshes ``io.*`` gauges at submit/completion, the sanitizer's
``san_*`` totals land in ``san.*``, the trainer stamps ``train.*`` per
step, and the serve engine snapshots it mid-run to gate admission on
live queue depth / inflight-IO backpressure.

Enable per-runtime with ``Runtime(monitor=True)`` (or the
``REPRO_MONITOR`` environment variable); off by default — hook sites
follow the sanitizer's one-``is None``-check pattern so virtual
metrics stay bit-identical either way.
"""
from .registry import DEFAULT_LATENCY_EDGES, Histogram, Monitor, Registry

__all__ = ["DEFAULT_LATENCY_EDGES", "Histogram", "Monitor", "Registry"]
