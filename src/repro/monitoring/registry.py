"""One snapshotable metrics registry across every layer of the runtime.

The paper's §5 argument — build file IO by reusing the data-block
concepts instead of inventing a parallel subsystem — applied one level
up: rather than per-layer stats dataclasses refreshed at ``run()``
return, the runtime, IO queue, checkpointer, sanitizer, trainer, and
serve engine all publish into one flat name → value registry that can
be snapshotted *mid-run* without stopping virtual time.

Three metric kinds:

- **counters / gauges** — plain ints/floats in a flat dict keyed by
  dotted names (``io.queue_depth``, ``spill.frag_bytes``, …).  Writers
  use :meth:`Registry.inc` / :meth:`Registry.set`; hot paths that
  already hold a field reference (the ``Stats`` property view) write
  the dict slot directly.
- **histograms** — fixed virtual-time bucket edges (geometric, four
  per decade over 1e-6..1e3 s) so two runs of the same schedule
  produce byte-identical snapshots; quantiles interpolate inside the
  hit bucket deterministically.
- **snapshots** — :meth:`Registry.snapshot` returns a sorted flat dict
  (histograms contribute ``<name>.count/.sum/.p50/.p99``), cheap
  enough to call from inside a serve loop every few virtual ms.

Everything here is deterministic: no wall clocks, no sampling, and the
bucket edges are constants — snapshots of virtual metrics diff clean
across commits, exactly like the ``BENCH_*.json`` files.
"""
from __future__ import annotations

import bisect
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "DEFAULT_LATENCY_EDGES",
    "Histogram",
    "Monitor",
    "Registry",
]

# Four buckets per decade, 1e-6 .. 1e3 virtual seconds.  Fixed at import
# time so histogram snapshots are diffable across runs and commits.
DEFAULT_LATENCY_EDGES: Tuple[float, ...] = tuple(
    10.0 ** (e / 4.0) for e in range(-24, 13)
)


class Histogram:
    """Fixed-edge latency histogram with deterministic quantiles.

    Bucket ``i`` holds observations ``x`` with ``edges[i-1] < x <=
    edges[i]`` (bucket 0 is the underflow ``x <= edges[0]``, the last
    bucket the overflow).  :meth:`quantile` linearly interpolates
    within the hit bucket — underflow interpolates over ``[0,
    edges[0]]``, overflow clamps to ``edges[-1]`` — so the result is a
    pure function of the counts, never of observation order.
    """

    __slots__ = ("name", "edges", "counts", "count", "total")

    def __init__(self, name: str,
                 edges: Sequence[float] = DEFAULT_LATENCY_EDGES):
        self.name = name
        self.edges: Tuple[float, ...] = tuple(float(e) for e in edges)
        self.counts: List[int] = [0] * (len(self.edges) + 1)
        self.count = 0
        self.total = 0.0

    def observe(self, x: float) -> None:
        self.counts[bisect.bisect_left(self.edges, x)] += 1
        self.count += 1
        self.total += x

    def quantile(self, q: float) -> float:
        if self.count == 0:
            return 0.0
        rank = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if c and cum + c >= rank:
                if i >= len(self.edges):
                    return self.edges[-1]
                lo = 0.0 if i == 0 else self.edges[i - 1]
                hi = self.edges[i]
                return lo + (hi - lo) * (max(rank - cum, 0.0) / c)
            cum += c
        return self.edges[-1]

    def summary(self) -> Dict[str, float]:
        return {
            f"{self.name}.count": self.count,
            f"{self.name}.sum": self.total,
            f"{self.name}.p50": self.quantile(0.50),
            f"{self.name}.p99": self.quantile(0.99),
        }

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Histogram({self.name!r}, count={self.count}, "
                f"p50={self.quantile(0.5):.3g}, p99={self.quantile(0.99):.3g})")


class Registry:
    """Flat dotted-name → scalar store plus named histograms.

    The scalar dict is exposed (``_values``) on purpose: the ``Stats``
    and ``CkptStats`` property views write slots directly so the ~40
    pre-registry increment sites stay one dict store, not a method
    call.  Names are namespaced by convention (``runtime.*``, ``io.*``,
    ``table.*``, ``spill.*``, ``san.*``, ``moe.*``, ``ckpt.*``,
    ``serve.*``, ``train.*``, ``edt.*`` — see the README Monitoring
    table).
    """

    __slots__ = ("_values", "_hists")

    def __init__(self) -> None:
        self._values: Dict[str, Any] = {}
        self._hists: Dict[str, Histogram] = {}

    def declare(self, name: str, initial: Any = 0) -> None:
        self._values.setdefault(name, initial)

    def inc(self, name: str, n: Any = 1) -> None:
        self._values[name] = self._values.get(name, 0) + n

    def set(self, name: str, value: Any) -> None:
        self._values[name] = value

    def value(self, name: str, default: Any = 0) -> Any:
        if name in self._values:
            return self._values[name]
        h = self._hists.get(name)
        return h.count if h is not None else default

    def histogram(self, name: str,
                  edges: Optional[Sequence[float]] = None) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = Histogram(name, edges if edges is not None
                          else DEFAULT_LATENCY_EDGES)
            self._hists[name] = h
        return h

    def snapshot(self, prefix: str = "") -> Dict[str, Any]:
        """Sorted flat view of every metric under ``prefix`` (all when
        empty).  Cheap — no virtual time passes, nothing is reset."""
        out: Dict[str, Any] = {}
        for k, v in self._values.items():
            if k.startswith(prefix):
                out[k] = v
        for k, h in self._hists.items():
            if k.startswith(prefix):
                out.update(h.summary())
        return dict(sorted(out.items()))

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"Registry({len(self._values)} scalars, "
                f"{len(self._hists)} histograms)")


class Monitor:
    """Hook sink the runtime holds when monitoring is on.

    Mirrors the sanitizer wiring (PR 9): the runtime keeps ``self._mon
    = None`` when off, and every hook site is a single ``is not None``
    check, so the monitored-off hot path pays one pointer compare and
    the virtual schedule — and therefore every committed bench metric —
    is bit-identical either way.
    """

    __slots__ = ("registry",)

    def __init__(self, registry: Registry):
        self.registry = registry

    def on_edt(self, cls: str, grant_wait: float, execute: float) -> None:
        """Per-EDT-class latency observation at retirement: virtual
        time from ready→grant and from grant→end."""
        reg = self.registry
        reg.histogram("edt.grant_wait." + cls).observe(grant_wait)
        reg.histogram("edt.execute." + cls).observe(execute)

    def on_io(self, queue: Any) -> None:
        """Refresh the live IO gauges off the queue's current state
        (called at submit, at completion, and on demand before a
        snapshot — the gauges are as fresh as the last call)."""
        reg = self.registry
        reg.set("io.inflight_ops", queue.inflight)
        reg.set("io.reads_inflight", queue.reads_inflight)
        reg.set("io.queue_depth", queue.queue_depth())
