"""Roofline terms from compiled AOT artifacts.

``cost_analysis()`` supplies HLO FLOPs and bytes; collective traffic is NOT
in cost_analysis, so ``collective_bytes`` parses the post-SPMD HLO text and
sums operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64"
                       r"|f64|c64|c128)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|tuple\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes per collective kind from post-SPMD HLO text."""
    per_kind: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    largest: Tuple[int, str] = (0, "")
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        if "-done(" in line:
            continue  # paired with -start; avoid double count
        # operand shapes appear inside the call parens, after the op name
        args = line[m.end():]
        total = 0
        for sm in _SHAPE_RE.finditer(args):
            total += _shape_bytes(sm.group(1), sm.group(2))
        per_kind[kind] += total
        counts[kind] += 1
        if total > largest[0]:
            largest = (total, line.strip()[:160])
    return {"per_kind": per_kind, "counts": counts,
            "total": sum(per_kind.values()), "largest": largest}


@dataclasses.dataclass
class Roofline:
    flops: float                 # per-device HLO FLOPs
    hbm_bytes: float             # per-device HLO bytes accessed
    coll_bytes: float            # per-device collective operand bytes
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float           # 6·N·D useful flops (per device)
    useful_ratio: float

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def roofline(cost: Dict[str, float], coll: Dict[str, Any],
             model_flops_total: float, num_chips: int,
             links_per_chip: float = 3.0) -> Roofline:
    """Build the three-term roofline for one compiled cell.

    ``cost`` is compiled.cost_analysis() (per-device program).
    ``model_flops_total`` is the whole-step useful FLOPs (6·N·D·tokens…);
    divided by chips for the per-device ratio.
    """
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    coll_b = float(coll["total"])
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = coll_b / (ICI_BW * links_per_chip)
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", coll_s)), key=lambda kv: kv[1])[0]
    mf = model_flops_total / num_chips
    return Roofline(flops=flops, hbm_bytes=hbm, coll_bytes=coll_b,
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=coll_s, dominant=dom,
                    model_flops=mf,
                    useful_ratio=(mf / flops if flops else 0.0))


# ------------------------------------------------------- model FLOPs (6·N·D)

def param_count(cfg) -> Tuple[float, float]:
    """Returns (total_params, active_params) analytically from the config."""
    d, v = cfg.d_model, cfg.vocab_size
    emb = v * d
    head = 0 if cfg.tie_embeddings else d * v
    per_attn = (d * cfg.num_heads * cfg.head_dim
                + 2 * d * cfg.num_kv_heads * cfg.head_dim
                + cfg.num_heads * cfg.head_dim * d)
    if cfg.use_mla:
        dn, dr, dv_ = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
        per_attn = (d * cfg.q_lora_rank
                    + cfg.q_lora_rank * cfg.num_heads * (dn + dr)
                    + d * (cfg.kv_lora_rank + dr)
                    + cfg.kv_lora_rank * cfg.num_heads * (dn + dv_)
                    + cfg.num_heads * dv_ * d)
    per_mlp = 3 * d * cfg.d_ff
    per_moe_expert = 3 * d * (cfg.moe_d_ff or cfg.d_ff)
    per_shared = 3 * d * (cfg.moe_d_ff or cfg.d_ff) * cfg.num_shared_experts
    per_mamba = 0
    if cfg.ssm_state:
        di, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
        per_mamba = (2 * d * di + 2 * d * n + d * h
                     + cfg.conv_kernel * (di + 2 * n) + di * d)

    total = emb + head
    active = emb + head
    L = cfg.num_layers
    fam = cfg.family
    if fam in ("dense", "vlm"):
        total += L * (per_attn + per_mlp)
        active = total
    elif fam == "moe":
        n_moe = L - cfg.first_k_dense
        dense_ff = 12288 if cfg.use_mla and cfg.d_model == 5120 else cfg.d_ff
        total += cfg.first_k_dense * (per_attn + 3 * d * dense_ff)
        active += cfg.first_k_dense * (per_attn + 3 * d * dense_ff)
        per_layer_total = (per_attn + cfg.num_experts * per_moe_expert
                           + per_shared
                           + (per_mlp if cfg.moe_dense_residual else 0))
        per_layer_active = (per_attn
                            + cfg.experts_per_token * per_moe_expert
                            + per_shared
                            + (per_mlp if cfg.moe_dense_residual else 0))
        total += n_moe * per_layer_total
        active += n_moe * per_layer_active
    elif fam == "ssm":
        total += L * per_mamba
        active = total
    elif fam == "hybrid":
        g = L // cfg.attn_every
        total += L * per_mamba + (per_attn + per_mlp)      # shared block once
        active = emb + head + L * per_mamba + g * (per_attn + per_mlp)
    elif fam == "encdec":
        enc_attn = 4 * d * cfg.num_heads * cfg.head_dim
        total += cfg.num_encoder_layers * (enc_attn + 2 * d * cfg.d_ff)
        total += L * (per_attn + enc_attn + 2 * d * cfg.d_ff)
        active = total
    return float(total), float(active)


def model_flops(cfg, shape) -> float:
    """6·N_active·D tokens for train; 2·N_active·D for inference steps."""
    _, active = param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per sequence
    return 2.0 * active * shape.global_batch
