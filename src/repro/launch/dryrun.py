import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh) cell.

For each cell this lowers the real step function (train_step for train
shapes, prefill/decode for serving shapes) against ShapeDtypeStruct inputs
on the production mesh, compiles it, prints ``memory_analysis`` /
``cost_analysis``, parses collective traffic out of the SPMD HLO, and
appends the roofline terms to a JSON artifact consumed by
``benchmarks/bench_roofline.py`` and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  python -m repro.launch.dryrun --all                    # single-pod table
  python -m repro.launch.dryrun --all --multi-pod        # 2-pod pass
"""
import argparse
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import all_arch_names, get_config
from repro.configs.base import SHAPES, applicable, shape_by_name
from repro.dist.sharding import ShardCtx, use_mesh
from repro.launch import hlo_analysis as ha
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig
from repro.train.steps import make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "results", "dryrun.json")


def _mem_dict(mem) -> Dict[str, float]:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        out[k] = float(getattr(mem, k, 0.0))
    return out


def lower_cell(cfg, shape, mesh, ctx: ShardCtx):
    """Build the lowered step for one cell.

    Returns ``(lowered, ckpt_inputs)`` where ``ckpt_inputs`` is the
    ``(state_shapes, state_shardings)`` pair for train shapes (reused by
    the ``ckpt_io`` cost model so the OptimizerConfig and eval_shape work
    are not duplicated) and ``None`` otherwise.
    """
    import dataclasses
    if shape.kind != "train":
        # serving keeps weights in the compute dtype (no fp32 masters)
        cfg = dataclasses.replace(cfg, param_dtype=cfg.dtype)
    model = LanguageModel(cfg)
    oc = OptimizerConfig(
        state_dtype=cfg.optimizer_state_dtype,
        accum_steps=cfg.train_accum_steps,
        accum_dtype="bfloat16" if cfg.optimizer_state_dtype == "int8"
        else "float32")
    ckpt_inputs = None

    if shape.kind == "train":
        step = make_train_step(model, oc)
        state_shapes = sp.state_specs(cfg, oc)
        state_sh = sp.state_shardings(cfg, oc, ctx)
        ckpt_inputs = (state_shapes, state_sh)
        batch_shapes = sp.batch_specs(cfg, shape)
        batch_sh = sp.batch_shardings(cfg, shape, ctx)
        fn = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     donate_argnums=(0,))
        lowered = fn.lower(state_shapes, batch_shapes)
    elif shape.kind == "prefill":
        params_shapes = sp.params_only_specs(cfg)
        params_sh = sp.param_shardings(params_shapes, ctx) \
            if hasattr(sp, "param_shardings") else None
        from repro.dist.sharding import param_shardings
        params_sh = param_shardings(params_shapes, ctx)
        batch_shapes = sp.batch_specs(cfg, shape)
        batch_sh = sp.batch_shardings(cfg, shape, ctx)
        fn = jax.jit(model.prefill, in_shardings=(params_sh, batch_sh))
        lowered = fn.lower(params_shapes, batch_shapes)
    else:  # decode
        params_shapes = sp.params_only_specs(cfg)
        from repro.dist.sharding import param_shardings
        params_sh = param_shardings(params_shapes, ctx)
        cache_shapes = model.cache_spec(shape.global_batch, shape.seq_len)
        cache_sh = sp.cache_shardings(cache_shapes, ctx)
        tok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        tok_sh = NamedSharding(mesh, ctx.spec(tok.shape, "dp", None))
        cur = jax.ShapeDtypeStruct((), jnp.int32)
        cur_sh = NamedSharding(mesh, P())
        fn = jax.jit(model.decode_step,
                     in_shardings=(params_sh, cache_sh, tok_sh, cur_sh),
                     donate_argnums=(1,))
        lowered = fn.lower(params_shapes, cache_shapes, tok, cur)
    return lowered, ckpt_inputs


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = shape_by_name(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    num_chips = mesh.size
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "skipped",
    }
    if not applicable(cfg, shape):
        rec["reason"] = "long_500k needs sub-quadratic arch (DESIGN.md)"
        return rec
    t0 = time.time()
    with use_mesh(mesh, pure_dp=cfg.pure_dp) as ctx:
        lowered, ckpt_inputs = lower_cell(cfg, shape, mesh, ctx)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):       # jax < 0.5 returns a list
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        # trip-count-aware parse (cost_analysis counts while bodies once)
        from repro.launch import hlo_cost
        parsed = hlo_cost.analyze(hlo)
        mf = ha.model_flops(cfg, shape)
        coll = {"per_kind": parsed.coll_bytes, "counts": parsed.coll_counts,
                "total": parsed.coll_total}
        rl = ha.roofline({"flops": parsed.flops,
                          "bytes accessed": parsed.bytes},
                         coll, mf, num_chips)
        ckpt_io = None
        if ckpt_inputs is not None:
            # checkpoint IO costed from the same §5 latency model the
            # runtime charges: §6 ranges per node, coalesced, one
            # io_latency per op on per-node disks
            from repro import ckpt as _ckpt
            ckpt_io = _ckpt.io_cost(*ckpt_inputs)

    rec.update({
        "status": "ok",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": _mem_dict(mem),
        "cost_analysis_raw": {k: float(v) for k, v in cost.items()
                              if isinstance(v, (int, float))},
        "collectives": {"per_kind": coll["per_kind"],
                        "counts": coll["counts"], "total": coll["total"]},
        "roofline": rl.as_dict(),
    })
    if ckpt_io is not None:
        rec["ckpt_io"] = ckpt_io
    if verbose:
        print(f"== {arch} × {shape_name} × {rec['mesh']} ==")
        print("  memory_analysis:", json.dumps(rec["memory"]))
        print("  parsed cost: flops={:.3e} bytes={:.3e} (raw cost_analysis "
              "flops={:.3e})".format(
                  rl.flops, rl.hbm_bytes,
                  rec["cost_analysis_raw"].get("flops", 0)))
        print("  collectives:", json.dumps(rec["collectives"]["per_kind"]))
        print("  roofline: compute={:.4f}s memory={:.4f}s coll={:.4f}s "
              "dominant={} useful={:.2f}".format(
                  rl.compute_s, rl.memory_s, rl.collective_s, rl.dominant,
                  rl.useful_ratio))
        print(f"  (lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
    return rec


def load_results(path: str) -> Dict[str, Any]:
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {"cells": {}}


def save_results(path: str, res: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(res, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--redo", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    out_path = args.out or os.path.normpath(RESULTS)
    results = load_results(out_path)

    if args.all:
        cells = [(a, s.name) for a in all_arch_names() for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        cells = [(args.arch, args.shape)]

    failures = []
    for arch, shape_name in cells:
        keyname = f"{arch}|{shape_name}|{'2x16x16' if args.multi_pod else '16x16'}"
        if not args.redo and results["cells"].get(keyname, {}).get("status") == "ok":
            print(f"-- cached: {keyname}")
            continue
        try:
            rec = run_cell(arch, shape_name, args.multi_pod)
        except Exception as e:  # record failures: they are bugs to fix
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape_name,
                   "mesh": "2x16x16" if args.multi_pod else "16x16",
                   "status": "error", "error": f"{type(e).__name__}: {e}"}
            failures.append(keyname)
        results["cells"][keyname] = rec
        save_results(out_path, results)
    print(f"\nwrote {out_path}")
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
