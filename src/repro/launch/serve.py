"""Continuous-batching serve driver on the paged-KV engine.

Admits an open-loop Poisson arrival stream into `repro.serve.engine`:
request slots come from a labeled-GUID array, the KV cache is pages of
one shared §6-partitioned block, and cold sessions spill to disk through
the IO queue when ``--resident-budget`` is set.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --requests 16 --rate 200 [--ckpt-dir /tmp/ckpt] [--static]

Positions are carried as traced (B,) arrays inside the jitted decode
step — the engine never round-trips decode state through Python ints, so
nothing retraces as requests join and leave the batch.
"""
import argparse
import dataclasses
import time

import numpy as np

from repro.configs import get_config
from repro.serve.engine import (ModelBackend, ServeEngine, SyntheticBackend,
                                poisson_workload, run_static)


def _fmt(m: dict) -> str:
    return (f"{m['tokens']:.0f} toks in {m['makespan_s'] * 1e3:.1f}ms virtual "
            f"-> {m['tok_per_s']:.0f} tok/s, "
            f"p50 {m['p50_latency_s'] * 1e3:.2f}ms "
            f"p99 {m['p99_latency_s'] * 1e3:.2f}ms")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (tiny dims, fp32)")
    ap.add_argument("--synthetic", action="store_true",
                    help="skip the model; deterministic token function")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="Poisson arrival rate, requests per virtual second")
    ap.add_argument("--prompt-len", type=int, nargs=2, default=(8, 24),
                    metavar=("LO", "HI"))
    ap.add_argument("--gen", type=int, nargs=2, default=(4, 12),
                    metavar=("LO", "HI"))
    ap.add_argument("--b-cap", type=int, default=8,
                    help="request slots / decode batch rows")
    ap.add_argument("--page-size", type=int, default=8,
                    help="tokens per KV page")
    ap.add_argument("--pool-pages", type=int, default=64)
    ap.add_argument("--max-pages", type=int, default=8,
                    help="page-table width (max pages per request)")
    ap.add_argument("--resident-budget", type=int, default=0,
                    help="data blocks resident per node before session "
                         "archives spill to disk (0 = unlimited)")
    ap.add_argument("--static", action="store_true",
                    help="also run the static-batch baseline")
    ap.add_argument("--monitor", action="store_true",
                    help="print live monitoring-registry snapshots "
                         "(queue depth, inflight IO, pages, sessions) "
                         "at --monitor-every virtual-second intervals")
    ap.add_argument("--monitor-every", type=float, default=0.01,
                    metavar="S", help="snapshot interval, virtual seconds")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    reqs = poisson_workload(args.requests, args.rate,
                            prompt_len=tuple(args.prompt_len),
                            gen=tuple(args.gen), seed=args.seed)

    if args.synthetic:
        backend = SyntheticBackend(args.page_size)
    else:
        import jax
        import jax.numpy as jnp
        from repro.models.model import LanguageModel
        cfg = get_config(args.arch)
        if args.smoke:
            cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, param_dtype=cfg.dtype)
        model = LanguageModel(cfg)
        if args.ckpt_dir:
            from repro import ckpt
            from repro.models.layers import cast_params
            tree, step = ckpt.restore(args.ckpt_dir)
            params = jax.tree_util.tree_map(jnp.asarray, tree)["params"]
            params = cast_params(params, cfg.dtype)
            print(f"restored step {step}")
        else:
            params = model.init(jax.random.PRNGKey(0))
        pad = args.page_size
        prompt_pad = ((args.prompt_len[1] + pad - 1) // pad) * pad
        backend = ModelBackend(model, params, pool_pages=args.pool_pages,
                               page_size=args.page_size,
                               prompt_pad=prompt_pad)
        vocab = cfg.vocab_size
        for r in reqs:
            r.prompt = np.minimum(r.prompt, vocab - 1)

    def _print_snap(t: float, snap: dict) -> None:
        print(f"  [monitor t={t * 1e3:8.3f}ms] "
              f"queued {snap['serve.queued']:.0f} "
              f"active {snap['serve.active']:.0f} "
              f"free_pages {snap['serve.free_pages']:.0f} "
              f"io_inflight {snap.get('io.inflight_ops', 0):.0f} "
              f"io_depth {snap.get('io.queue_depth', 0):.0f} "
              f"spilled {snap.get('spill.objects', 0):.0f}")

    eng = ServeEngine(backend, b_cap=args.b_cap,
                      pool_pages=args.pool_pages, max_pages=args.max_pages,
                      resident_budget=args.resident_budget or None,
                      monitor=args.monitor or None,
                      monitor_interval=args.monitor_every if args.monitor
                      else 0.0,
                      on_monitor=_print_snap if args.monitor else None)
    t0 = time.perf_counter()
    m = eng.run(reqs)
    wall = time.perf_counter() - t0
    print(f"continuous: {_fmt(m)}  "
          f"[evictions {m['evictions']:.0f}, resumes {m['resumes']:.0f}, "
          f"spilled {m['spilled_objects']:.0f}; wall {wall:.2f}s]")
    if args.monitor:
        print(f"monitor: {len(eng.monitor_snapshots)} snapshots; "
              f"hist p99 latency {m['p99_hist_latency_s'] * 1e3:.2f}ms, "
              f"hist p99 ttft {m['p99_hist_ttft_s'] * 1e3:.2f}ms")
    for r in reqs[: min(2, len(reqs))]:
        print(f"  req{r.rid}: {r.out}")

    if args.static:
        s = run_static(reqs, b_cap=args.b_cap)
        print(f"static:     {_fmt(s)}")
        print(f"speedup: {m['tok_per_s'] / s['tok_per_s']:.2f}x tok/s, "
              f"{s['p99_latency_s'] / max(m['p99_latency_s'], 1e-12):.2f}x p99")


if __name__ == "__main__":
    main()
