"""Batched serving driver: prefill a batch of prompts, decode N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --smoke \
      --batch 4 --prompt-len 32 --gen 16 [--ckpt-dir /tmp/ckpt]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import ckpt
from repro.configs import get_config
from repro.models.model import LanguageModel


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, param_dtype=cfg.dtype)  # serving weights
    model = LanguageModel(cfg)

    if args.ckpt_dir:
        tree, step = ckpt.restore(args.ckpt_dir)
        params = jax.tree_util.tree_map(jnp.asarray, tree)["params"]
        # restored fp32 masters → serving dtype
        from repro.models.layers import cast_params
        params = cast_params(params, cfg.dtype)
        print(f"restored step {step}")
    else:
        params = model.init(jax.random.PRNGKey(0))

    key = jax.random.PRNGKey(42)
    b, p = args.batch, args.prompt_len
    batch = {"tokens": jax.random.randint(key, (b, p), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.num_patches, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model)) * 0.02

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step, donate_argnums=(1,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    # grow the cache seq axes for generation (attention caches only)
    def grow(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        axis = {"k": -2, "v": -2, "c_kv": -2, "k_rope": -2}.get(name)
        if axis is None:
            return leaf
        pad = [(0, 0)] * leaf.ndim
        pad[axis] = (0, args.gen)
        return jnp.pad(leaf, pad)
    cache = jax.tree_util.tree_map_with_path(grow, cache)

    prefix = cfg.num_patches if cfg.family == "vlm" else 0
    cur = prefix + p
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out_tokens = [np.asarray(tok)[:, 0]]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(cur + i, jnp.int32))
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out_tokens.append(np.asarray(tok)[:, 0])
    jax.block_until_ready(tok)
    t_gen = time.perf_counter() - t0

    gen = np.stack(out_tokens, axis=1)
    print(f"arch={cfg.name} prefill({p} toks x{b}): {t_prefill*1e3:.0f}ms; "
          f"decode {args.gen - 1} steps: {t_gen*1e3:.0f}ms "
          f"({(args.gen - 1) * b / max(t_gen, 1e-9):.1f} tok/s)")
    for i in range(min(b, 2)):
        print(f"  seq{i}: {gen[i].tolist()}")


if __name__ == "__main__":
    main()
