"""End-to-end training driver.

Runs any assigned architecture (reduced or full config) through the
OCR-runtime trainer: §4 labeled step map, §5 chunked checkpoints, §3 async
checkpoint write-back, straggler watchdog.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
import argparse

import jax

from repro.configs import get_config
from repro.data import FileTokens, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data", default="synthetic",
                    help="synthetic | markov | path to int32 token file")
    ap.add_argument("--tp", type=int, default=1,
                    help="model-parallel size over local devices")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    model = LanguageModel(cfg)
    oc = OptimizerConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                         total_steps=args.steps,
                         state_dtype=cfg.optimizer_state_dtype)

    if args.data in ("synthetic", "markov"):
        data = SyntheticTokens(cfg.vocab_size, args.batch, args.seq,
                               seed=0, mode="markov" if args.data == "markov"
                               else "uniform")
    else:
        data = FileTokens(args.data, cfg.vocab_size, args.batch, args.seq)

    mesh = make_host_mesh(model=args.tp) if args.tp > 1 else None
    tc = TrainerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every
                       if args.ckpt_dir else 0)
    tr = Trainer(model, oc, data, tc, mesh=mesh)
    state = tr.init_or_restore(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params(dev)="
          f"{sum(l.size for l in jax.tree_util.tree_leaves(state['params'])):,}"
          f" start_step={tr.start_step}")
    state = tr.run(state, args.steps - tr.start_step)
    for h in tr.history[:3] + tr.history[-3:]:
        print(f"  step {h['step']:5d} loss={h['ce_loss']:.4f} "
              f"acc={h['accuracy']:.3f} {h['step_time']*1e3:.0f}ms")
    if tr.straggler_steps:
        print("stragglers:", tr.straggler_steps)
    rs = tr.last_runtime_stats
    print(f"runtime: tasks={rs.tasks_executed} msgs={rs.messages_sent} "
          f"creator_calls={rs.creator_calls}")


if __name__ == "__main__":
    main()
