"""ShapeDtypeStruct stand-ins and sharding trees for every dry-run cell.

``input_specs(cfg, shape)`` returns the model inputs for a cell without any
device allocation; ``*_shardings`` derive NamedSharding trees from the
logical rules in ``repro.dist.sharding``.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist.sharding import ShardCtx, param_shardings
from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig
from repro.train.steps import init_train_state

S = jax.ShapeDtypeStruct


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Training/prefill batch ShapeDtypeStructs for one cell."""
    b, s = shape.global_batch, shape.seq_len
    bf = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    out: Dict[str, Any] = {}
    s_text = s
    if cfg.family == "vlm":
        s_text = s - cfg.num_patches
        out["patches"] = S((b, cfg.num_patches, cfg.d_model), bf)
    if cfg.family == "encdec":
        out["frames"] = S((b, cfg.encoder_seq, cfg.d_model), bf)
    out["tokens"] = S((b, s_text), jnp.int32)
    if shape.kind == "train":
        out["targets"] = S((b, s_text), jnp.int32)
    return out


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig, ctx: ShardCtx
                    ) -> Dict[str, Any]:
    specs = batch_specs(cfg, shape)

    def shard(leaf):
        dims = [None] * len(leaf.shape)
        return NamedSharding(ctx.mesh,
                             ctx.spec(leaf.shape, "dp", *dims[1:]))

    return jax.tree_util.tree_map(shard, specs)


def state_specs(cfg: ModelConfig, oc: OptimizerConfig) -> Any:
    model = LanguageModel(cfg)
    return jax.eval_shape(
        lambda k: init_train_state(model, k, oc), jax.random.PRNGKey(0))


def state_shardings(cfg: ModelConfig, oc: OptimizerConfig, ctx: ShardCtx
                    ) -> Any:
    shapes = state_specs(cfg, oc)
    params_sh = param_shardings(shapes["params"], ctx)
    m_sh = param_shardings(shapes["opt"]["m"], ctx)
    v_sh = param_shardings(shapes["opt"]["v"], ctx)
    step_sh = NamedSharding(ctx.mesh, P())
    return {"params": params_sh,
            "opt": {"m": m_sh, "v": v_sh, "step": step_sh}}


# ------------------------------------------------------------- decode cache

_CACHE_RULES = {
    # leaf name -> logical axes for the *trailing* dims (leading stack dims None)
    "k": (None, "dp", None, "kv_seq", None),      # head-major (B,K,S,hd)
    "v": (None, "dp", None, "kv_seq", None),
    "c_kv": (None, "dp", "kv_seq", None),
    "k_rope": (None, "dp", "kv_seq", None),
    "cross_k": (None, "dp", None, None, None),
    "cross_v": (None, "dp", None, None, None),
    "conv_x": (None, "dp", None, "tp"),
    "conv_B": (None, "dp", None, None),
    "conv_C": (None, "dp", None, None),
    "state": (None, "dp", "tp", None, None),
}


def cache_shardings(cache_tree: Any, ctx: ShardCtx) -> Any:
    def leaf_sh(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        rule = _CACHE_RULES.get(name)
        shape = leaf.shape
        if rule is None:
            return NamedSharding(ctx.mesh, P(*([None] * len(shape))))
        pad = len(shape) - len(rule)
        if pad < 0:
            rule = rule[-len(shape):]
            pad = 0
        logical = (None,) * pad + rule
        return NamedSharding(ctx.mesh, ctx.spec(shape, *logical))

    return jax.tree_util.tree_map_with_path(leaf_sh, cache_tree)


def params_only_specs(cfg: ModelConfig) -> Any:
    model = LanguageModel(cfg)
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))
