"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches JAX device state; the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` *before* any jax
import and then calls this.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over the actually-available devices (tests/examples)."""
    n = len(jax.devices())
    model = min(model, n)
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
