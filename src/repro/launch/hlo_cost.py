"""Trip-count-aware cost model over post-SPMD HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE regardless of
trip count — useless for scan-over-layers models (a 60-layer scan reports
1/60th of the flops).  This module re-derives per-device cost from the HLO
text itself:

* computations are parsed into symbol tables (instruction → shape);
* a call graph is built: ``while`` edges multiply by
  ``backend_config.known_trip_count``, ``fusion(..., calls=%c)`` edges count
  flops (dots can live inside fusions) but not bytes (fusion internals stay
  in registers);
* dot flops = 2 · |result| · K (contracting dims from the lhs operand's
  shape), exact for the matmul-dominated models here;
* HBM bytes = Σ over executed instructions of (operand + result bytes),
  with in-place special cases (dynamic-update-slice counts 2·|update|,
  gather/scatter count touched bytes, not whole operands);
* collective *operand* bytes per kind, derived from result shapes and
  replica-group sizes (all-gather operand = result/g, reduce-scatter
  operand = result·g).

Validated against analytic 6·N·D estimates in tests/test_hlo_cost.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPNAME_RE = re.compile(r"^\s*([a-z][\w\-]*)\(")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")

_SKIP_BYTES = {
    "parameter", "constant", "iota", "get-tuple-element", "tuple", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "rng-get-and-update-state", "opt-barrier",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _shapes_bytes(segment: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(segment):
        total += _shape_elems(m.group(2)) * _DTYPE_BYTES[m.group(1)]
    return total


def _shape_dims(segment: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(segment):
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((m.group(1), dims))
    return out


@dataclasses.dataclass
class _Instr:
    name: str
    op: str
    result_seg: str           # text between '=' and op name (result type)
    args_seg: str             # inside the op's parens
    meta_seg: str             # after the parens (configs, dims, groups)
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: List[_Instr]
    symtab: Dict[str, str]    # instr name -> result type segment


def _parse_computations(text: str) -> Dict[str, _Computation]:
    comps: Dict[str, _Computation] = {}
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _HEADER_RE.match(line)
            if m and line.rstrip().endswith("{"):
                cur = _Computation(m.group(2), [], {})
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        om = None
        # find op token: first lowercase word followed by '(' after the type
        # result type is either "(tuple...)" or "dtype[...]..." prefix
        if rest.startswith("("):
            depth = 0
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
            result_seg = rest[: i + 1]
            tail = rest[i + 1:]
        else:
            sp = rest.find(" ")
            result_seg = rest[:sp] if sp > 0 else rest
            tail = rest[sp + 1:] if sp > 0 else ""
        om = _OPNAME_RE.match(tail)
        if not om:
            cur.symtab[name] = result_seg
            continue
        op = om.group(1)
        rest2 = tail[om.end():]         # after the op's '('
        depth = 1
        for i, ch in enumerate(rest2):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        args_seg = rest2[:i]
        meta_seg = rest2[i + 1:]
        cur.instrs.append(_Instr(name, op, result_seg, args_seg, meta_seg,
                                 line))
        cur.symtab[name] = result_seg
    return comps


def _group_size(meta: str, line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_EXPL_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _dot_flops(instr: _Instr, symtab: Dict[str, str]) -> float:
    result_elems = sum(_shape_elems(m.group(2))
                       for m in _SHAPE_RE.finditer(instr.result_seg))
    kdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.meta_seg)
    ops = _OPERAND_RE.findall(instr.args_seg)
    if not kdims or not ops:
        return 2.0 * result_elems
    lhs_seg = symtab.get(ops[0], "")
    lhs = _shape_dims(lhs_seg)
    if not lhs:
        return 2.0 * result_elems
    dims = lhs[0][1]
    k = 1
    for idx in kdims.group(1).split(","):
        if idx and int(idx) < len(dims):
            k *= dims[int(idx)]
    return 2.0 * result_elems * k


def _conv_flops(instr: _Instr, symtab: Dict[str, str]) -> float:
    result_elems = sum(_shape_elems(m.group(2))
                       for m in _SHAPE_RE.finditer(instr.result_seg))
    ops = _OPERAND_RE.findall(instr.args_seg)
    if len(ops) < 2:
        return 2.0 * result_elems
    ker = _shape_dims(symtab.get(ops[1], ""))
    kelems = _shape_elems(",".join(map(str, ker[0][1]))) if ker else 1
    return 2.0 * result_elems * kelems


def _instr_bytes(instr: _Instr, symtab: Dict[str, str],
                 dus_fusions: Optional[Dict[str, float]] = None) -> float:
    """HBM traffic model: every materialized result is written once and
    read ≥ once downstream → 2 × result bytes, with in-place special cases.
    (Counting full operand bytes per consumer would triple-count buffers
    consumed by several cheap ops.)
    """
    op = instr.op
    if op in _SKIP_BYTES:
        return 0.0
    if op == "fusion" and dus_fusions is not None:
        # fusions rooted at dynamic-update-slice update in place: count the
        # update bytes, not the whole aliased result buffer
        fm = re.search(r"calls=%([\w.\-]+)", instr.line)
        if fm and fm.group(1) in dus_fusions:
            return 2.0 * dus_fusions[fm.group(1)] + 64
    result_b = _shapes_bytes(instr.result_seg)
    operand_names = _OPERAND_RE.findall(instr.args_seg)
    if op == "dynamic-update-slice":
        upd = (_shapes_bytes(symtab.get(operand_names[1], ""))
               if len(operand_names) > 1 else result_b)
        return 2.0 * upd + 64
    if op == "gather":
        idx = (_shapes_bytes(symtab.get(operand_names[1], ""))
               if len(operand_names) > 1 else 0)
        return 2.0 * result_b + idx
    if op == "scatter":
        upd = (_shapes_bytes(symtab.get(operand_names[2], ""))
               if len(operand_names) > 2 else result_b)
        idx = (_shapes_bytes(symtab.get(operand_names[1], ""))
               if len(operand_names) > 1 else 0)
        return 2.0 * upd + idx
    if op.startswith("all-gather"):
        g = _group_size(instr.meta_seg, instr.line)
        return result_b / max(g, 1) + result_b
    if op.startswith("reduce-scatter"):
        g = _group_size(instr.meta_seg, instr.line)
        return result_b * g + result_b
    if op == "dot":
        # MXU reads both operands from HBM (streamed once) + writes result
        operand_b = sum(_shapes_bytes(symtab.get(n, ""))
                        for n in operand_names)
        return result_b + operand_b
    return 2.0 * result_b


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes.values())


def analyze(text: str, entry_name: Optional[str] = None) -> HloCost:
    comps = _parse_computations(text)
    # find entry
    entry = None
    m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
    if m:
        entry = m.group(1)
    if entry is None or entry not in comps:
        entry = max(comps, key=lambda c: len(comps[c].instrs))

    # computations rooted at dynamic-update-slice — directly or as a tuple
    # of DUS outputs (multi-output fusions) — update in place when fused:
    # map name -> total update-operand bytes
    dus_fusions: Dict[str, float] = {}
    for cname, comp in comps.items():
        if not comp.instrs:
            continue
        by_name = {i.name: i for i in comp.instrs}

        def _as_dus(instr):
            """The instr, looked through dtype-convert wrappers (the CPU
            backend legalizes bf16 DUS chains as convert∘DUS∘convert —
            a TPU build updates in place with native bf16)."""
            seen = 0
            while instr is not None and instr.op == "convert" and seen < 3:
                ops_ = _OPERAND_RE.findall(instr.args_seg)
                instr = by_name.get(ops_[0]) if ops_ else None
                seen += 1
            if instr is not None and instr.op == "dynamic-update-slice":
                return instr
            return None

        root = comp.instrs[-1]
        roots = [root]
        if root.op == "tuple":
            roots = [by_name[n] for n in _OPERAND_RE.findall(root.args_seg)
                     if n in by_name]
        total = 0.0
        ok = bool(roots)
        for r in roots:
            dus = _as_dus(r)
            if dus is None:
                ok = False
                break
            ops_ = _OPERAND_RE.findall(dus.args_seg)
            if len(ops_) > 1:
                total += _shapes_bytes(comp.symtab.get(ops_[1], ""))
            else:
                ok = False
                break
        if ok:
            dus_fusions[cname] = total

    # multiplicities: (computation, flops_only) -> count
    mult: Dict[str, float] = {entry: 1.0}
    flops_only: Dict[str, bool] = {entry: False}
    order = [entry]
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        cm = mult[cname]
        conly = flops_only[cname]
        for ins in comp.instrs:
            if ins.op == "while":
                tm = _TRIP_RE.search(ins.line)
                trips = float(tm.group(1)) if tm else 1.0
                bm = re.search(r"body=%([\w.\-]+)", ins.line)
                if bm:
                    b = bm.group(1)
                    mult[b] = mult.get(b, 0.0) + cm * trips
                    flops_only[b] = conly and flops_only.get(b, True)
                    if b not in order:
                        order.append(b)
                    elif mult[b] > cm * trips:  # re-walk for accumulated mult
                        pass
            elif ins.op in ("fusion", "call"):
                fm = re.search(r"(?:calls|to_apply)=%([\w.\-]+)", ins.line)
                if fm:
                    f = fm.group(1)
                    mult[f] = mult.get(f, 0.0) + cm
                    if ins.op == "fusion":
                        # fusion internals: flops yes, bytes no (registers)
                        flops_only[f] = True
                    else:
                        # called computations (e.g. the CPU backend's
                        # parallel-task wrappers) materialize internally:
                        # bytes count unless the caller was flops-only
                        flops_only[f] = conly and flops_only.get(f, True)
                    if f not in order:
                        order.append(f)
            elif ins.op == "conditional":
                for br in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                     r"(?:true|false)_computation=%([\w.\-]+))",
                                     ins.line):
                    names = (br[0].split(",") if br[0] else [br[1]])
                    for nm in names:
                        nm = nm.strip().lstrip("%")
                        if nm:
                            mult[nm] = mult.get(nm, 0.0) + cm
                            flops_only[nm] = conly
                            if nm not in order:
                                order.append(nm)

    cost = HloCost()
    for cname, cm in mult.items():
        comp = comps.get(cname)
        if comp is None or cm == 0:
            continue
        conly = flops_only.get(cname, False)
        for ins in comp.instrs:
            if ins.op == "dot":
                cost.flops += cm * _dot_flops(ins, comp.symtab)
            elif ins.op == "convolution":
                cost.flops += cm * _conv_flops(ins, comp.symtab)
            for ck in _COLLECTIVES:
                if ins.op == ck or ins.op == ck + "-start":
                    g = _group_size(ins.meta_seg, ins.line)
                    rb = _shapes_bytes(ins.result_seg)
                    if ck == "all-gather":
                        ob = rb / max(g, 1)
                    elif ck == "reduce-scatter":
                        ob = rb * g
                    else:
                        ob = rb
                    cost.coll_bytes[ck] += cm * ob
                    cost.coll_counts[ck] += cm
            if not conly:
                cost.bytes += cm * _instr_bytes(ins, comp.symtab, dus_fusions)
    return cost
