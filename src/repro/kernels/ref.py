"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.attention import full_attention
from repro.models.mamba import ssd_chunked, ssd_reference


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q: (B,H,S,hd), k/v: (B,KH,S,hd) — same layout as the kernel."""
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = full_attention(qt, kt, vt, causal=causal, window=window)
    return jnp.transpose(out, (0, 2, 1, 3))


def ssd_scan_ref(x, dt, A, B, C, chunk):
    """Chunked SSD oracle (itself validated against ssd_reference)."""
    return ssd_chunked(x, dt, A, B, C, chunk)


ssd_scan_sequential = ssd_reference


def partition_copy_ref(dst, src, dst_off_rows, src_off_rows, rows):
    """Row-tiled §6.3 partition copy oracle.  dst/src: (N, 128) views."""
    block = jax.lax.dynamic_slice(src, (src_off_rows, 0),
                                  (rows, src.shape[1]))
    return jax.lax.dynamic_update_slice(dst, block.astype(dst.dtype),
                                        (dst_off_rows, 0))


def flash_decode_ref(q, k_cache, v_cache, cur_len, window=0):
    """q (B,1,H,hd); head-major caches (B,KH,S,hd); oracle via the
    seq-major decode_attention."""
    import jax.numpy as jnp
    from repro.models.attention import decode_attention
    kt = jnp.transpose(k_cache, (0, 2, 1, 3))
    vt = jnp.transpose(v_cache, (0, 2, 1, 3))
    return decode_attention(q, kt, vt, cur_len=cur_len, window=window)
