"""Pallas TPU flash attention (causal, GQA, sliding window) — differentiable.

TPU adaptation of the paper's §6 data-block partitioning at the memory
hierarchy: the (S × S) attention computation is partitioned into disjoint
(block_q × block_k) tiles; each grid step acquires its q-tile "EW" in VMEM
while streaming k/v tiles HBM→VMEM.  The online-softmax carry (m, l, acc)
lives in VMEM scratch and persists across the sequential innermost grid
dimension (TPU grids execute in order), exactly the inter-chunk state carry
pattern the paper expresses with partitions + events.

Three kernels, wired through ``jax.custom_vjp`` so the *training* path runs
on Pallas too (ROADMAP "Differentiable Pallas flash attention"):

* ``_fwd_kernel`` — forward; optionally emits the per-row logsumexp
  residual alongside the output (only the differentiated path pays for it).
* ``_bwd_dq_kernel`` — dq pass: grid (B, H, nq, nk), nk innermost, dq
  accumulated in VMEM scratch from the saved lse + delta.
* ``_bwd_dkv_kernel`` — dk/dv pass: grid (B, KH, nk, G, nq) with the
  (group, q-block) reduction innermost, so the GQA head-group sum lands in
  the same VMEM scratch carry — no (B, H, S, hd)-sized dk staging.

All three take the global ``q_offset`` as a scalar-prefetch operand (the
context-parallel stripe origin under ``repro.dist.flash``'s shard_map —
a traced ``axis_index`` product), so the causal/window masks and the
block-level ``pl.when`` skips stay globally positioned in both directions.

Layouts (chosen for MXU alignment):
  q:    (B, H, S, hd)      k, v: (B, KH, S, hd)
  out:  (B, H, S, hd)
Grid: (B, H, nq, nk), nk innermost (reduction).  Causal tiles with
j·bk > (i+1)·bq are skipped with ``pl.when`` — no wasted MXU work, unlike
the masked jnp oracle (see EXPERIMENTS.md §Perf).  Sequence lengths that
do not divide the block sizes are zero-padded at the edge and masked via
the static ``kv_len`` bound (the §6 masked-edge-tile treatment
``multi_partition_copy`` uses for ragged ranges).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# resolve whichever this jax provides
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _tile_mask(q_start, k_start: int, block_q: int, block_k: int,
               causal: bool, window: int, kv_len: int, sk_padded: int):
    """(block_q, block_k) boolean mask for one tile, or None when every
    element is live.  ``q_start`` is the tile's *global* first row (traced:
    it includes the scalar-prefetched stripe offset)."""
    if not (causal or window > 0 or kv_len < sk_padded):
        return None
    rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 0)
    cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = cols <= rows
    if window > 0:
        mask = jnp.logical_and(mask, rows - cols < window)
    if kv_len < sk_padded:
        mask = jnp.logical_and(mask, cols < kv_len)
    return mask


def _tile_run(q_start, k_start: int, block_q: int, block_k: int,
              causal: bool, window: int, kv_len: int, sk_padded: int):
    """Block-level ``pl.when`` predicate: False only if the whole tile is
    provably masked (the §6 tile-skip — no wasted MXU work)."""
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window > 0:
        run = jnp.logical_and(run,
                              q_start - (k_start + block_k - 1) < window)
    if kv_len < sk_padded:
        run = jnp.logical_and(run, jnp.bool_(k_start < kv_len))
    return run


# ---------------------------------------------------------------- forward

def _fwd_kernel(off_ref, q_ref, k_ref, v_ref, o_ref, *rest,
                block_q: int, block_k: int, num_kv_blocks: int,
                causal: bool, window: int, scale: float, kv_len: int,
                with_lse: bool):
    if with_lse:
        lse_ref, m_ref, l_ref, acc_ref = rest
    else:
        lse_ref, (m_ref, l_ref, acc_ref) = None, rest
    i = pl.program_id(2)
    j = pl.program_id(3)
    sk_padded = num_kv_blocks * block_k

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q + off_ref[0]          # global row of tile row 0
    k_start = j * block_k

    run = _tile_run(q_start, k_start, block_q, block_k, causal, window,
                    kv_len, sk_padded)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                   # (bq, bk)
        mask = _tile_mask(q_start, k_start, block_q, block_k, causal,
                          window, kv_len, sk_padded)
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        if mask is not None:
            # a fully-masked row in a live tile would otherwise contribute
            # exp(NEG_INF − NEG_INF) = 1 per element while m is still the
            # init value — zero the masked lanes explicitly
            p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)
        if with_lse:
            lse_ref[0, 0] = (m_ref[...] + jnp.log(l))[:, 0]


def _fwd_call(q, k, v, offs, *, causal: bool, window: int, block_q: int,
              block_k: int, kv_len: int, interpret: bool, with_lse: bool):
    b, h, sq, hd = q.shape
    _, kh, sk, _ = k.shape
    hd_v = v.shape[-1]
    g = h // kh
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / np.sqrt(hd)

    kernel = functools.partial(
        _fwd_kernel, block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        causal=causal, window=window, scale=scale, kv_len=kv_len,
        with_lse=with_lse)
    out_shape = [jax.ShapeDtypeStruct((b, h, sq, hd_v), q.dtype)]
    out_specs = [pl.BlockSpec((1, 1, block_q, hd_v),
                              lambda bb, hh, ii, jj, off: (bb, hh, ii, 0))]
    if with_lse:
        out_shape.append(jax.ShapeDtypeStruct((b, h, sq), jnp.float32))
        out_specs.append(pl.BlockSpec(
            (1, 1, block_q), lambda bb, hh, ii, jj, off: (bb, hh, ii)))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bb, hh, ii, jj, off: (bb, hh, ii, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, ii, jj, off: (bb, hh // g, jj, 0)),
            pl.BlockSpec((1, 1, block_k, hd_v),
                         lambda bb, hh, ii, jj, off: (bb, hh // g, jj, 0)),
        ],
        out_specs=out_specs,
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd_v), jnp.float32),
        ],
    )
    res = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(offs, q, k, v)
    return (res[0], res[1]) if with_lse else (res[0], None)


# --------------------------------------------------------------- backward

def _bwd_dq_kernel(off_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, dq_acc, *, block_q: int, block_k: int,
                   num_kv_blocks: int, causal: bool, window: int,
                   scale: float, kv_len: int):
    i = pl.program_id(2)
    j = pl.program_id(3)
    sk_padded = num_kv_blocks * block_k

    @pl.when(j == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start = i * block_q + off_ref[0]
    k_start = j * block_k
    run = _tile_run(q_start, k_start, block_q, block_k, causal, window,
                    kv_len, sk_padded)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd_v)
        do = do_ref[0, 0].astype(jnp.float32)          # (bq, hd_v)
        lse = lse_ref[0, 0].reshape(block_q, 1)
        delta = delta_ref[0, 0].reshape(block_q, 1)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _tile_mask(q_start, k_start, block_q, block_k, causal,
                          window, kv_len, sk_padded)
        p = jnp.exp(s - lse)                           # (bq, bk)
        if mask is not None:
            p = jnp.where(mask, p, 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(off_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *, block_q: int,
                    block_k: int, num_q_blocks: int, num_groups: int,
                    causal: bool, window: int, scale: float, kv_len: int,
                    sk_padded: int):
    j = pl.program_id(2)                               # k block
    gg = pl.program_id(3)                              # head within group
    i = pl.program_id(4)                               # q block

    @pl.when(jnp.logical_and(gg == 0, i == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = i * block_q + off_ref[0]
    k_start = j * block_k
    run = _tile_run(q_start, k_start, block_q, block_k, causal, window,
                    kv_len, sk_padded)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd_v)
        do = do_ref[0, 0].astype(jnp.float32)          # (bq, hd_v)
        lse = lse_ref[0, 0].reshape(block_q, 1)
        delta = delta_ref[0, 0].reshape(block_q, 1)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = _tile_mask(q_start, k_start, block_q, block_k, causal,
                          window, kv_len, sk_padded)
        p = jnp.exp(s - lse)                           # (bq, bk)
        if mask is not None:
            p = jnp.where(mask, p, 0.0)
        # dv += pᵀ · do ; contraction over the q rows
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(jnp.logical_and(gg == num_groups - 1, i == num_q_blocks - 1))
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd_call(q, k, v, do, lse, delta, offs, *, causal: bool, window: int,
              block_q: int, block_k: int, kv_len: int, interpret: bool):
    b, h, sq, hd = q.shape
    _, kh, sk, _ = k.shape
    hd_v = v.shape[-1]
    g = h // kh
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / np.sqrt(hd)

    # --- dq pass: grid (B, H, nq, nk), nk innermost reduction ------------
    dq_kernel = functools.partial(
        _bwd_dq_kernel, block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        causal=causal, window=window, scale=scale, kv_len=kv_len)
    dq = pl.pallas_call(
        dq_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, h, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, hd),
                             lambda bb, hh, ii, jj, off: (bb, hh, ii, 0)),
                pl.BlockSpec((1, 1, block_k, hd),
                             lambda bb, hh, ii, jj, off:
                             (bb, hh // g, jj, 0)),
                pl.BlockSpec((1, 1, block_k, hd_v),
                             lambda bb, hh, ii, jj, off:
                             (bb, hh // g, jj, 0)),
                pl.BlockSpec((1, 1, block_q, hd_v),
                             lambda bb, hh, ii, jj, off: (bb, hh, ii, 0)),
                pl.BlockSpec((1, 1, block_q),
                             lambda bb, hh, ii, jj, off: (bb, hh, ii)),
                pl.BlockSpec((1, 1, block_q),
                             lambda bb, hh, ii, jj, off: (bb, hh, ii)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, block_q, hd),
                lambda bb, hh, ii, jj, off: (bb, hh, ii, 0)),
            scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(offs, q, k, v, do, lse, delta)

    # --- dk/dv pass: grid (B, KH, nk, G, nq); the GQA group sum and the
    # q-block reduction both ride the innermost sequential dims, so dk/dv
    # accumulate per *kv* head directly in scratch ------------------------
    dkv_kernel = functools.partial(
        _bwd_dkv_kernel, block_q=block_q, block_k=block_k, num_q_blocks=nq,
        num_groups=g, causal=causal, window=window, scale=scale,
        kv_len=kv_len, sk_padded=nk * block_k)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, kh, nk, g, nq),
            in_specs=[
                pl.BlockSpec((1, 1, block_q, hd),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk * g + gg, ii, 0)),
                pl.BlockSpec((1, 1, block_k, hd),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, jj, 0)),
                pl.BlockSpec((1, 1, block_k, hd_v),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, jj, 0)),
                pl.BlockSpec((1, 1, block_q, hd_v),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk * g + gg, ii, 0)),
                pl.BlockSpec((1, 1, block_q),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk * g + gg, ii)),
                pl.BlockSpec((1, 1, block_q),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk * g + gg, ii)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, block_k, hd),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, jj, 0)),
                pl.BlockSpec((1, 1, block_k, hd_v),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, jj, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((block_k, hd), jnp.float32),
                pltpu.VMEM((block_k, hd_v), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, sk, hd), k.dtype),
            jax.ShapeDtypeStruct((b, kh, sk, hd_v), v.dtype),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary", "arbitrary")),
        interpret=interpret,
    )(offs, q, k, v, do, lse, delta)
    return dq, dk, dv


# ------------------------------------------------------------- custom VJP

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9))
def _flash(q, k, v, q_offset, causal, window, block_q, block_k, kv_len,
           interpret):
    """Primal (non-differentiated) call: no residual output."""
    offs = jnp.reshape(q_offset.astype(jnp.int32), (1,))
    out, _ = _fwd_call(q, k, v, offs, causal=causal, window=window,
                       block_q=block_q, block_k=block_k, kv_len=kv_len,
                       interpret=interpret, with_lse=False)
    return out


def _flash_fwd_rule(q, k, v, q_offset, causal, window, block_q, block_k,
                    kv_len, interpret):
    offs = jnp.reshape(q_offset.astype(jnp.int32), (1,))
    out, lse = _fwd_call(q, k, v, offs, causal=causal, window=window,
                         block_q=block_q, block_k=block_k, kv_len=kv_len,
                         interpret=interpret, with_lse=True)
    return out, (q, k, v, out, lse, offs)


def _flash_bwd_rule(causal, window, block_q, block_k, kv_len, interpret,
                    res, do):
    q, k, v, out, lse, offs = res
    # delta_i = rowsum(do · out), elementwise on the unblocked arrays (see
    # models.attention._flash_bwd for why not a blocked dot)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                            # (B, H, S)
    dq, dk, dv = _bwd_call(q, k, v, do, lse, delta, offs, causal=causal,
                           window=window, block_q=block_q, block_k=block_k,
                           kv_len=kv_len, interpret=interpret)
    return dq, dk, dv, jnp.zeros((), jnp.float32)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ----------------------------------------------------------------- public

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_offset=0.0, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, KH, S, hd) → (B, H, S, hd_v).

    Differentiable: the backward runs the ``_bwd_dq`` / ``_bwd_dkv``
    Pallas kernels from the saved logsumexp (O(S) memory), matching the
    jnp twin (``models.attention.flash_attention_jnp``) to fp32 tolerance.

    ``q_offset`` is the global position of q row 0 (a traced
    ``axis_index`` product under context-parallel shard_map); its
    cotangent is zero.  Sequence lengths need not divide the block sizes:
    edges are zero-padded and masked like the forward's causal tiles.
    """
    b, h, sq, hd = q.shape
    sk = k.shape[2]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    sq_p = -(-sq // block_q) * block_q
    sk_p = -(-sk // block_k) * block_k
    off = jnp.asarray(q_offset).astype(jnp.float32)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
    out = _flash(q, k, v, off, causal, window, block_q, block_k, int(sk),
                 interpret)
    return out[:, :, :sq] if sq_p != sq else out
