"""Pallas TPU flash attention (causal, GQA, sliding window) — differentiable.

TPU adaptation of the paper's §6 data-block partitioning at the memory
hierarchy: the (S × S) attention computation is partitioned into disjoint
(block_q × block_k) tiles; each grid step acquires its q-tile "EW" in VMEM
while streaming k/v tiles HBM→VMEM.  The online-softmax carry (m, l, acc)
lives in VMEM scratch and persists across the sequential innermost grid
dimension (TPU grids execute in order), exactly the inter-chunk state carry
pattern the paper expresses with partitions + events.

Block sizes are no longer constants: every call plans its tiles through
``kernels.autotune.plan_attention`` (VMEM footprint + edge-tile waste +
grid-step cost), unless the caller pins them.  Two structural choices ride
the plan:

* **GQA head folding** — queries live in a (B, KH, G, S, hd) layout and a
  grid step loads ``g_fold`` query heads of one kv head as a single
  (gf·bq, hd) tile, so the folded heads share the streamed k/v tile and
  their MACs batch into one dot.
* **Fused backward** — when dk/dv for the whole (padded) kv sequence fit
  the VMEM budget, backward is ONE kernel on grid (B, KH, nq, nk)
  computing dq, dk and dv per tile visit: dq accumulates in scratch
  (flushed when the k loop finishes), dk/dv accumulate into full-length
  revisited output blocks.  This recomputes the probability tile once
  instead of once per pass — ~30 % fewer MACs than the dq-pass + dkv-pass
  split, which remains as the fallback for long sequences.

All kernels take the global ``q_offset`` as a scalar-prefetch operand (the
context-parallel stripe origin under ``repro.dist.flash``'s shard_map —
a traced ``axis_index`` product), so the causal/window masks and the
block-level ``pl.when`` skips stay globally positioned in both directions.

Layouts (chosen for MXU alignment):
  q:    (B, H, S, hd) public → (B, KH, G, S, hd) internal
  k, v: (B, KH, S, hd)
Causal tiles with j·bk > (i+1)·bq are skipped with ``pl.when`` — no wasted
MXU work, unlike the masked jnp oracle.  Sequence lengths that do not
divide the block sizes are zero-padded at the edge and masked via the
static ``kv_len`` bound (the §6 masked-edge-tile treatment
``multi_partition_copy`` uses for ragged ranges).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import autotune
from repro.kernels.autotune import AttnPlan

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# resolve whichever this jax provides
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _tile_mask(q_start, k_start, gf: int, block_q: int, block_k: int,
               causal: bool, window: int, kv_len: int, sk_padded: int):
    """(gf·block_q, block_k) boolean mask for one folded tile, or None
    when every element is live.  ``q_start`` is the tile's *global* first
    row (traced: it includes the scalar-prefetched stripe offset); the
    ``gf`` folded heads share row positions, so the (block_q, block_k)
    mask tiles along the fold axis."""
    if not (causal or window > 0 or kv_len < sk_padded):
        return None
    rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 0)
    cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = cols <= rows
    if window > 0:
        mask = jnp.logical_and(mask, rows - cols < window)
    if kv_len < sk_padded:
        mask = jnp.logical_and(mask, cols < kv_len)
    if gf > 1:
        mask = jnp.tile(mask, (gf, 1))
    return mask


def _tile_run(q_start, k_start, block_q: int, block_k: int,
              causal: bool, window: int, kv_len: int, sk_padded: int):
    """Block-level ``pl.when`` predicate: False only if the whole tile is
    provably masked (the §6 tile-skip — no wasted MXU work)."""
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window > 0:
        run = jnp.logical_and(run,
                              q_start - (k_start + block_k - 1) < window)
    if kv_len < sk_padded:
        run = jnp.logical_and(run, jnp.bool_(k_start < kv_len))
    return run


def _dot(a, b, dims):
    return jax.lax.dot_general(a, b, (dims, ((), ())),
                               preferred_element_type=jnp.float32)


def _additive_mask(offs, gf: int, block_q: int, block_k: int, causal: bool,
                   window: int, kv_len: int, sk_padded: int):
    """Precomputed additive mask (0 / NEG_INF) for single-tile grids,
    built OUTSIDE the kernel: one (gf·bq, bk) f32 array shared by every
    grid step (and constant-folded by XLA when the offset is static)
    replaces the per-step iota/compare/select chain.  Masked lanes then
    vanish through exp underflow — ``exp(x + NEG_INF − m) == 0`` — the
    same convention the jnp twin uses."""
    rows = offs[0] + jax.lax.broadcasted_iota(jnp.int32,
                                              (block_q, block_k), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask = cols <= rows
    if window > 0:
        mask = jnp.logical_and(mask, rows - cols < window)
    if kv_len < sk_padded:
        mask = jnp.logical_and(mask, cols < kv_len)
    amask = jnp.where(mask, 0.0, NEG_INF).astype(jnp.float32)
    if gf > 1:
        amask = jnp.tile(amask, (gf, 1))
    return amask


# ---------------------------------------------------------------- forward

def _fwd_kernel(off_ref, q_ref, k_ref, v_ref, *rest,
                gf: int, block_q: int, block_k: int, num_kv_blocks: int,
                causal: bool, window: int, scale: float, kv_len: int,
                with_lse: bool, premask: bool):
    single = num_kv_blocks == 1
    if premask:
        mask_ref, *rest = rest
    o_ref, *rest = rest
    if single:
        lse_ref = rest[0] if with_lse else None
    elif with_lse:
        lse_ref, m_ref, l_ref, acc_ref = rest
    else:
        lse_ref, (m_ref, l_ref, acc_ref) = None, rest
    i = pl.program_id(2)
    j = pl.program_id(3)
    sk_padded = num_kv_blocks * block_k
    rows = gf * block_q
    hd_v = v_ref.shape[-1]

    q_start = i * block_q + off_ref[0]          # global row of tile row 0
    k_start = j * block_k

    def _tile_s():
        # fold scale into the q tile: (gf·bq, hd) multiplies instead of
        # (gf·bq, bk) on the logits
        q = q_ref[0, 0].reshape(rows, q_ref.shape[-1]).astype(
            jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        s = _dot(q, k, ((1,), (1,)))                   # (gf·bq, bk)
        if premask:
            s = s + mask_ref[...]
        else:
            mask = _tile_mask(q_start, k_start, gf, block_q, block_k,
                              causal, window, kv_len, sk_padded)
            if mask is not None:
                s = jnp.where(mask, s, NEG_INF)
        return s

    if single:
        # one kv tile: plain softmax, no carry scratch, no rescale.
        # Masked lanes vanish via exp underflow (twin convention).
        s = _tile_s()
        v = v_ref[0, 0].astype(jnp.float32)
        m = s.max(axis=1, keepdims=True)
        p = jnp.exp(s - m)
        l = jnp.maximum(p.sum(axis=1, keepdims=True), 1e-37)
        o = _dot(p, v, ((1,), (0,))) / l
        o_ref[0, 0] = o.reshape(gf, block_q, hd_v).astype(o_ref.dtype)
        if with_lse:
            lse_ref[0, 0] = (m + jnp.log(l)).reshape(gf, block_q)
        return

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    run = _tile_run(q_start, k_start, block_q, block_k, causal, window,
                    kv_len, sk_padded)

    @pl.when(run)
    def _compute():
        s = _tile_s()
        v = v_ref[0, 0].astype(jnp.float32)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + _dot(p, v, ((1,), (0,)))
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l).reshape(
            gf, block_q, hd_v).astype(o_ref.dtype)
        if with_lse:
            lse_ref[0, 0] = (m_ref[...] + jnp.log(l)).reshape(gf, block_q)


# ---- megakernels: grid (1,), whole arrays as blocks, one batched dot
# over (B, KH) per matmul.  One flat XLA computation, so the softmax
# elementwise chain runs at flat speed instead of the ~4x in-loop
# penalty a multi-step interpret grid pays, and the (B, KH) slices
# batch into single dot_generals instead of a grid dimension.  The
# planner picks this at shapes where the full (padded+masked) matrix
# costs less than the grid's per-step overheads.

def _mega_amask(off_ref, g: int, sq: int, sk: int, causal: bool,
                window: int, kv_len: int):
    """(g·sq, sk) additive mask shared by every (batch, kv head) slice
    (rows are global: stripe offset applies), or None when everything is
    live."""
    if not (causal or window > 0 or kv_len < sk):
        return None
    return _additive_mask(off_ref, g, sq, sk, causal, window, kv_len, sk)


def _bdot(a, b, contract):
    """dot_general batched over the leading (B, KH) dims."""
    return jax.lax.dot_general(a, b, (contract, ((0, 1), (0, 1))),
                               preferred_element_type=jnp.float32)


def _fwd_mega_kernel(off_ref, q_ref, k_ref, v_ref, *rest, g: int,
                     causal: bool, window: int, scale: float,
                     kv_len: int, with_lse: bool):
    o_ref = rest[0]
    lse_ref = rest[1] if with_lse else None
    b, kh, _, sq, hd = q_ref.shape
    sk = k_ref.shape[2]
    hd_v = v_ref.shape[-1]
    amask = _mega_amask(off_ref, g, sq, sk, causal, window, kv_len)
    q = q_ref[...].reshape(b, kh, g * sq, hd).astype(jnp.float32) * scale
    kt = k_ref[...].astype(jnp.float32)                # (b, kh, sk, hd)
    vt = v_ref[...].astype(jnp.float32)
    s = _bdot(q, kt, ((3,), (3,)))                     # (b, kh, g·sq, sk)
    if amask is not None:
        s = s + amask
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-37)
    o = _bdot(p, vt, ((3,), (2,))) / l
    o_ref[...] = o.reshape(b, kh, g, sq, hd_v).astype(o_ref.dtype)
    if with_lse:
        lse_ref[...] = (m + jnp.log(l)).reshape(b, kh, g, sq)


def _whole(shape):
    n = len(shape)
    return pl.BlockSpec(shape, lambda i, off, _n=n: (0,) * _n)


def _bt(shape):
    """Batch-tiled spec: one batch row per grid step, everything else
    whole.  The mega kernel bodies read ``b`` from the ref shape, so the
    same bodies run unchanged with b=1 blocks."""
    n = len(shape)
    return pl.BlockSpec((1,) + tuple(shape[1:]),
                        lambda i, off, _n=n: (i,) + (0,) * (_n - 1))


def _fwd_mega_call(q, k, v, offs, *, causal: bool, window: int,
                   kv_len: int, interpret: bool, with_lse: bool,
                   batch_tiled: bool = False):
    b, kh, g, sq, hd = q.shape
    hd_v = v.shape[-1]
    spec = _bt if batch_tiled else _whole
    kernel = functools.partial(
        _fwd_mega_kernel, g=g, causal=causal, window=window,
        scale=1.0 / np.sqrt(hd), kv_len=kv_len, with_lse=with_lse)
    out_shape = [jax.ShapeDtypeStruct((b, kh, g, sq, hd_v), q.dtype)]
    out_specs = [spec((b, kh, g, sq, hd_v))]
    if with_lse:
        out_shape.append(jax.ShapeDtypeStruct((b, kh, g, sq), jnp.float32))
        out_specs.append(spec((b, kh, g, sq)))
    res = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b,) if batch_tiled else (1,),
            in_specs=[spec(q.shape), spec(k.shape), spec(v.shape)],
            out_specs=out_specs,
        ),
        out_shape=out_shape,
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=(
                ("parallel",) if batch_tiled else ("arbitrary",))),
        interpret=interpret,
    )(offs, q, k, v)
    return (res[0], res[1]) if with_lse else (res[0], None)


def _bwd_mega_kernel(off_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                     delta_ref, dq_ref, dk_ref, dv_ref, *, g: int,
                     causal: bool, window: int, scale: float, kv_len: int):
    b, kh, _, sq, hd = q_ref.shape
    sk = k_ref.shape[2]
    hd_v = v_ref.shape[-1]
    amask = _mega_amask(off_ref, g, sq, sk, causal, window, kv_len)
    q = q_ref[...].reshape(b, kh, g * sq, hd).astype(jnp.float32)
    kt = k_ref[...].astype(jnp.float32)                # (b, kh, sk, hd)
    vt = v_ref[...].astype(jnp.float32)
    do = do_ref[...].reshape(b, kh, g * sq, hd_v).astype(jnp.float32)
    lse = lse_ref[...].reshape(b, kh, g * sq, 1)
    delta = delta_ref[...].reshape(b, kh, g * sq, 1)
    s = _bdot(q * scale, kt, ((3,), (3,)))
    if amask is not None:
        s = s + amask
    p = jnp.exp(s - lse)                               # (b, kh, g·sq, sk)
    # contraction over the g·sq rows IS the GQA group sum
    dv = _bdot(p, do, ((2,), (2,)))                    # (b, kh, sk, hd_v)
    dp = _bdot(do, vt, ((3,), (3,)))
    ds = p * (dp - delta) * scale
    dq = _bdot(ds, kt, ((3,), (2,)))
    dk = _bdot(ds, q, ((2,), (2,)))
    dq_ref[...] = dq.reshape(b, kh, g, sq, hd).astype(dq_ref.dtype)
    dk_ref[...] = dk.astype(dk_ref.dtype)
    dv_ref[...] = dv.astype(dv_ref.dtype)


def _bwd_mega_call(q, k, v, do, lse, delta, offs, *, causal: bool,
                   window: int, kv_len: int, interpret: bool,
                   batch_tiled: bool = False):
    b, kh, g, sq, hd = q.shape
    sk = k.shape[2]
    hd_v = v.shape[-1]
    spec = _bt if batch_tiled else _whole
    kernel = functools.partial(
        _bwd_mega_kernel, g=g, causal=causal, window=window,
        scale=1.0 / np.sqrt(hd), kv_len=kv_len)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b,) if batch_tiled else (1,),
            in_specs=[spec(q.shape), spec(k.shape), spec(v.shape),
                      spec(do.shape), spec(lse.shape),
                      spec(delta.shape)],
            out_specs=[spec((b, kh, g, sq, hd)),
                       spec((b, kh, sk, hd)),
                       spec((b, kh, sk, hd_v))],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, g, sq, hd), q.dtype),
            jax.ShapeDtypeStruct((b, kh, sk, hd), k.dtype),
            jax.ShapeDtypeStruct((b, kh, sk, hd_v), v.dtype),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=(
                ("parallel",) if batch_tiled else ("arbitrary",))),
        interpret=interpret,
    )(offs, q, k, v, do, lse, delta)


def _fwd_call(q, k, v, offs, *, causal: bool, window: int, plan: AttnPlan,
              kv_len: int, interpret: bool, with_lse: bool):
    if plan.mega_fwd or plan.mega_fwd_bt:
        return _fwd_mega_call(q, k, v, offs, causal=causal, window=window,
                              kv_len=kv_len, interpret=interpret,
                              with_lse=with_lse,
                              batch_tiled=plan.mega_fwd_bt)
    block_q, block_k, g_fold = plan.block_q, plan.block_k, plan.g_fold
    b, kh, g, sq, hd = q.shape
    sk = k.shape[2]
    hd_v = v.shape[-1]
    gf = g_fold if g % g_fold == 0 else 1
    ngf = g // gf
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / np.sqrt(hd)

    # single-tile grids get the additive mask precomputed outside the
    # kernel — one shared array instead of per-step iota/select chains
    premask = (nq == 1 and nk == 1
               and (causal or window > 0 or kv_len < sk))
    kernel = functools.partial(
        _fwd_kernel, gf=gf, block_q=block_q, block_k=block_k,
        num_kv_blocks=nk, causal=causal, window=window, scale=scale,
        kv_len=kv_len, with_lse=with_lse, premask=premask)
    out_shape = [jax.ShapeDtypeStruct((b, kh, g, sq, hd_v), q.dtype)]
    out_specs = [pl.BlockSpec(
        (1, 1, gf, block_q, hd_v),
        lambda bb, hh, ii, jj, off: (bb, hh // ngf, hh % ngf, ii, 0))]
    if with_lse:
        out_shape.append(jax.ShapeDtypeStruct((b, kh, g, sq), jnp.float32))
        out_specs.append(pl.BlockSpec(
            (1, 1, gf, block_q),
            lambda bb, hh, ii, jj, off: (bb, hh // ngf, hh % ngf, ii)))

    scratch = []
    if nk > 1:
        scratch = [
            pltpu.VMEM((gf * block_q, 1), jnp.float32),
            pltpu.VMEM((gf * block_q, 1), jnp.float32),
            pltpu.VMEM((gf * block_q, hd_v), jnp.float32),
        ]
    in_specs = [
        pl.BlockSpec((1, 1, gf, block_q, hd),
                     lambda bb, hh, ii, jj, off:
                     (bb, hh // ngf, hh % ngf, ii, 0)),
        pl.BlockSpec((1, 1, block_k, hd),
                     lambda bb, hh, ii, jj, off: (bb, hh // ngf, jj, 0)),
        pl.BlockSpec((1, 1, block_k, hd_v),
                     lambda bb, hh, ii, jj, off: (bb, hh // ngf, jj, 0)),
    ]
    operands = [offs, q, k, v]
    if premask:
        in_specs.append(pl.BlockSpec(
            (gf * block_q, block_k), lambda bb, hh, ii, jj, off: (0, 0)))
        operands.append(_additive_mask(offs, gf, block_q, block_k, causal,
                                       window, kv_len, block_k))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kh * ngf, nq, nk),
        in_specs=in_specs,
        out_specs=out_specs,
        scratch_shapes=scratch,
    )
    res = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(*operands)
    return (res[0], res[1]) if with_lse else (res[0], None)


# --------------------------------------------------------------- backward

def _bwd_dq_kernel(off_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, dq_acc, *, gf: int, block_q: int, block_k: int,
                   num_kv_blocks: int, causal: bool, window: int,
                   scale: float, kv_len: int):
    i = pl.program_id(2)
    j = pl.program_id(3)
    sk_padded = num_kv_blocks * block_k
    rows = gf * block_q
    hd = q_ref.shape[-1]

    @pl.when(j == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start = i * block_q + off_ref[0]
    k_start = j * block_k
    run = _tile_run(q_start, k_start, block_q, block_k, causal, window,
                    kv_len, sk_padded)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].reshape(rows, hd).astype(jnp.float32) * scale
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd_v)
        do = do_ref[0, 0].reshape(rows, v.shape[-1]).astype(jnp.float32)
        lse = lse_ref[0, 0].reshape(rows, 1)
        delta = delta_ref[0, 0].reshape(rows, 1)
        s = _dot(q, k, ((1,), (1,)))
        mask = _tile_mask(q_start, k_start, gf, block_q, block_k, causal,
                          window, kv_len, sk_padded)
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse)                           # (gf·bq, bk)
        dp = _dot(do, v, ((1,), (1,)))
        ds = p * (dp - delta) * scale
        dq_acc[...] += _dot(ds, k, ((1,), (0,)))

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc[...].reshape(
            gf, block_q, hd).astype(dq_ref.dtype)


def _bwd_dkv_kernel(off_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *, gf: int, block_q: int,
                    block_k: int, num_q_blocks: int, num_groups: int,
                    causal: bool, window: int, scale: float, kv_len: int,
                    sk_padded: int):
    j = pl.program_id(2)                               # k block
    gg = pl.program_id(3)                              # folded-head group
    i = pl.program_id(4)                               # q block
    rows = gf * block_q

    @pl.when(jnp.logical_and(gg == 0, i == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = i * block_q + off_ref[0]
    k_start = j * block_k
    run = _tile_run(q_start, k_start, block_q, block_k, causal, window,
                    kv_len, sk_padded)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].reshape(rows, q_ref.shape[-1]).astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd_v)
        do = do_ref[0, 0].reshape(rows, v.shape[-1]).astype(jnp.float32)
        lse = lse_ref[0, 0].reshape(rows, 1)
        delta = delta_ref[0, 0].reshape(rows, 1)
        s = _dot(q * scale, k, ((1,), (1,)))
        mask = _tile_mask(q_start, k_start, gf, block_q, block_k, causal,
                          window, kv_len, sk_padded)
        if mask is not None:
            s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse)                           # (gf·bq, bk)
        # dv += pᵀ · do — the contraction over the gf·bq rows IS the
        # GQA group sum for the folded heads
        dv_acc[...] += _dot(p, do, ((0,), (0,)))
        dp = _dot(do, v, ((1,), (1,)))
        ds = p * (dp - delta) * scale
        dk_acc[...] += _dot(ds, q, ((0,), (0,)))

    @pl.when(jnp.logical_and(gg == num_groups - 1, i == num_q_blocks - 1))
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd_fused_kernel(off_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                      delta_ref, *refs, g: int, block_q: int, block_k: int,
                      num_q_blocks: int, num_kv_blocks: int, causal: bool,
                      window: int, scale: float, kv_len: int,
                      premask: bool):
    """Fused dq+dk+dv: grid (B, KH, nq, nk), nk innermost.  dq rides VMEM
    scratch (flushed when the k loop finishes); dk/dv accumulate into
    whole-kv revisited output blocks — the probability tile is recomputed
    once per (i, j) visit instead of once per backward pass."""
    if premask:
        mask_ref, *refs = refs
    if len(refs) == 4:
        dq_ref, dk_ref, dv_ref, dq_acc = refs
    else:
        (dq_ref, dk_ref, dv_ref), dq_acc = refs, None
    i = pl.program_id(2)
    j = pl.program_id(3)
    sk_padded = num_kv_blocks * block_k
    rows = g * block_q
    hd = q_ref.shape[-1]
    single = num_q_blocks == 1 and num_kv_blocks == 1

    if not single:
        @pl.when(jnp.logical_and(i == 0, j == 0))
        def _init_kv():
            dk_ref[...] = jnp.zeros_like(dk_ref)
            dv_ref[...] = jnp.zeros_like(dv_ref)

        @pl.when(j == 0)
        def _init_q():
            dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start = i * block_q + off_ref[0]
    k_start = j * block_k

    def _compute():
        q = q_ref[0, 0].reshape(rows, hd).astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, hd_v)
        do = do_ref[0, 0].reshape(rows, v.shape[-1]).astype(jnp.float32)
        lse = lse_ref[0, 0].reshape(rows, 1)
        delta = delta_ref[0, 0].reshape(rows, 1)
        s = _dot(q * scale, k, ((1,), (1,)))
        if premask:
            s = s + mask_ref[...]
        else:
            mask = _tile_mask(q_start, k_start, g, block_q, block_k,
                              causal, window, kv_len, sk_padded)
            if mask is not None:
                s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse)                           # (g·bq, bk)
        dp = _dot(do, v, ((1,), (1,)))
        ds = p * (dp - delta) * scale
        dq = _dot(ds, k, ((1,), (0,)))
        dv = _dot(p, do, ((0,), (0,)))
        dk = _dot(ds, q, ((0,), (0,)))
        if single:
            # one tile: write grads straight through, no RMW/scratch
            dq_ref[0, 0] = dq.reshape(g, block_q, hd).astype(dq_ref.dtype)
            dk_ref[0, 0] = dk
            dv_ref[0, 0] = dv
        else:
            dq_acc[...] += dq
            dv_ref[0, 0, pl.ds(j * block_k, block_k)] += dv
            dk_ref[0, 0, pl.ds(j * block_k, block_k)] += dk

    if single:
        _compute()
        return

    run = _tile_run(q_start, k_start, block_q, block_k, causal, window,
                    kv_len, sk_padded)
    pl.when(run)(_compute)

    @pl.when(j == num_kv_blocks - 1)
    def _flush_dq():
        dq_ref[0, 0] = dq_acc[...].reshape(
            g, block_q, hd).astype(dq_ref.dtype)


def _bwd_call(q, k, v, do, lse, delta, offs, plan: AttnPlan, *,
              causal: bool, window: int, kv_len: int, interpret: bool):
    b, kh, g, sq, hd = q.shape
    sk = k.shape[2]
    hd_v = v.shape[-1]
    scale = 1.0 / np.sqrt(hd)

    if plan.mega_bwd or plan.mega_bwd_bt:
        return _bwd_mega_call(q, k, v, do, lse, delta, offs, causal=causal,
                              window=window, kv_len=kv_len,
                              interpret=interpret,
                              batch_tiled=plan.mega_bwd_bt)

    if plan.fused_bwd:
        bq, bk = plan.dq_block_q, plan.dq_block_k
        nq, nk = sq // bq, sk // bk
        single = nq == 1 and nk == 1
        premask = single and (causal or window > 0 or kv_len < sk)
        kernel = functools.partial(
            _bwd_fused_kernel, g=g, block_q=bq, block_k=bk,
            num_q_blocks=nq, num_kv_blocks=nk, causal=causal, window=window,
            scale=scale, kv_len=kv_len, premask=premask)
        in_specs = [
            pl.BlockSpec((1, 1, g, bq, hd),
                         lambda bb, hk, ii, jj, off:
                         (bb, hk, 0, ii, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda bb, hk, ii, jj, off:
                         (bb, hk, jj, 0)),
            pl.BlockSpec((1, 1, bk, hd_v),
                         lambda bb, hk, ii, jj, off:
                         (bb, hk, jj, 0)),
            pl.BlockSpec((1, 1, g, bq, hd_v),
                         lambda bb, hk, ii, jj, off:
                         (bb, hk, 0, ii, 0)),
            pl.BlockSpec((1, 1, g, bq),
                         lambda bb, hk, ii, jj, off:
                         (bb, hk, 0, ii)),
            pl.BlockSpec((1, 1, g, bq),
                         lambda bb, hk, ii, jj, off:
                         (bb, hk, 0, ii)),
        ]
        operands = [offs, q, k, v, do, lse, delta]
        if premask:
            in_specs.append(pl.BlockSpec(
                (g * bq, bk), lambda bb, hk, ii, jj, off: (0, 0)))
            operands.append(_additive_mask(offs, g, bq, bk, causal,
                                           window, kv_len, bk))
        dq, dk, dv = pl.pallas_call(
            kernel,
            grid_spec=pltpu.PrefetchScalarGridSpec(
                num_scalar_prefetch=1,
                grid=(b, kh, nq, nk),
                in_specs=in_specs,
                out_specs=[
                    pl.BlockSpec((1, 1, g, bq, hd),
                                 lambda bb, hk, ii, jj, off:
                                 (bb, hk, 0, ii, 0)),
                    # whole-kv revisited blocks: constant index per
                    # (batch, kv head) so the accumulator stays resident
                    pl.BlockSpec((1, 1, sk, hd),
                                 lambda bb, hk, ii, jj, off:
                                 (bb, hk, 0, 0)),
                    pl.BlockSpec((1, 1, sk, hd_v),
                                 lambda bb, hk, ii, jj, off:
                                 (bb, hk, 0, 0)),
                ],
                scratch_shapes=[] if single else
                [pltpu.VMEM((g * bq, hd), jnp.float32)],
            ),
            out_shape=[
                jax.ShapeDtypeStruct((b, kh, g, sq, hd), q.dtype),
                jax.ShapeDtypeStruct((b, kh, sk, hd), jnp.float32),
                jax.ShapeDtypeStruct((b, kh, sk, hd_v), jnp.float32),
            ],
            compiler_params=_COMPILER_PARAMS(
                dimension_semantics=("parallel", "parallel", "arbitrary",
                                     "arbitrary")),
            interpret=interpret,
        )(*operands)
        return dq, dk.astype(k.dtype), dv.astype(v.dtype)

    # --- two-call fallback -----------------------------------------------
    gf = plan.g_fold if g % plan.g_fold == 0 else 1
    ngf = g // gf
    bq, bk = plan.dq_block_q, plan.dq_block_k
    nq, nk = sq // bq, sk // bk

    # dq pass: grid (B, KH·ngf, nq, nk), nk innermost reduction
    dq_kernel = functools.partial(
        _bwd_dq_kernel, gf=gf, block_q=bq, block_k=bk, num_kv_blocks=nk,
        causal=causal, window=window, scale=scale, kv_len=kv_len)
    dq = pl.pallas_call(
        dq_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, kh * ngf, nq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, gf, bq, hd),
                             lambda bb, hh, ii, jj, off:
                             (bb, hh // ngf, hh % ngf, ii, 0)),
                pl.BlockSpec((1, 1, bk, hd),
                             lambda bb, hh, ii, jj, off:
                             (bb, hh // ngf, jj, 0)),
                pl.BlockSpec((1, 1, bk, hd_v),
                             lambda bb, hh, ii, jj, off:
                             (bb, hh // ngf, jj, 0)),
                pl.BlockSpec((1, 1, gf, bq, hd_v),
                             lambda bb, hh, ii, jj, off:
                             (bb, hh // ngf, hh % ngf, ii, 0)),
                pl.BlockSpec((1, 1, gf, bq),
                             lambda bb, hh, ii, jj, off:
                             (bb, hh // ngf, hh % ngf, ii)),
                pl.BlockSpec((1, 1, gf, bq),
                             lambda bb, hh, ii, jj, off:
                             (bb, hh // ngf, hh % ngf, ii)),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, gf, bq, hd),
                lambda bb, hh, ii, jj, off:
                (bb, hh // ngf, hh % ngf, ii, 0)),
            scratch_shapes=[pltpu.VMEM((gf * bq, hd), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, sq, hd), q.dtype),
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(offs, q, k, v, do, lse, delta)

    # dk/dv pass: grid (B, KH, nk, ngf, nq); the folded-group sum and the
    # q-block reduction both ride the innermost sequential dims, so dk/dv
    # accumulate per *kv* head directly in scratch
    dbq, dbk = plan.dkv_block_q, plan.dkv_block_k
    dnq, dnk = sq // dbq, sk // dbk
    dkv_kernel = functools.partial(
        _bwd_dkv_kernel, gf=gf, block_q=dbq, block_k=dbk, num_q_blocks=dnq,
        num_groups=ngf, causal=causal, window=window, scale=scale,
        kv_len=kv_len, sk_padded=dnk * dbk)
    dk, dv = pl.pallas_call(
        dkv_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, kh, dnk, ngf, dnq),
            in_specs=[
                pl.BlockSpec((1, 1, gf, dbq, hd),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, gg, ii, 0)),
                pl.BlockSpec((1, 1, dbk, hd),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, jj, 0)),
                pl.BlockSpec((1, 1, dbk, hd_v),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, jj, 0)),
                pl.BlockSpec((1, 1, gf, dbq, hd_v),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, gg, ii, 0)),
                pl.BlockSpec((1, 1, gf, dbq),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, gg, ii)),
                pl.BlockSpec((1, 1, gf, dbq),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, gg, ii)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, dbk, hd),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, jj, 0)),
                pl.BlockSpec((1, 1, dbk, hd_v),
                             lambda bb, hk, jj, gg, ii, off:
                             (bb, hk, jj, 0)),
            ],
            scratch_shapes=[
                pltpu.VMEM((dbk, hd), jnp.float32),
                pltpu.VMEM((dbk, hd_v), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, kh, sk, hd), k.dtype),
            jax.ShapeDtypeStruct((b, kh, sk, hd_v), v.dtype),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary", "arbitrary")),
        interpret=interpret,
    )(offs, q, k, v, do, lse, delta)
    return dq, dk, dv


# ------------------------------------------------------------- custom VJP

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _flash(q, k, v, q_offset, causal, window, plan, kv_len, interpret):
    """Primal (non-differentiated) call: no residual output.  ``q`` is the
    internal 5-d (B, KH, G, S, hd) layout; ``plan`` is the (hashable)
    ``AttnPlan`` carrying every block decision."""
    offs = jnp.reshape(q_offset.astype(jnp.int32), (1,))
    out, _ = _fwd_call(q, k, v, offs, causal=causal, window=window,
                       plan=plan, kv_len=kv_len,
                       interpret=interpret, with_lse=False)
    return out


def _flash_fwd_rule(q, k, v, q_offset, causal, window, plan, kv_len,
                    interpret):
    offs = jnp.reshape(q_offset.astype(jnp.int32), (1,))
    out, lse = _fwd_call(q, k, v, offs, causal=causal, window=window,
                         plan=plan, kv_len=kv_len,
                         interpret=interpret, with_lse=True)
    return out, (q, k, v, out, lse, offs)


def _flash_bwd_rule(causal, window, plan, kv_len, interpret, res, do):
    q, k, v, out, lse, offs = res
    # delta_i = rowsum(do · out), elementwise on the unblocked arrays (see
    # models.attention._flash_bwd for why not a blocked dot)
    delta = jnp.sum(do.astype(jnp.float32) * out.astype(jnp.float32),
                    axis=-1)                            # (B, KH, G, S)
    dq, dk, dv = _bwd_call(q, k, v, do, lse, delta, offs, plan,
                           causal=causal, window=window, kv_len=kv_len,
                           interpret=interpret)
    return dq, dk, dv, jnp.zeros((), jnp.float32)


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ----------------------------------------------------------------- public

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_offset=0.0, *, causal: bool = True, window: int = 0,
                    block_q: int | None = None, block_k: int | None = None,
                    interpret: bool = False,
                    plan: AttnPlan | None = None) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, KH, S, hd) → (B, H, S, hd_v).

    Differentiable: the backward runs the Pallas kernels (fused or
    dq/dkv two-call, per the plan) from the saved logsumexp (O(S)
    memory), matching the jnp twin
    (``models.attention.flash_attention_jnp``) to fp32 tolerance.

    Block sizes come from ``kernels.autotune.plan_attention`` unless
    ``block_q``/``block_k`` pin them (or a full ``plan`` is supplied).
    ``q_offset`` is the global position of q row 0 (a traced
    ``axis_index`` product under context-parallel shard_map); its
    cotangent is zero.  Sequence lengths need not divide the block sizes:
    edges are zero-padded and masked like the forward's causal tiles.
    """
    b, h, sq, hd = q.shape
    _, kh, sk, _ = k.shape
    hd_v = v.shape[-1]
    g = h // kh
    if plan is None:
        # a traced q_offset (context-parallel stripe) means no tile is
        # provably dead at trace time — plan with every tile live
        static_off = isinstance(q_offset, (int, float, np.integer,
                                           np.floating))
        plan = autotune.plan_attention(
            sq, sk, hd, hd_v, g, kh, b, np.dtype(q.dtype).itemsize * 8,
            bool(causal), int(window), int(sk), diag_aligned=static_off,
            backend="interpret" if interpret else "tpu",
            block_q=block_q, block_k=block_k)
    sq_p = -(-sq // plan.block_q) * plan.block_q
    sk_p = -(-sk // plan.block_k) * plan.block_k
    off = jnp.asarray(q_offset).astype(jnp.float32)
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, sq_p - sq), (0, 0)))
    if sk_p != sk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, sk_p - sk), (0, 0)))
    q5 = q.reshape(b, kh, g, sq_p, hd)
    out = _flash(q5, k, v, off, causal, window, plan, int(sk), interpret)
    out = out.reshape(b, h, sq_p, hd_v)
    return out[:, :, :sq] if sq_p != sq else out
