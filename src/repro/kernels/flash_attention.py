"""Pallas TPU flash attention (causal, GQA, optional sliding window).

TPU adaptation of the paper's §6 data-block partitioning at the memory
hierarchy: the (S × S) attention computation is partitioned into disjoint
(block_q × block_k) tiles; each grid step acquires its q-tile "EW" in VMEM
while streaming k/v tiles HBM→VMEM.  The online-softmax carry (m, l, acc)
lives in VMEM scratch and persists across the sequential innermost grid
dimension (TPU grids execute in order), exactly the inter-chunk state carry
pattern the paper expresses with partitions + events.

Layouts (chosen for MXU alignment):
  q:    (B, H, S, hd)      k, v: (B, KH, S, hd)
  out:  (B, H, S, hd)
Grid: (B, H, nq, nk), nk innermost (reduction).  Causal tiles with
j·bk > (i+1)·bq are skipped with ``pl.when`` — no wasted MXU work, unlike
the masked jnp oracle (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# resolve whichever this jax provides
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  block_q: int, block_k: int, num_kv_blocks: int,
                  causal: bool, window: int, scale: float):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = i * block_q
    k_start = j * block_k

    # causal block-level skip: tile strictly above the diagonal
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window > 0:
        run = jnp.logical_and(run, q_start - (k_start + block_k - 1) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                   # (bq, bk)
        if causal or window > 0:
            rows = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            mask = cols <= rows
            if window > 0:
                mask = jnp.logical_and(mask, rows - cols < window)
            s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new
        l_ref[...] = l_new

    @pl.when(j == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (B, H, S, hd); k, v: (B, KH, S, hd) → (B, H, S, hd)."""
    b, h, sq, hd = q.shape
    _, kh, sk, _ = k.shape
    hd_v = v.shape[-1]
    g = h // kh
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k
    scale = 1.0 / np.sqrt(hd)

    grid = (b, h, nq, nk)
    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        causal=causal, window=window, scale=scale)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda bb, hh, ii, jj: (bb, hh, ii, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda bb, hh, ii, jj: (bb, hh // g, jj, 0)),
            pl.BlockSpec((1, 1, block_k, hd_v),
                         lambda bb, hh, ii, jj: (bb, hh // g, jj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd_v),
                               lambda bb, hh, ii, jj: (bb, hh, ii, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd_v), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
