"""Pallas TPU flash-decode: one-token attention against a head-major cache.

Serving hot path: q (B, KH, G, hd) attends to a (B, KH, S, hd) cache (the
framework's head-major decode layout — no relayout between the cache DUS
and this kernel).  Grid (B, KH, ns) with the sequence dimension innermost:
the online-softmax carry (m, l, acc) persists in VMEM scratch across
sequence blocks, and blocks entirely past ``cur_len`` are skipped with
``pl.when`` — the §6 partitioning of the cache into EW stripes, walked
sequentially per (batch, kv-head).

``cur_len`` (tokens valid in the cache, including the just-inserted one)
arrives as a (1, 1) int32 array broadcast to every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import autotune

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# resolve whichever this jax provides
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams

NEG_INF = -1e30


def _decode_kernel(cur_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, block_s: int, num_blocks: int, scale: float,
                   window: int):
    j = pl.program_id(2)
    cur = cur_ref[0, 0]                                # valid entries

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    base = j * block_s
    run = base < cur                                   # §6 stripe skip
    if window > 0:
        run = jnp.logical_and(run, base + block_s > cur - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (block_s, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                   # (G, block_s)
        pos = base + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        mask = pos < cur
        if window > 0:
            mask = jnp.logical_and(mask, pos >= cur - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == num_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                 cur_len: jax.Array, *, window: int = 0,
                 block_s: int | None = None,
                 interpret: bool = False) -> jax.Array:
    """q: (B, KH, G, hd); caches: (B, KH, S, hd); cur_len: () int32.

    Returns (B, KH, G, hd_v).  cur_len counts valid cache entries
    (the new token must already be written at cur_len − 1).
    ``block_s=None`` asks the autotuner for a pow2 divisor of the cache
    length sized to the VMEM budget.
    """
    b, kh, g, hd = q.shape
    s = k_cache.shape[2]
    hd_v = v_cache.shape[-1]
    if block_s is None:
        block_s = autotune.plan_decode(
            s, g, hd, hd_v, q.dtype.itemsize * 8,
            backend="interpret" if interpret else "tpu")
    block_s = min(block_s, s)
    assert s % block_s == 0
    ns = s // block_s
    scale = 1.0 / np.sqrt(hd)
    cur = jnp.reshape(cur_len.astype(jnp.int32), (1, 1))

    kernel = functools.partial(_decode_kernel, block_s=block_s,
                               num_blocks=ns, scale=scale, window=window)
    return pl.pallas_call(
        kernel,
        grid=(b, kh, ns),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, hh, jj: (0, 0)),
            pl.BlockSpec((1, 1, g, hd), lambda bb, hh, jj: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda bb, hh, jj: (bb, hh, jj, 0)),
            pl.BlockSpec((1, 1, block_s, hd_v),
                         lambda bb, hh, jj: (bb, hh, jj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd_v),
                               lambda bb, hh, jj: (bb, hh, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kh, g, hd_v), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, hd_v), jnp.float32),
        ],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(cur, q, k_cache, v_cache)
