"""jit'd wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU, so
the same call sites work in tests and on hardware.  Layout adaptation from
model conventions (B, S, H, hd) to kernel conventions (B, H, S, hd) lives
here, not in model code.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import flash_decode as _fd
from . import partition_copy as _pc
from . import ssd_scan as _ssd
from ..core.objects import spans_overlap


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, q_offset=0.0, *, causal=True, window=0,
                    block_q=None, block_k=None, interpret=None):
    """Model layout: q (B,S,H,hd), k/v (B,S,KH,hd) → (B,S,H,hd_v).

    Differentiable (custom-VJP backward kernels); ``q_offset`` is the
    global position of q row 0 under context-parallel stripes — a traced
    operand, not a static argument, so shard_map `axis_index` products
    trace through.  ``block_q``/``block_k`` default to the trace-time
    autotuner (``repro.kernels.autotune``); ints pin the tiles.
    """
    interpret = _default_interpret() if interpret is None else interpret
    qt = jnp.transpose(q, (0, 2, 1, 3))
    kt = jnp.transpose(k, (0, 2, 1, 3))
    vt = jnp.transpose(v, (0, 2, 1, 3))
    out = _fa.flash_attention(qt, kt, vt, q_offset, causal=causal,
                              window=window, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    return jnp.transpose(out, (0, 2, 1, 3))


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk=128, interpret=None):
    """Model layout: x (B,S,H,P), dt (B,S,H), B/C (B,S,N).

    Returns (y (B,S,H,P), state (B,H,P,N)).
    """
    interpret = _default_interpret() if interpret is None else interpret
    xt = jnp.transpose(x, (0, 2, 1, 3))
    dtt = jnp.transpose(dt, (0, 2, 1))
    y, st = _ssd.ssd_scan(xt, dtt, A, B, C, chunk=chunk, interpret=interpret)
    return jnp.transpose(y, (0, 2, 1, 3)), st


@functools.partial(jax.jit, static_argnames=("dst_off", "src_off", "size",
                                             "interpret"))
def partition_copy_bytes(dst, src, *, dst_off, src_off, size, interpret=None):
    """§6.3 fallback copy on flat byte buffers.

    dst/src: (N,) uint8.  Returns new dst with src[src_off:src_off+size]
    written at dst_off.  Offsets/size need only be lane-aligned (128 B);
    32 KiB-aligned copies keep the tile-per-grid-step fast path, anything
    else routes through the fused masked-edge kernel as a single range.
    """
    interpret = _default_interpret() if interpret is None else interpret
    lanes = _pc.LANES
    block = 256 * lanes
    assert dst.shape[0] % lanes == 0 and src.shape[0] % lanes == 0
    assert dst_off % lanes == 0 and src_off % lanes == 0 and size % lanes == 0
    d2 = dst.reshape(-1, lanes)
    s2 = src.reshape(-1, lanes)
    if dst_off % block == 0 and src_off % block == 0 and size % block == 0:
        out = _pc.partition_copy(d2, s2, dst_off // lanes, src_off // lanes,
                                 size // lanes, interpret=interpret)
    else:
        out = _pc.multi_partition_copy(
            d2, s2, ((dst_off // lanes, src_off // lanes, size // lanes),),
            interpret=interpret)
    return out.reshape(-1)


def multi_partition_copy_bytes(dst, src, ranges, *, block_rows=256,
                               interpret=None):
    """Fused §6.3 copy of a whole partition set in one kernel launch.

    dst/src: (N,) uint8 byte buffers.  ``ranges`` is a sequence of
    ``(dst_off, src_off, size)`` byte triples, each a multiple of 128
    (lane granularity — NOT the 32 KiB tile granularity of
    :func:`partition_copy_bytes`).  Destination ranges must be mutually
    disjoint; overlap raises ``ValueError`` (§6.2 partitions are disjoint
    by construction, so an overlap is a caller bug).  Returns the new dst.
    """
    interpret = _default_interpret() if interpret is None else interpret
    lanes = _pc.LANES
    nd, ns = int(dst.shape[0]), int(src.shape[0])
    row_ranges = []
    for (d_off, s_off, size) in ranges:
        if size <= 0:
            raise ValueError(f"empty copy range ({d_off},{s_off},{size})")
        if d_off % lanes or s_off % lanes or size % lanes:
            raise ValueError(
                f"range ({d_off},{s_off},{size}) not 128-byte aligned")
        if d_off + size > nd or s_off + size > ns or d_off < 0 or s_off < 0:
            raise ValueError(
                f"range ({d_off},{s_off},{size}) out of bounds "
                f"(dst {nd}, src {ns})")
        row_ranges.append((d_off // lanes, s_off // lanes, size // lanes))
    if spans_overlap((d, d + n) for d, _, n in row_ranges):
        raise ValueError("destination ranges overlap")
    pad_d = (-nd) % lanes
    pad_s = (-ns) % lanes
    d2 = (jnp.pad(dst, (0, pad_d)) if pad_d else jnp.asarray(dst)) \
        .reshape(-1, lanes)
    s2 = (jnp.pad(src, (0, pad_s)) if pad_s else jnp.asarray(src)) \
        .reshape(-1, lanes)
    out = _pc.multi_partition_copy(d2, s2, tuple(row_ranges),
                                   block_rows=block_rows,
                                   interpret=interpret)
    return out.reshape(-1)[:nd]


@functools.partial(jax.jit, static_argnames=("window", "block_s",
                                             "interpret"))
def flash_decode(q, k_cache, v_cache, cur_len, *, window=0, block_s=None,
                 interpret=None):
    """Serving layout: q (B,1,H,hd), head-major caches (B,KH,S,hd).

    Returns (B, 1, H, hd_v).  cur_len = valid entries incl. the new
    token.  ``block_s`` defaults to ``autotune.plan_decode``.
    """
    interpret = _default_interpret() if interpret is None else interpret
    b, one, h, hd = q.shape
    kh = k_cache.shape[1]
    g = h // kh
    qg = q.reshape(b, kh, g, hd)
    out = _fd.flash_decode(qg, k_cache, v_cache, cur_len, window=window,
                           block_s=block_s, interpret=interpret)
    return out.reshape(b, 1, h, out.shape[-1])
