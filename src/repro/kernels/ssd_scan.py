"""Pallas TPU kernel for the Mamba2 SSD chunked scan.

Grid: (B, H, nc) with the chunk dimension innermost (sequential): the
carried state (P × N) lives in VMEM scratch across chunk steps — the §6
"partition + carried event" pattern on the time axis.  Per chunk the
intra-block term is two MXU matmuls ((Q×N)·(N×Q) and (Q×Q)·(Q×P)) plus the
state in/out projections; all compute in fp32.

Layouts:
  x:  (B, H, S, P)    dt: (B, H, S)   A: (H,)
  B/C: (B, S, N)      out: (B, H, S, P), final state (B, H, P, N)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across 0.4.x/0.5.x;
# resolve whichever this jax provides
_COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, o_ref, st_out_ref,
                state_ref, *, chunk: int, num_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)          # (1, Q) -> (Q,)
    dt = dt.reshape(chunk)
    a = a_ref[0]                                    # scalar A_h
    bmat = b_ref[0].astype(jnp.float32)            # (Q, N)
    cmat = c_ref[0].astype(jnp.float32)            # (Q, N)

    da = dt * a                                     # (Q,) ≤ 0
    cum = jnp.cumsum(da)                            # (Q,)
    total = cum[-1]

    # intra-chunk: att[q, t] = (C_q · B_t) * exp(cum_q - cum_t) * dt_t, t ≤ q
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(cum[:, None] - cum[None, :])
    att = jnp.where(rows >= cols, cb * decay * dt[None, :], 0.0)
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # off-diagonal: y += exp(cum_q) * C_q @ state_prev^T   (state: (P, N))
    prev = state_ref[...]                           # (P, N)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cmat, prev, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # state update: state = exp(total) * prev + Σ_t exp(total - cum_t) dt_t x_t B_t
    w = jnp.exp(total - cum) * dt                   # (Q,)
    xw = x * w[:, None]                             # (Q, P)
    new_contrib = jax.lax.dot_general(xw, bmat, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    state_ref[...] = jnp.exp(total) * prev + new_contrib     # (P, N)

    o_ref[0, 0] = y.astype(o_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _finalize():
        st_out_ref[0, 0] = state_ref[...].astype(st_out_ref.dtype)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
             C: jax.Array, *, chunk: int = 128,
             interpret: bool = False):
    """x: (B,H,S,P); dt: (B,H,S); A: (H,); B/C: (B,S,N).

    Returns (y (B,H,S,P), final_state (B,H,P,N)).
    """
    b, h, s, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk

    dt3 = dt.reshape(b, h, 1, s)                    # 2D-iota-friendly block
    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc)

    y, st = pl.pallas_call(
        kernel,
        grid=(b, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bb, hh, cc: (bb, hh, cc, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda bb, hh, cc: (bb, hh, 0, cc)),
            pl.BlockSpec((1,), lambda bb, hh, cc: (hh,)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, cc: (bb, cc, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, hh, cc: (bb, cc, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, p), lambda bb, hh, cc: (bb, hh, cc, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bb, hh, cc: (bb, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h, s, p), x.dtype),
            jax.ShapeDtypeStruct((b, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=_COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt3, A.astype(jnp.float32), B, C)
    return y, st
