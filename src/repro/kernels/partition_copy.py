"""Pallas TPU kernels materializing §6.3 ``ocrDbCopy(DB_COPY_PARTITION)``.

When the zero-copy view path is unavailable (partition crosses a device
boundary, or the runtime chose to materialize), the copy itself is the
fallback.  Two kernels implement it:

* :func:`partition_copy` — one contiguous tile-aligned range, one grid step
  per (rows × 128) tile staged through VMEM.
* :func:`multi_partition_copy` — a whole *partition set* in one
  ``pallas_call``: N disjoint ranges at lane (128 B) granularity, driven by
  scalar-prefetched per-block source/dest row tables.  Range lengths need
  not be block-aligned; edge tiles are handled by a masked read-modify-write
  so untouched destination rows are preserved bit-exactly.

Above :data:`DMA_STAGE_BYTES` of buffer, the batched kernel's
whole-buffer VMEM residency stops being a plan (a 32 MiB spill buffer
doesn't fit a 16 MiB VMEM), so ``multi_partition_copy`` re-stages: the
buffers stay in HBM (``memory_space=pltpu.ANY``) and each grid step
moves one autotuner-sized chunk through a double-buffered VMEM stage
with explicit ``pltpu.make_async_copy`` DMAs — the next chunk's source
fetch is in flight while the current chunk merges.  Same tables, same
table order, same masked-RMW edge handling, so arrival-order/hazard
semantics are identical to the batched path.

dst/src are 2-D (N, 128) views of the flat byte buffers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import autotune

LANES = 128

# Buffer size above which multi_partition_copy switches from whole-buffer
# VMEM residency to the HBM-staged chunked-DMA kernel.
DMA_STAGE_BYTES = 16 * 2 ** 20


def dma_staged(dst_bytes: int, src_bytes: int) -> bool:
    """True when a copy over buffers this large takes the DMA-staged
    path (either buffer too big for whole-buffer VMEM residency)."""
    return max(dst_bytes, src_bytes) > DMA_STAGE_BYTES


def _copy_kernel(src_ref, dst_in_ref, o_ref):
    del dst_in_ref  # aliased with o_ref; untouched tiles keep dst contents
    o_ref[...] = src_ref[...]


def partition_copy(dst: jax.Array, src: jax.Array, dst_off_rows: int,
                   src_off_rows: int, rows: int, *, block_rows: int = 256,
                   interpret: bool = False) -> jax.Array:
    """Copy ``rows`` rows of ``src`` (from src_off_rows) into ``dst`` at
    dst_off_rows.  Rows are (·, 128) lanes.  Returns the new dst.

    Offsets and length must be multiples of ``block_rows`` (the §6.2
    partition-granularity constraint, tile-aligned on TPU); ops.py pads.
    """
    assert dst.shape[1] == LANES and src.shape[1] == LANES
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    assert dst_off_rows % block_rows == 0 and src_off_rows % block_rows == 0
    nb = rows // block_rows
    d_base = dst_off_rows // block_rows
    s_base = src_off_rows // block_rows

    return pl.pallas_call(
        _copy_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, LANES),
                               lambda i: (s_base + i, 0)),
                  pl.BlockSpec((block_rows, LANES),
                               lambda i: (d_base + i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES),
                               lambda i: (d_base + i, 0)),
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(src, dst)


def _block_tables(ranges, block_rows: int):
    """Flatten row ranges into per-grid-block (dst, src, valid-rows) tables."""
    d_tab, s_tab, n_tab = [], [], []
    for (d0, s0, rows) in ranges:
        nb = -(-rows // block_rows)
        for b in range(nb):
            d_tab.append(d0 + b * block_rows)
            s_tab.append(s0 + b * block_rows)
            n_tab.append(min(block_rows, rows - b * block_rows))
    return (np.asarray(d_tab, np.int32), np.asarray(s_tab, np.int32),
            np.asarray(n_tab, np.int32))


def multi_partition_copy(dst: jax.Array, src: jax.Array,
                         ranges, *, block_rows: int = 256,
                         interpret: bool = False) -> jax.Array:
    """Execute N disjoint-range copies in a single ``pallas_call``.

    ``ranges`` is a tuple of ``(dst_row, src_row, rows)`` row triples
    (a row is one 128-byte lane).  Offsets are lane-granular — no block
    alignment required; each range's edge tile is masked.  The grid has
    one step per ``block_rows`` tile of any range; the tile's source/dest
    rows come from scalar-prefetched tables, so the whole partition set
    costs one kernel launch.  Destination ranges must be disjoint
    (callers validate); results are bit-exact vs range-by-range numpy
    assignment.

    The offset tables are runtime operands: only the block *count* (their
    length) and buffer shapes key the jit cache, so flushes with new
    offsets but the same number of tiles reuse the compiled kernel.
    """
    assert dst.shape[1] == LANES and src.shape[1] == LANES
    if dma_staged(dst.shape[0] * LANES * dst.dtype.itemsize,
                  src.shape[0] * LANES * src.dtype.itemsize):
        total = sum(r for (_, _, r) in ranges)
        chunk = autotune.plan_copy_chunk(int(total))
        d_tab, s_tab, n_tab = _block_tables(ranges, chunk)
        if d_tab.shape[0] == 0:
            return dst
        return _multi_partition_copy_dma(
            dst, src, jnp.asarray(d_tab), jnp.asarray(s_tab),
            jnp.asarray(n_tab), chunk=chunk, interpret=interpret)
    d_tab, s_tab, n_tab = _block_tables(ranges, block_rows)
    if d_tab.shape[0] == 0:
        return dst
    return _multi_partition_copy_impl(
        dst, src, jnp.asarray(d_tab), jnp.asarray(s_tab), jnp.asarray(n_tab),
        block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _multi_partition_copy_impl(dst: jax.Array, src: jax.Array,
                               d_tab: jax.Array, s_tab: jax.Array,
                               n_tab: jax.Array, *, block_rows: int,
                               interpret: bool) -> jax.Array:
    total_blocks = int(d_tab.shape[0])
    nd = dst.shape[0]
    # pad by one block so edge tiles can load/store block_rows full rows;
    # masked RMW keeps the pad rows' (and any untouched rows') contents
    dst_p = jnp.pad(dst, ((0, block_rows), (0, 0)))
    src_p = jnp.pad(src, ((0, block_rows), (0, 0)))

    def kernel(d_ref, s_ref, n_ref, src_ref, dst_in_ref, o_ref):
        del dst_in_ref  # aliased with o_ref; read through o_ref for RMW
        i = pl.program_id(0)
        dr = d_ref[i]
        sr = s_ref[i]
        nv = n_ref[i]
        rows = jax.lax.broadcasted_iota(jnp.int32, (block_rows, LANES), 0)
        val = src_ref[pl.ds(sr, block_rows), :]
        cur = o_ref[pl.ds(dr, block_rows), :]
        o_ref[pl.ds(dr, block_rows), :] = jnp.where(rows < nv, val, cur)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(total_blocks,),
        in_specs=[pl.BlockSpec(src_p.shape, lambda i, *_: (0, 0)),
                  pl.BlockSpec(dst_p.shape, lambda i, *_: (0, 0))],
        out_specs=pl.BlockSpec(dst_p.shape, lambda i, *_: (0, 0)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_p.shape, dst_p.dtype),
        # operand indices include the 3 scalar-prefetch tables: dst_in is 4
        input_output_aliases={4: 0},
        interpret=interpret,
    )(jnp.asarray(d_tab), jnp.asarray(s_tab), jnp.asarray(n_tab),
      src_p, dst_p)
    return out[:nd]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def _multi_partition_copy_dma(dst: jax.Array, src: jax.Array,
                              d_tab: jax.Array, s_tab: jax.Array,
                              n_tab: jax.Array, *, chunk: int,
                              interpret: bool) -> jax.Array:
    """HBM-staged variant: buffers never become VMEM-resident blocks.

    src/dst live in ``pltpu.ANY`` (HBM on hardware); each grid step
    DMAs one ``chunk``-row table entry through a two-slot VMEM stage —
    while chunk *i* merges, chunk *i+1*'s source fetch is already in
    flight (started one step ahead on the other slot/semaphore pair).
    The destination chunk is fetched, merged under the valid-row mask
    (same edge treatment as the batched kernel), and DMA'd back before
    the step ends, so table order — and therefore hazard/arrival
    semantics — matches the batched path exactly.
    """
    total_blocks = int(d_tab.shape[0])
    nd = dst.shape[0]
    # pad by one chunk so edge tiles can move full-chunk DMAs; the
    # masked merge keeps pad-row (and untouched-row) contents
    dst_p = jnp.pad(dst, ((0, chunk), (0, 0)))
    src_p = jnp.pad(src, ((0, chunk), (0, 0)))

    def kernel(d_ref, s_ref, n_ref, src_ref, dst_in_ref, o_ref,
               scr, sdst, sem_a, sem_b, sem_d, sem_o):
        del dst_in_ref  # aliased with o_ref; RMW goes through o_ref
        i = pl.program_id(0)
        n = pl.num_programs(0)

        def _src_copy(blk, slot, sem):
            return pltpu.make_async_copy(
                src_ref.at[pl.ds(s_ref[blk], chunk)], scr.at[slot], sem)

        @pl.when(i == 0)
        def _first():
            _src_copy(0, 0, sem_a).start()

        @pl.when(jnp.logical_and(i + 1 < n, (i + 1) % 2 == 0))
        def _prefetch_even():
            _src_copy(i + 1, 0, sem_a).start()

        @pl.when(jnp.logical_and(i + 1 < n, (i + 1) % 2 == 1))
        def _prefetch_odd():
            _src_copy(i + 1, 1, sem_b).start()

        def _merge(slot, sem):
            _src_copy(i, slot, sem).wait()
            dcp = pltpu.make_async_copy(
                o_ref.at[pl.ds(d_ref[i], chunk)], sdst, sem_d)
            dcp.start()
            dcp.wait()
            rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, LANES), 0)
            scr[slot] = jnp.where(rows < n_ref[i], scr[slot], sdst[...])
            ocp = pltpu.make_async_copy(
                scr.at[slot], o_ref.at[pl.ds(d_ref[i], chunk)], sem_o)
            ocp.start()
            ocp.wait()

        @pl.when(i % 2 == 0)
        def _even():
            _merge(0, sem_a)

        @pl.when(i % 2 == 1)
        def _odd():
            _merge(1, sem_b)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(total_blocks,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY),
                  pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        scratch_shapes=[
            pltpu.VMEM((2, chunk, LANES), dst.dtype),
            pltpu.VMEM((chunk, LANES), dst.dtype),
            pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA, pltpu.SemaphoreType.DMA,
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_p.shape, dst_p.dtype),
        input_output_aliases={4: 0},
        interpret=interpret,
    )(d_tab, s_tab, n_tab, src_p, dst_p)
    return out[:nd]
