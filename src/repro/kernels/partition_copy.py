"""Pallas TPU kernel materializing §6.3 ``ocrDbCopy(DB_COPY_PARTITION)``.

When the zero-copy view path is unavailable (partition crosses a device
boundary, or the runtime chose to materialize), the copy itself is the
fallback.  This kernel is that fallback as a TPU-native tiled HBM→HBM copy:
lane-aligned (rows × 128) tiles staged through VMEM, offsets expressed in
tiles — i.e. the §6.2 rule "partitions are contiguous, non-overlapping
ranges" becomes "tile-aligned row ranges".

dst/src are 2-D (N, 128) views of the flat byte buffers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _copy_kernel(src_ref, dst_in_ref, o_ref):
    del dst_in_ref  # aliased with o_ref; untouched tiles keep dst contents
    o_ref[...] = src_ref[...]


def partition_copy(dst: jax.Array, src: jax.Array, dst_off_rows: int,
                   src_off_rows: int, rows: int, *, block_rows: int = 256,
                   interpret: bool = False) -> jax.Array:
    """Copy ``rows`` rows of ``src`` (from src_off_rows) into ``dst`` at
    dst_off_rows.  Rows are (·, 128) lanes.  Returns the new dst.

    Offsets and length must be multiples of ``block_rows`` (the §6.2
    partition-granularity constraint, tile-aligned on TPU); ops.py pads.
    """
    assert dst.shape[1] == LANES and src.shape[1] == LANES
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    assert dst_off_rows % block_rows == 0 and src_off_rows % block_rows == 0
    nb = rows // block_rows
    d_base = dst_off_rows // block_rows
    s_base = src_off_rows // block_rows

    return pl.pallas_call(
        _copy_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, LANES),
                               lambda i: (s_base + i, 0)),
                  pl.BlockSpec((block_rows, LANES),
                               lambda i: (d_base + i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES),
                               lambda i: (d_base + i, 0)),
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(src, dst)
