"""Pallas TPU kernels materializing §6.3 ``ocrDbCopy(DB_COPY_PARTITION)``.

When the zero-copy view path is unavailable (partition crosses a device
boundary, or the runtime chose to materialize), the copy itself is the
fallback.  Two kernels implement it:

* :func:`partition_copy` — one contiguous tile-aligned range, one grid step
  per (rows × 128) tile staged through VMEM.
* :func:`multi_partition_copy` — a whole *partition set* in one
  ``pallas_call``: N disjoint ranges at lane (128 B) granularity, driven by
  scalar-prefetched per-block source/dest row tables.  Range lengths need
  not be block-aligned; edge tiles are handled by a masked read-modify-write
  so untouched destination rows are preserved bit-exactly.

dst/src are 2-D (N, 128) views of the flat byte buffers.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _copy_kernel(src_ref, dst_in_ref, o_ref):
    del dst_in_ref  # aliased with o_ref; untouched tiles keep dst contents
    o_ref[...] = src_ref[...]


def partition_copy(dst: jax.Array, src: jax.Array, dst_off_rows: int,
                   src_off_rows: int, rows: int, *, block_rows: int = 256,
                   interpret: bool = False) -> jax.Array:
    """Copy ``rows`` rows of ``src`` (from src_off_rows) into ``dst`` at
    dst_off_rows.  Rows are (·, 128) lanes.  Returns the new dst.

    Offsets and length must be multiples of ``block_rows`` (the §6.2
    partition-granularity constraint, tile-aligned on TPU); ops.py pads.
    """
    assert dst.shape[1] == LANES and src.shape[1] == LANES
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0
    assert dst_off_rows % block_rows == 0 and src_off_rows % block_rows == 0
    nb = rows // block_rows
    d_base = dst_off_rows // block_rows
    s_base = src_off_rows // block_rows

    return pl.pallas_call(
        _copy_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, LANES),
                               lambda i: (s_base + i, 0)),
                  pl.BlockSpec((block_rows, LANES),
                               lambda i: (d_base + i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANES),
                               lambda i: (d_base + i, 0)),
        out_shape=jax.ShapeDtypeStruct(dst.shape, dst.dtype),
        input_output_aliases={1: 0},
        interpret=interpret,
    )(src, dst)


def _block_tables(ranges, block_rows: int):
    """Flatten row ranges into per-grid-block (dst, src, valid-rows) tables."""
    d_tab, s_tab, n_tab = [], [], []
    for (d0, s0, rows) in ranges:
        nb = -(-rows // block_rows)
        for b in range(nb):
            d_tab.append(d0 + b * block_rows)
            s_tab.append(s0 + b * block_rows)
            n_tab.append(min(block_rows, rows - b * block_rows))
    return (np.asarray(d_tab, np.int32), np.asarray(s_tab, np.int32),
            np.asarray(n_tab, np.int32))


def multi_partition_copy(dst: jax.Array, src: jax.Array,
                         ranges, *, block_rows: int = 256,
                         interpret: bool = False) -> jax.Array:
    """Execute N disjoint-range copies in a single ``pallas_call``.

    ``ranges`` is a tuple of ``(dst_row, src_row, rows)`` row triples
    (a row is one 128-byte lane).  Offsets are lane-granular — no block
    alignment required; each range's edge tile is masked.  The grid has
    one step per ``block_rows`` tile of any range; the tile's source/dest
    rows come from scalar-prefetched tables, so the whole partition set
    costs one kernel launch.  Destination ranges must be disjoint
    (callers validate); results are bit-exact vs range-by-range numpy
    assignment.

    The offset tables are runtime operands: only the block *count* (their
    length) and buffer shapes key the jit cache, so flushes with new
    offsets but the same number of tiles reuse the compiled kernel.
    """
    assert dst.shape[1] == LANES and src.shape[1] == LANES
    d_tab, s_tab, n_tab = _block_tables(ranges, block_rows)
    if d_tab.shape[0] == 0:
        return dst
    return _multi_partition_copy_impl(
        dst, src, jnp.asarray(d_tab), jnp.asarray(s_tab), jnp.asarray(n_tab),
        block_rows=block_rows, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _multi_partition_copy_impl(dst: jax.Array, src: jax.Array,
                               d_tab: jax.Array, s_tab: jax.Array,
                               n_tab: jax.Array, *, block_rows: int,
                               interpret: bool) -> jax.Array:
    total_blocks = int(d_tab.shape[0])
    nd = dst.shape[0]
    # pad by one block so edge tiles can load/store block_rows full rows;
    # masked RMW keeps the pad rows' (and any untouched rows') contents
    dst_p = jnp.pad(dst, ((0, block_rows), (0, 0)))
    src_p = jnp.pad(src, ((0, block_rows), (0, 0)))

    def kernel(d_ref, s_ref, n_ref, src_ref, dst_in_ref, o_ref):
        del dst_in_ref  # aliased with o_ref; read through o_ref for RMW
        i = pl.program_id(0)
        dr = d_ref[i]
        sr = s_ref[i]
        nv = n_ref[i]
        rows = jax.lax.broadcasted_iota(jnp.int32, (block_rows, LANES), 0)
        val = src_ref[pl.ds(sr, block_rows), :]
        cur = o_ref[pl.ds(dr, block_rows), :]
        o_ref[pl.ds(dr, block_rows), :] = jnp.where(rows < nv, val, cur)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(total_blocks,),
        in_specs=[pl.BlockSpec(src_p.shape, lambda i, *_: (0, 0)),
                  pl.BlockSpec(dst_p.shape, lambda i, *_: (0, 0))],
        out_specs=pl.BlockSpec(dst_p.shape, lambda i, *_: (0, 0)),
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_p.shape, dst_p.dtype),
        # operand indices include the 3 scalar-prefetch tables: dst_in is 4
        input_output_aliases={4: 0},
        interpret=interpret,
    )(jnp.asarray(d_tab), jnp.asarray(s_tab), jnp.asarray(n_tab),
      src_p, dst_p)
    return out[:nd]
