"""Trace-time block autotuning for the Pallas kernels (§6 partitioning).

The paper's §6 data-block partitioning promises tile-granular accesses at
hardware speed — but a *fixed* tile size can't deliver that across shapes:
a 512-row q block on a 68-row context-parallel stripe is 87 % edge-tile
waste, while 64-row blocks on a 4096-token sequence pay 4096 grid-step
overheads for work 8× fewer steps could do.  So the partition size is
chosen at **trace time** from the static shape, via a cost model with
three terms:

* **VMEM footprint** — every candidate is rejected unless its resident
  tiles (double-buffered streamed operands + f32 scratch carries) fit the
  per-kernel budget.  This is a hard constraint, not a cost term.
* **edge-tile waste** — padded tiles do masked work on dead rows/cols;
  the model charges the *padded* MAC count, so a block that divides the
  sequence beats one that overhangs it.
* **grid-step count** — each grid step pays a fixed overhead (pipeline
  bookkeeping on TPU, interpreter dispatch in interpret mode) plus the
  k/v tile re-fetch.  Fewer, larger steps amortize it; the VMEM budget
  caps how far that goes.

Beyond (block_q, block_k) the planner picks two structural knobs the
fixed-constant path never had:

* ``g_fold`` — how many GQA query heads of one kv head share a grid
  step.  Folded heads reuse the streamed k/v tile (G× fewer k/v fetches)
  and batch their MACs into one dot; the q tile grows gf×, so VMEM
  decides.
* ``fused`` backward — when dk/dv for the whole (padded) kv sequence fit
  in VMEM, the backward runs as ONE kernel computing dq, dk and dv per
  tile visit, recomputing the probability tile once instead of once per
  pass (~30 % fewer MACs than the dq-pass + dkv-pass split).

Plans are pure functions of static ints — cached, deterministic, no
runtime measurement — so they never retrace and behave identically on
every host.  Config overrides (``attn_block_q/k``) win over the model
when set.
"""
from __future__ import annotations

import dataclasses
import functools
import os

__all__ = [
    "AttnPlan", "plan_attention", "plan_decode", "plan_copy_chunk",
    "min_block", "edge_waste", "live_tiles", "vmem_budget_bytes",
    "MIN_BLOCK", "MAX_BLOCK", "DEFAULT_VMEM_BUDGET", "LANES",
]

MIN_BLOCK = 16               # smallest tile the planner will choose
MAX_BLOCK = 2048             # largest tile the planner will consider
LANES = 128

# Default per-kernel VMEM budget: sized for a TPU v4-ish core (16 MiB
# VMEM) with headroom for the Mosaic pipeline's own buffers.
DEFAULT_VMEM_BUDGET = 12 * 2 ** 20
# Interpret-mode "VMEM" is host RAM: a larger per-kernel working set is
# harmless, and the 512-row tiles it admits are the measured winners at
# hd=128 (a 12 MiB budget rejects them and forces losing 128/256 tiles).
INTERPRET_VMEM_BUDGET = 32 * 2 ** 20
# Largest *grid-path* tile per backend.  Interpret stops at 512: every
# committed bench shape was measured at 128/256/512 and 512 wins, while
# >512 tiles blow up the in-loop transients without measured benefit.
GRID_BLOCK_CAP = {"interpret": 512, "tpu": MAX_BLOCK}

# Per-grid-step fixed overhead, in MAC-equivalents (1 MAC ≈ 0.015 ns on
# the ~65 GMAC/s single-core interpret baseline; ~100 GMAC/s/core TPU).
STEP_COST = {"interpret": 500_000, "tpu": 100_000}
# Cost per streamed byte, in MAC-equivalents (HBM→VMEM ~1 MAC/byte at
# TPU roofline; interpret's slicing traffic is modeled by
# STEP_BYTE_COST below instead).
BYTE_COST = {"interpret": 0.0, "tpu": 1.0}
# Interpret's dominant per-step cost: the interpreter touches the WHOLE
# operand buffers on every grid step (block gather/scatter over the
# full arrays), so each step costs ~0.17 ns/byte of total pass
# footprint (~6 GB/s memcpy) — fitted from the committed sweep at
# S ∈ {1024, 4096}: 0.63 ms/step @ 4 MB operands, 2.6 ms/step @ 16 MB.
# Steps skipped by ``pl.when`` still pay about half (gather/scatter
# happens; the body doesn't).  A compiled TPU pipeline streams only the
# tiles (BYTE_COST) — this term is zero there.
STEP_BYTE_COST = {"interpret": 11.0, "tpu": 0.0}
# Cost per softmax-matrix element (the exp/where/max chain), in
# MAC-equivalents.  Fitted from the sweep: a live in-loop tile costs
# ~8.2 ns/elem *including* its MACs → ~390 MACs/elem of pure
# elementwise; the flat (single-step) computation runs the same chain
# at ~5.3 ns/elem → ~340.  The in-loop/flat gap is what lets the
# single-step megakernel win at small shapes.  TPU pipelines the VPU
# chain behind the MXU: near-free.
ELEM_COST = {"interpret": 390.0, "interpret_flat": 340.0, "tpu": 2.0,
             "tpu_flat": 2.0}
# Feasibility gate for the single-step megakernels.  On TPU the whole
# problem must genuinely sit in VMEM, so the regular budget applies
# (None = use the VMEM budget).  In interpret mode "VMEM" is host RAM
# and the gate only bounds the materialized (B·KH·G·S·S) softmax
# transients.
MEGA_BUDGET = {"interpret": 192 * 2 ** 20, "tpu": None}


def vmem_budget_bytes(backend: str = "tpu") -> int:
    """Per-kernel VMEM budget (bytes); ``REPRO_VMEM_BUDGET`` overrides."""
    env = os.environ.get("REPRO_VMEM_BUDGET")
    if env is not None:
        return int(env)
    if backend.startswith("interpret"):
        return INTERPRET_VMEM_BUDGET
    return DEFAULT_VMEM_BUDGET


def min_block() -> int:
    """Smallest block the planner can pick — the floor consumers like
    ``flash_min_seq`` derive thresholds from (a sequence of
    ``2·min_block()`` is the shortest that can fill two q tiles)."""
    return MIN_BLOCK


def edge_waste(seq: int, block: int) -> float:
    """Dead fraction of the padded sequence: (padded − live) / live.

    Monotone non-increasing in ``seq`` between multiples of ``block``
    (more live rows amortize the same pad), zero exactly at multiples.
    """
    if seq <= 0:
        return 0.0
    padded = -(-seq // block) * block
    return (padded - seq) / seq


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _pow2s(lo: int, hi: int):
    b = lo
    while b <= hi:
        yield b
        b *= 2


def live_tiles(sq: int, sk: int, block_q: int, block_k: int, causal: bool,
               window: int, kv_len: int, diag_aligned: bool = True) -> int:
    """Tiles the kernel actually computes (the ``pl.when`` skip count).

    ``diag_aligned``: the q rows end at the kv end (the local
    sq == kv_len case — offset statically known to be kv_len − sq).
    Under context-parallel stripes the offset is a *traced*
    ``axis_index`` product, so no tile is provably dead at trace time
    and every tile counts.
    """
    nq, nk = _ceil_div(sq, block_q), _ceil_div(sk, block_k)
    if not diag_aligned:
        if kv_len < nk * block_k:
            nk_live = _ceil_div(kv_len, block_k)
            return nq * nk_live
        return nq * nk
    off = max(kv_len - sq, 0)
    live = 0
    for i in range(nq):
        for j in range(nk):
            k0 = j * block_k
            if k0 >= kv_len:
                continue
            q_last = off + (i + 1) * block_q - 1
            if causal and k0 > q_last:
                continue
            if window > 0:
                q0 = off + i * block_q
                if q0 - (k0 + block_k - 1) >= window:
                    continue
            live += 1
    return live


def _divisors(n: int):
    return [d for d in range(1, n + 1) if n % d == 0]


@dataclasses.dataclass(frozen=True)
class AttnPlan:
    """Blocks chosen for one flash-attention shape — fwd and both
    backward structures.  Hashable (it rides ``custom_vjp`` nondiff
    args and jit static args)."""
    block_q: int                 # forward q tile rows
    block_k: int                 # forward k tile rows
    g_fold: int                  # query heads per grid step (divides G)
    fused_bwd: bool              # one fused dq+dk+dv kernel?
    # dq-pass blocks (two-call backward; also the fused kernel's tiles)
    dq_block_q: int
    dq_block_k: int
    # dk/dv-pass blocks; dkv_block_q is the q-reduction block riding the
    # innermost sequential grid dim
    dkv_block_q: int
    dkv_block_k: int
    vmem_bytes: int              # worst per-kernel footprint estimate
    # single-step folded kernels: the whole (B, KH) problem in one grid
    # step, batch/kv-head loop unrolled in the body.  Escapes the
    # interpret backend's in-loop elementwise penalty; only chosen when
    # the single-tile footprint fits the budget.
    mega_fwd: bool = False
    mega_bwd: bool = False
    # batch-tiled mega: grid over B only, one batch row per step.  The
    # softmax transient shrinks by 1/B, so serving-size batches keep the
    # flat elementwise chain when the full-batch transient blows
    # MEGA_BUDGET; each extra grid step costs one STEP_COST.
    mega_fwd_bt: bool = False
    mega_bwd_bt: bool = False

    @property
    def padded_q(self):
        """Pad target for sq: lcm-free — every pass block divides
        blocks chosen as divisors of the fwd-padded length."""
        return self.block_q

    def describe(self) -> str:
        fb = "fused" if self.fused_bwd else \
            f"dq{self.dq_block_q}x{self.dq_block_k}/" \
            f"dkv{self.dkv_block_q}x{self.dkv_block_k}"
        mega = "".join([" mega_fwd" if self.mega_fwd else "",
                        " mega_bwd" if self.mega_bwd else "",
                        " mega_fwd_bt" if self.mega_fwd_bt else "",
                        " mega_bwd_bt" if self.mega_bwd_bt else ""])
        return (f"bq{self.block_q} bk{self.block_k} gf{self.g_fold} "
                f"bwd={fb} vmem={self.vmem_bytes // 1024}KiB{mega}")


def _fwd_vmem(bq: int, bk: int, gf: int, hd: int, hd_v: int,
              in_bytes: int) -> int:
    # streamed k/v tiles are double-buffered by the pipeline; q/out/lse
    # change only with the outer q index but budget them buffered too
    tiles = 2 * (bk * (hd + hd_v)) * in_bytes \
        + 2 * (gf * bq * (hd + hd_v + 1)) * in_bytes
    scratch = gf * bq * (hd_v + 2) * 4          # acc, m, l (f32)
    return tiles + scratch


def _dq_vmem(bq: int, bk: int, gf: int, hd: int, hd_v: int,
             in_bytes: int) -> int:
    tiles = 2 * (bk * (hd + hd_v)) * in_bytes \
        + 2 * (gf * bq * (hd + hd_v + 2 + hd)) * in_bytes
    scratch = gf * bq * hd * 4                  # dq accumulator
    return tiles + scratch


def _dkv_vmem(bq: int, bk: int, gf: int, hd: int, hd_v: int,
              in_bytes: int) -> int:
    tiles = 2 * (gf * bq * (hd + hd_v + 2)) * in_bytes \
        + 2 * (bk * (hd + hd_v)) * in_bytes * 2     # k/v in + dk/dv out
    scratch = bk * (hd + hd_v) * 4              # dk, dv accumulators
    return tiles + scratch


def _fused_vmem(bq: int, bk: int, g: int, sk_p: int, hd: int, hd_v: int,
                in_bytes: int) -> int:
    tiles = 2 * (bk * (hd + hd_v)) * in_bytes \
        + 2 * (g * bq * (hd + hd_v + 2 + hd)) * in_bytes
    resident = sk_p * (hd + hd_v) * in_bytes    # dk/dv whole-kv out blocks
    scratch = g * bq * hd * 4                   # dq accumulator
    return tiles + resident + scratch


def _pass_cost(sq: int, sk: int, bq: int, bk: int, gf: int, g: int,
               kh: int, batch: int, hd_work: int, causal: bool,
               window: int, kv_len: int, diag_aligned: bool,
               step_cost: float, byte_cost: float, elem_cost: float,
               step_byte_cost: float, pass_bytes: int,
               in_bytes: int) -> float:
    """One kernel pass: padded MACs + softmax-matrix elementwise chain +
    per-step overhead (fixed + whole-pass-footprint gather/scatter) +
    streamed tile bytes.  ``pass_bytes`` is the TOTAL operand footprint
    of the pass (all batch/head slices) — interpret touches all of it
    on every grid step."""
    nq, nk = _ceil_div(sq, bq), _ceil_div(sk, bk)
    live = live_tiles(sq, sk, bq, bk, causal, window, kv_len, diag_aligned)
    groups = _ceil_div(g, gf)
    seq_steps = nq * nk * groups                 # per (batch, kv head)
    live_steps = live * groups
    tile_elems = gf * bq * bk
    macs = live_steps * tile_elems * hd_work
    kv_bytes = seq_steps * bk * hd_work * in_bytes
    per_bh = (macs + live_steps * tile_elems * elem_cost
              + live_steps * step_cost
              + (seq_steps - live_steps) * 0.25 * step_cost
              + kv_bytes * byte_cost)
    dead_steps = seq_steps - live_steps
    step_traffic = ((live_steps + 0.5 * dead_steps) * kh * batch
                    * pass_bytes * step_byte_cost)
    return per_bh * kh * batch + step_traffic


@functools.lru_cache(maxsize=4096)
def plan_attention(sq: int, sk: int, hd: int, hd_v: int, g: int, kh: int,
                   batch: int, dtype_bits: int, causal: bool, window: int,
                   kv_len: int, diag_aligned: bool = True,
                   backend: str = "interpret",
                   vmem_budget: int | None = None,
                   block_q: int | None = None,
                   block_k: int | None = None) -> AttnPlan:
    """Choose blocks for one flash-attention shape (trace-time, cached).

    ``block_q`` / ``block_k`` are the config *overrides*: when given
    they pin the forward AND backward tiles (clamped to the sequence),
    bypassing the search — the knob configs keep for reproducing a
    hand-tuned layout.  Everything else — g_fold, the fused-backward
    choice — is still planned, but under the pinned tiles.
    """
    budget = vmem_budget_bytes(backend) if vmem_budget is None else vmem_budget
    step_cost = STEP_COST.get(backend, STEP_COST["tpu"])
    byte_cost = BYTE_COST.get(backend, BYTE_COST["tpu"])
    sbc = STEP_BYTE_COST.get(backend, STEP_BYTE_COST["tpu"])
    elem_in = ELEM_COST.get(backend, ELEM_COST["tpu"])
    elem_flat = ELEM_COST.get(backend + "_flat", elem_in)
    block_cap = GRID_BLOCK_CAP.get(backend, MAX_BLOCK)
    in_bytes = max(dtype_bits // 8, 1)
    hd_work = hd + hd_v

    # Overrides pin their axis verbatim (clamped to the sequence, the
    # historical ``min(block, seq)`` behavior); the other axis is still
    # searched.  ``pinned`` relaxes the VMEM rejection so an explicit
    # choice is always honored.
    if block_q is not None:
        q_cands = [max(min(block_q, sq), 1)]
    else:
        hi_q = min(block_cap, _ceil_div(sq, MIN_BLOCK) * MIN_BLOCK)
        q_cands = list(_pow2s(MIN_BLOCK, hi_q)) or [MIN_BLOCK]
    if block_k is not None:
        k_cands = [max(min(block_k, sk), 1)]
    else:
        hi_k = min(block_cap, _ceil_div(sk, MIN_BLOCK) * MIN_BLOCK)
        k_cands = list(_pow2s(MIN_BLOCK, hi_k)) or [MIN_BLOCK]
    pinned = block_q is not None or block_k is not None

    gf_cands = _divisors(g)

    # total operand footprints (bytes): what interpret's per-step block
    # gather/scatter walks — q/out/lse vs the backward passes' extras
    pb_fwd = batch * kh * (g * sq * (hd + hd_v + 1)
                           + sk * (hd + hd_v)) * in_bytes

    # ---- forward: minimize cost over (bq, bk, gf) under the budget ----
    best = None
    for bq in q_cands:
        for bk in k_cands:
            for gf in gf_cands:
                vm = _fwd_vmem(bq, bk, gf, hd, hd_v, in_bytes)
                if vm > budget and not (pinned and gf == 1):
                    continue
                c = _pass_cost(sq, sk, bq, bk, gf, g, kh, batch, hd_work,
                               causal, window, kv_len, diag_aligned,
                               step_cost, byte_cost, elem_in,
                               sbc, pb_fwd, in_bytes)
                key = (c, -bq * bk, -gf)
                if best is None or key < best[0]:
                    best = (key, bq, bk, gf, vm)
    _, bq, bk, gf, vm_fwd = best
    sq_p = _ceil_div(sq, bq) * bq
    sk_p = _ceil_div(sk, bk) * bk

    # ---- backward candidates must tile the fwd-padded sequence ----
    if pinned:
        bwd_q_cands = [bq]
        bwd_k_cands = [bk]
    else:
        bwd_q_cands = [b for b in _pow2s(MIN_BLOCK, min(block_cap, sq_p))
                       if sq_p % b == 0] or [bq]
        bwd_k_cands = [b for b in _pow2s(MIN_BLOCK, min(block_cap, sk_p))
                       if sk_p % b == 0] or [bk]

    # q/do/dq + lse/delta, k/v in; dk/dv whole-kv RMW counts twice
    pb_fused = batch * kh * (g * sq_p * (2 * hd + hd_v + 2)
                             + 3 * sk_p * (hd + hd_v)) * in_bytes
    pb_dq = batch * kh * (g * sq_p * (2 * hd + hd_v + 2)
                          + sk_p * (hd + hd_v)) * in_bytes
    pb_dkv = batch * kh * (g * sq_p * (hd + hd_v + 2)
                           + 2 * sk_p * (hd + hd_v)) * in_bytes

    # fused: one kernel, dk/dv resident for the whole padded kv length;
    # ~10 MAC-units per tile element instead of 6 (dq pass) + 8 (dkv)
    best_fused = None
    for fbq in bwd_q_cands:
        for fbk in bwd_k_cands:
            vm = _fused_vmem(fbq, fbk, g, sk_p, hd, hd_v, in_bytes)
            if vm > budget:
                continue
            c = _pass_cost(sq_p, sk_p, fbq, fbk, g, g, kh, batch,
                           int(hd_work * 2.5), causal, window, kv_len,
                           diag_aligned, step_cost, byte_cost,
                           2 * elem_in, sbc, pb_fused, in_bytes)
            key = (c, -fbq * fbk)
            if best_fused is None or key < best_fused[0]:
                best_fused = (key, fbq, fbk, vm)

    # two-call: dq pass (grid like fwd) + dkv pass (q-reduction block)
    best_dq = None
    for dbq in bwd_q_cands:
        for dbk in bwd_k_cands:
            for dgf in gf_cands:
                vm = _dq_vmem(dbq, dbk, dgf, hd, hd_v, in_bytes)
                if vm > budget and not (pinned and dgf == 1):
                    continue
                c = _pass_cost(sq_p, sk_p, dbq, dbk, dgf, g, kh, batch,
                               int(hd_work * 1.5), causal, window, kv_len,
                               diag_aligned, step_cost, byte_cost,
                               2 * elem_in, sbc, pb_dq, in_bytes)
                key = (c, -dbq * dbk, -dgf)
                if best_dq is None or key < best_dq[0]:
                    best_dq = (key, dbq, dbk, dgf, vm)
    best_dkv = None
    for dbq in bwd_q_cands:
        for dbk in bwd_k_cands:
            for dgf in gf_cands:
                vm = _dkv_vmem(dbq, dbk, dgf, hd, hd_v, in_bytes)
                if vm > budget and not (pinned and dgf == 1):
                    continue
                c = _pass_cost(sq_p, sk_p, dbq, dbk, dgf, g, kh, batch,
                               hd_work * 2, causal, window, kv_len,
                               diag_aligned, step_cost, byte_cost,
                               2 * elem_in, sbc, pb_dkv, in_bytes)
                key = (c, -dbq * dbk, -dgf)
                if best_dkv is None or key < best_dkv[0]:
                    best_dkv = (key, dbq, dbk, dgf, vm)

    two_call_cost = best_dq[0][0] + best_dkv[0][0]
    use_fused = best_fused is not None and best_fused[0][0] <= two_call_cost

    # ---- mega: grid (1,), the whole (B, KH) problem in one step, one
    # batched dot per matmul.  One flat XLA computation: elementwise
    # runs at flat speed (no in-loop penalty) but every masked element
    # is computed.  Gated on the materialized softmax-matrix transients
    # (host RAM in interpret mode, real VMEM on TPU).
    mega_fwd = mega_bwd = False
    mega_fwd_bt = mega_bwd_bt = False
    vm_mf = vm_mb = 0
    if not pinned:
        mega_budget = MEGA_BUDGET.get(backend) or budget
        full = batch * kh * g * sq_p * sk_p
        vm_mf = 2 * full * 4
        vm_mb = 4 * full * 4
        c_mf = full * (hd_work + elem_flat) + step_cost
        c_mb = full * (hd_work * 2.5 + 2 * elem_flat) + step_cost
        mega_fwd = vm_mf <= mega_budget and c_mf < best[0][0]
        bwd_cost = best_fused[0][0] if use_fused else two_call_cost
        mega_bwd = vm_mb <= mega_budget and c_mb < bwd_cost
        # batch-tiled fallback: when the full-batch transient is what
        # killed the mega (serving batch sizes), grid over B alone — the
        # per-step transient is 1/B of the full one and the flat
        # elementwise chain survives, at B·STEP_COST extra
        if batch > 1:
            c_mf_bt = full * (hd_work + elem_flat) + batch * step_cost
            c_mb_bt = full * (hd_work * 2.5 + 2 * elem_flat) \
                + batch * step_cost
            mega_fwd_bt = (not mega_fwd and vm_mf // batch <= mega_budget
                           and c_mf_bt < best[0][0])
            mega_bwd_bt = (not mega_bwd and vm_mb // batch <= mega_budget
                           and c_mb_bt < bwd_cost)

    if use_fused:
        _, fbq, fbk, vm_f = best_fused
        plan = AttnPlan(block_q=bq, block_k=bk, g_fold=gf, fused_bwd=True,
                        dq_block_q=fbq, dq_block_k=fbk,
                        dkv_block_q=fbq, dkv_block_k=fbk,
                        vmem_bytes=max(vm_fwd, vm_f,
                                       vm_mf if mega_fwd else 0,
                                       vm_mb if mega_bwd else 0,
                                       vm_mf // batch if mega_fwd_bt else 0,
                                       vm_mb // batch if mega_bwd_bt else 0),
                        mega_fwd=mega_fwd, mega_bwd=mega_bwd,
                        mega_fwd_bt=mega_fwd_bt, mega_bwd_bt=mega_bwd_bt)
    else:
        _, dqq, dqk, dqgf, vm_dq = best_dq
        _, dkq, dkk, dkgf, vm_dkv = best_dkv
        del dqgf, dkgf   # two-call passes re-derive their fold below
        plan = AttnPlan(block_q=bq, block_k=bk, g_fold=gf, fused_bwd=False,
                        dq_block_q=dqq, dq_block_k=dqk,
                        dkv_block_q=dkq, dkv_block_k=dkk,
                        vmem_bytes=max(vm_fwd, vm_dq, vm_dkv,
                                       vm_mf if mega_fwd else 0,
                                       vm_mb if mega_bwd else 0,
                                       vm_mf // batch if mega_fwd_bt else 0,
                                       vm_mb // batch if mega_bwd_bt else 0),
                        mega_fwd=mega_fwd, mega_bwd=mega_bwd,
                        mega_fwd_bt=mega_fwd_bt, mega_bwd_bt=mega_bwd_bt)
    return plan


@functools.lru_cache(maxsize=1024)
def plan_decode(seq: int, g: int, hd: int, hd_v: int, dtype_bits: int,
                backend: str = "interpret",
                vmem_budget: int | None = None,
                block_s: int | None = None) -> int:
    """Sequence block for the flash-decode kernel.  The cache length
    must divide the block, so candidates are pow2 divisors of ``seq``;
    cost is steps + streamed cache bytes under the VMEM budget."""
    if block_s is not None:
        return min(block_s, seq)
    budget = vmem_budget_bytes(backend) if vmem_budget is None else vmem_budget
    step_cost = STEP_COST.get(backend, STEP_COST["tpu"])
    in_bytes = max(dtype_bits // 8, 1)
    best = None
    for b in _pow2s(MIN_BLOCK, min(seq, MAX_BLOCK * 4)):
        if seq % b:
            continue
        vm = 2 * b * (hd + hd_v) * in_bytes + g * (hd_v + 2) * 4
        if vm > budget and best is not None:
            continue
        steps = seq // b
        c = steps * (step_cost + g * b * (hd + hd_v))
        if best is None or c < best[0]:
            best = (c, b)
    return best[1] if best else min(seq, 512)


@functools.lru_cache(maxsize=64)
def plan_copy_chunk(total_rows: int, vmem_budget: int | None = None) -> int:
    """Rows per DMA chunk for the HBM-staged ``multi_partition_copy``
    path: double-buffered source stage + RMW stage must fit the budget,
    and at least a few chunks should exist so the prefetch overlaps."""
    budget = vmem_budget_bytes() if vmem_budget is None else vmem_budget
    # 2 src slots + 1 rmw slot, each chunk×LANES bytes
    cap = max(budget // (3 * LANES), MIN_BLOCK)
    chunk = MIN_BLOCK
    while chunk * 2 <= cap and chunk * 2 <= 8192 and \
            chunk * 4 <= max(total_rows, MIN_BLOCK * 4):
        chunk *= 2
    return chunk
