"""Mesh sharding as paper-§6 data-block partitioning.

The distributed layer treats per-device shards of one logical array the way
the paper treats partitions of one data block: disjoint ranges of a single
buffer that multiple tasks (devices) want to access at once.  Everything
here is organized around that bridge:

* :class:`ShardCtx` wraps a ``jax.sharding.Mesh`` and translates *logical*
  axis names ("dp", "tp", "fsdp", "sp", "ep", "vocab", "kv_seq") into the
  physical mesh axes, dropping any axis whose size does not divide the
  dimension (a sharding that does not divide is not a valid §6 partition,
  so it silently degrades to replication rather than emitting one).
* :func:`_resolve_with_priority` maps a parameter's key path to a
  ``PartitionSpec`` via suffix rules — the most specific (longest) matching
  suffix wins, so ``("moe", "w_gate")`` (an expert bank, expert-parallel)
  beats the generic ``("w_gate",)`` dense rule.
* :func:`param_shardings` applies those rules to a whole params tree.
* :func:`use_mesh` / :func:`current_ctx` install an ambient context so
  model code can constrain intermediates without threading a ctx argument.
* :func:`partition_tree_of` lowers a ``NamedSharding`` to the disjoint
  ``(offset, size)`` byte ranges of §6 — the ranges a ``db_partition``
  call accepts (tests prove it by handing them to the core runtime).

Logical → physical axis mapping:

  ==========  =====================================================
  logical     physical
  ==========  =====================================================
  dp          ("pod", "data") — every axis in ``pure_dp`` mode
  fsdp        ("pod", "data") — disabled in ``pure_dp`` mode
  tp / model  ("model",)      — tensor / head parallel
  ep          ("model",)      — expert banks (MoE)
  sp          ("model",)      — sequence dim of activations
  kv_seq      ("model",)      — sequence dim of decode caches
  vocab       ("model",)      — vocab dim of logits
  ==========  =====================================================
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]


def shard_map(f, mesh, in_specs, out_specs, check: bool = False):
    """Version-portable ``shard_map`` (0.4.x experimental → 0.5+ jax.*).

    ``check`` maps onto ``check_vma`` (new) / ``check_rep`` (old).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as sm_exp
    return sm_exp(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check)


# --------------------------------------------------------------------- context

@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Ambient sharding context: a mesh plus the logical-axis dictionary."""

    mesh: Optional[Mesh] = None
    pure_dp: bool = False

    @property
    def active(self) -> bool:
        return self.mesh is not None and self.mesh.size > 1

    @property
    def axis_sizes(self) -> Dict[str, int]:
        if self.mesh is None:
            return {}
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))

    @property
    def model_size(self) -> int:
        return self.axis_sizes.get("model", 1) if self.active else 1

    # -- logical axes ------------------------------------------------------

    def _physical(self, logical: str) -> Tuple[str, ...]:
        """Mesh axes backing one logical name (existing axes only)."""
        sizes = self.axis_sizes
        if logical == "dp":
            if self.pure_dp:
                return tuple(self.mesh.axis_names)
            return tuple(a for a in ("pod", "data") if a in sizes)
        if logical == "fsdp":
            if self.pure_dp:
                return ()
            return tuple(a for a in ("pod", "data") if a in sizes)
        if logical in ("tp", "model", "ep", "sp", "kv_seq", "vocab"):
            if self.pure_dp:
                return ()
            return tuple(a for a in ("model",) if a in sizes)
        raise KeyError(f"unknown logical axis {logical!r}")

    def resolve(self, logical: Optional[str], dim: int) -> Axes:
        """Physical axes for ``logical`` on a dimension of size ``dim``.

        Returns a single axis name, a tuple of names, or None when the
        logical axis is unmapped or its total size does not divide ``dim``
        (an indivisible sharding is not a valid §6 partition).
        """
        if logical is None or not self.active:
            return None
        axes = self._physical(logical)
        if not axes:
            return None
        sizes = self.axis_sizes
        total = 1
        for a in axes:
            total *= sizes[a]
        if total <= 1 or dim % total != 0:
            # try a prefix that still divides (e.g. batch 4 on pod×data=8)
            for cut in range(len(axes) - 1, 0, -1):
                t = 1
                for a in axes[:cut]:
                    t *= sizes[a]
                if t > 1 and dim % t == 0:
                    axes = axes[:cut]
                    total = t
                    break
            else:
                return None
        return axes[0] if len(axes) == 1 else tuple(axes)

    def spec(self, shape: Sequence[int], *logical: Optional[str]) -> P:
        """PartitionSpec for ``shape`` with one logical name per dim."""
        assert len(logical) == len(shape), (tuple(shape), logical)
        return P(*(self.resolve(l, d) for l, d in zip(logical, shape)))

    def constrain(self, x: jax.Array, *logical: Optional[str]) -> jax.Array:
        """Sharding-constrain ``x`` (no-op without an active mesh)."""
        if not self.active:
            return x
        spec = self.spec(x.shape, *logical)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


_NULL_CTX = ShardCtx()
_CTX_STACK: List[ShardCtx] = []


def current_ctx() -> ShardCtx:
    """The innermost :func:`use_mesh` context (inactive ctx outside any)."""
    return _CTX_STACK[-1] if _CTX_STACK else _NULL_CTX


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], pure_dp: bool = False):
    """Install ``mesh`` as the ambient sharding context.

    ``mesh=None`` installs an *inactive* ctx (single-device semantics), so
    callers can pass an optional mesh through unconditionally.  In
    ``pure_dp`` mode the batch shards over every mesh axis and weights
    stay replicated (no TP/SP/FSDP) — the recipe small models prefer.
    """
    ctx = ShardCtx(mesh=mesh, pure_dp=pure_dp)
    _CTX_STACK.append(ctx)
    try:
        yield ctx
    finally:
        _CTX_STACK.pop()


# ------------------------------------------------------- param sharding rules

# (key-path suffix) -> logical axes for the *trailing* dims.  Leading stack
# dims (vmap-init layer stacking) are padded with None.  Ordered by
# specificity: the longest matching suffix wins (`_resolve_with_priority`).
_PARAM_RULES: Tuple[Tuple[Tuple[str, ...], Tuple[Optional[str], ...]], ...] = (
    # MoE expert banks: expert dim is the §6 partition axis (EP); the
    # d_model/d_ff dim re-gathers per layer (FSDP)
    (("moe", "w_gate"), ("ep", "fsdp", None)),
    (("moe", "w_up"), ("ep", "fsdp", None)),
    (("moe", "w_down"), ("ep", None, "fsdp")),
    (("moe", "router"), (None, None)),          # fp32, tiny: replicated
    # attention projections: heads over TP, d_model over FSDP
    (("w_q",), ("fsdp", "tp", None)),
    (("w_k",), ("fsdp", "tp", None)),
    (("w_v",), ("fsdp", "tp", None)),
    (("w_o",), ("tp", None, "fsdp")),
    (("b_q",), ("tp", None)),
    (("b_k",), ("tp", None)),
    (("b_v",), ("tp", None)),
    # MLA low-rank factors
    (("w_dq",), ("fsdp", None)),
    (("w_dkv",), ("fsdp", None)),
    (("w_uq",), (None, "tp", None)),
    (("w_uk",), (None, "tp", None)),
    (("w_uv",), (None, "tp", None)),
    # dense MLPs (SwiGLU + GELU): hidden over TP, d_model over FSDP
    (("w_gate",), ("fsdp", "tp")),
    (("w_up",), ("fsdp", "tp")),
    (("w_down",), ("tp", "fsdp")),
    (("w_in",), ("fsdp", "tp")),
    (("w_out",), ("tp", "fsdp")),
    (("b_in",), ("tp",)),
    # mamba projections: d_inner / heads are TP-aligned, B/C/dt head-shared
    (("w_z",), ("fsdp", "tp")),
    (("w_x",), ("fsdp", "tp")),
    (("out_proj",), ("tp", "fsdp")),
    (("conv_x",), (None, "tp")),
    (("conv_b_x",), ("tp",)),
    # embeddings / unembedding: vocab over TP (vocab-parallel CE loss)
    (("embedding",), ("tp", "fsdp")),
    (("lm_head",), ("fsdp", "tp")),
)


def _path_keys(path: Sequence[Any]) -> Tuple[str, ...]:
    return tuple(p.key if hasattr(p, "key") else str(p) for p in path)


def _resolve_with_priority(keys: Tuple[str, ...], shape: Tuple[int, ...],
                           ctx: ShardCtx) -> P:
    """PartitionSpec for one param leaf by key-path suffix priority.

    The longest rule suffix that matches the end of ``keys`` wins; its
    logical axes apply to the trailing ``len(rule)`` dims (leading stack
    dims replicate).  Unmatched leaves (norms, biases, scalars) replicate.
    Every resolved axis is divisibility-checked, so the emitted spec is
    always a valid §6 partitioning of the leaf.
    """
    best: Optional[Tuple[Optional[str], ...]] = None
    best_len = 0
    for suffix, logical in _PARAM_RULES:
        if len(suffix) > best_len and len(suffix) <= len(keys) \
                and keys[-len(suffix):] == suffix:
            best, best_len = logical, len(suffix)
    if best is None or len(best) > len(shape):
        return P(*([None] * len(shape)))
    pad = len(shape) - len(best)
    logical_full = (None,) * pad + best
    return ctx.spec(shape, *logical_full)


def param_shardings(shapes: Any, ctx: ShardCtx) -> Any:
    """NamedSharding tree for a params(-like) tree of ShapeDtypeStructs.

    Works for params, optimizer moments (same tree structure ⇒ same key
    paths ⇒ same shardings), and real arrays alike.
    """
    if ctx.mesh is None:
        raise ValueError("param_shardings requires a ShardCtx with a mesh")

    def leaf_sh(path, leaf):
        spec = _resolve_with_priority(_path_keys(path), tuple(leaf.shape), ctx)
        return NamedSharding(ctx.mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf_sh, shapes)


# ----------------------------------------------------- §6 partition lowering

def device_ranges_of(shape: Tuple[int, ...], itemsize: int,
                     sharding: NamedSharding
                     ) -> List[Tuple[Any, List[Tuple[int, int]]]]:
    """Per-device §6 byte ranges of one row-major buffer under a sharding.

    Each device's shard is a hyperrectangle of the row-major buffer; it
    lowers to one byte range per contiguous run (one run when only leading
    dims shard, many when an inner dim shards), emitted in the shard's own
    row-major order — so a shard's host bytes split into equal run-sized
    pieces correspond 1:1, in order, with that device's ranges.  Devices
    are visited in ``mesh.devices.flat`` order; replicated devices repeat
    ranges.  This is the §6 range map the sharded checkpoint writer uses
    to make each node write exactly its own bytes.
    """
    shape = tuple(int(d) for d in shape)
    if not shape:
        # scalar: a single range owned by the first device (all replicate)
        return [(sharding.mesh.devices.flat[0], [(0, itemsize)])]
    nelems = int(np.prod(shape))
    total = nelems * itemsize
    if nelems == 0:
        return []
    # row-major strides in bytes
    strides = [itemsize] * len(shape)
    for i in range(len(shape) - 2, -1, -1):
        strides[i] = strides[i + 1] * shape[i + 1]

    out: List[Tuple[Any, List[Tuple[int, int]]]] = []
    indices_map = sharding.devices_indices_map(shape)
    for dev in sharding.mesh.devices.flat:
        idx = indices_map[dev]
        starts = []
        lens = []
        for d, sl in enumerate(idx):
            start = 0 if sl.start is None else int(sl.start)
            stop = shape[d] if sl.stop is None else int(sl.stop)
            starts.append(start)
            lens.append(stop - start)
        # innermost contiguous run: trailing dims that are whole
        k = len(shape)
        while k > 0 and lens[k - 1] == shape[k - 1]:
            k -= 1
        if k == 0:
            out.append((dev, [(0, total)]))
            continue
        run = lens[k - 1] * strides[k - 1]   # bytes per contiguous run
        base = starts[k - 1] * strides[k - 1]
        # iterate the outer (non-run) dims
        outer = [range(s, s + l) for s, l in zip(starts[:k - 1],
                                                 lens[:k - 1])]
        ranges = []
        for combo in itertools.product(*outer):
            off = base + sum(c * strides[d] for d, c in enumerate(combo))
            ranges.append((off, run))
        out.append((dev, ranges))
    return out


def partition_tree_of(shape: Tuple[int, ...], itemsize: int,
                      sharding: NamedSharding) -> List[Tuple[int, int]]:
    """Lower a sharding to the §6 ``(offset, size)`` byte ranges per device.

    Flat view of :func:`device_ranges_of`: ranges in device order,
    replicated devices repeating theirs — deduplicated, the distinct
    ranges are mutually disjoint and tile the buffer exactly, which is
    precisely what ``db_partition`` (§6.2) accepts.  Lane alignment: a
    run's byte size is a multiple of the trailing-dims byte count, so
    whenever the innermost *sharded* dim leaves ≥ 32 f32 (128 B) of
    trailing extent, every range is lane-aligned for the fused-copy
    kernel (``partition_copy_bytes``).
    """
    return [r for _dev, ranges in device_ranges_of(shape, itemsize, sharding)
            for r in ranges]


def moe_bucket_ranges(num_experts: int, capacity: int, width: int,
                      itemsize: int, ctx: ShardCtx) -> List[Tuple[int, int]]:
    """§6 destination ranges of one shard's ``(E, C, width)`` a2a bucket.

    The capacity-bucketed MoE dispatch packs each source shard's tokens
    into per-destination-expert buckets; the ``all_to_all`` then hands
    destination shard *j* exactly the contiguous range covering its
    experts ``[j·E/m, (j+1)·E/m)`` — the same NamedSharding →
    disjoint-``(offset, size)`` lowering the expert banks use, so the
    exchanged buckets are literally a §6 partitioning of the bucket block
    (tests hand these ranges to ``db_partition``).  Distinct ranges only
    (replicated mesh axes deduplicated), in offset order; without an
    active expert-parallel axis the whole block is one local range.
    """
    shape = (num_experts, capacity, width)
    total = num_experts * capacity * width * itemsize
    ep = ctx.resolve("ep", num_experts) if ctx.mesh is not None else None
    if ep is None:
        return [(0, total)]
    sharding = NamedSharding(ctx.mesh, P(ep, None, None))
    seen = set()
    out: List[Tuple[int, int]] = []
    for r in partition_tree_of(shape, itemsize, sharding):
        if r not in seen:
            seen.add(r)
            out.append(r)
    return sorted(out)
