"""The §6 sharding bridge: mesh shardings that are provably valid paper
partitions (see ``repro.dist.sharding``) plus mesh-strategy attention
dispatch (``repro.dist.flash``)."""
from .sharding import (ShardCtx, current_ctx, param_shardings,
                       partition_tree_of, use_mesh)

__all__ = ["ShardCtx", "current_ctx", "param_shardings",
           "partition_tree_of", "use_mesh"]
