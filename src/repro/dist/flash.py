"""Mesh-strategy dispatch for attention compute.

One place decides how attention parallelizes, so the model blocks never
mention the mesh:

* **head-parallel** — when the (kv-)head count divides the "model" axis,
  heads shard and every device runs the plain local kernel on its heads;
  no collective at all (attention is independent per head).
* **context/sequence-parallel** — otherwise, when the sequence divides the
  "model" axis: q shards over sequence, k/v stay whole, and each device
  computes its q stripe against the full context (``q_offset`` keeps the
  causal mask globally correct).  Used for training/prefill.
* **lse-combine flash decode** — one-token decode against a cache whose
  *sequence* dim shards over "model": every device computes a partial
  softmax over its §6 stripe of the cache and the partials combine with a
  global max + psum (the log-sum-exp trick), two scalarish collectives.
* **single device** — no mesh (or ``pure_dp``): the existing kernels.
  Long-sequence training/prefill runs the differentiable Pallas flash
  kernel (custom-VJP backward kernels; compiled on TPU, interpret mode
  on CPU); the decode hot path routes to the Pallas flash-decode kernel
  on a TPU backend.

The §6 reading: a decode cache is one data block; the sequence stripes the
lse-combine path walks are exactly the disjoint EW partitions
``partition_tree_of`` emits for the cache's ``kv_seq`` sharding.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kernel_ops
from repro.models.attention import (decode_attention, flash_min_seq,
                                    full_attention)
from .sharding import current_ctx, shard_map

NEG_INF = -1e30


def _blocks(cfg) -> Tuple[Optional[int], Optional[int], int]:
    """Config tile overrides (None = let the trace-time autotuner pick)
    and the flash threshold.  ``flash_min_seq`` derives its floor from
    ``autotune.min_block()`` when no override pins a tile, so the
    threshold and the planner can never disagree about the smallest
    sequence worth tiling — fwd and bwd alike."""
    return (getattr(cfg, "attn_block_q", None),
            getattr(cfg, "attn_block_k", None),
            flash_min_seq(cfg))


def _attn_local(q: jax.Array, k: jax.Array, v: jax.Array, *, window: int,
                block_q: Optional[int], block_k: Optional[int],
                min_seq: int = 2048, q_offset=0) -> jax.Array:
    """Single-shard causal attention: the differentiable Pallas flash
    kernel for long sequences (O(S) memory, custom-VJP backward kernels —
    training and inference take the same path), dense reference for short
    ones.  Ragged sequence lengths are edge-padded inside the kernel, so
    the flash branch is purely length-thresholded."""
    sq = q.shape[1]
    if sq > min_seq:
        return kernel_ops.flash_attention(
            q, k, v, jnp.asarray(q_offset).astype(jnp.float32),
            causal=True, window=window, block_q=block_q, block_k=block_k)
    return full_attention(q, k, v, causal=True, window=window,
                          q_offset=q_offset)


def causal_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, cfg=None,
                     window: int = 0) -> jax.Array:
    """Causal (optionally sliding-window) attention, mesh-dispatched.

    q: (B, S, H, hd); k, v: (B, S, KH, hd) → (B, S, H, hd_v).
    """
    ctx = current_ctx()
    b, s, h, _ = q.shape
    kh = k.shape[2]
    m = ctx.model_size
    bq, bk, min_seq = _blocks(cfg)

    if not ctx.active or ctx.pure_dp or m <= 1:
        return _attn_local(q, k, v, window=window, block_q=bq, block_k=bk,
                           min_seq=min_seq)

    dp = ctx.resolve("dp", b)
    if h % m == 0 and kh % m == 0:
        # head-parallel: no collective, local kernel per head shard
        spec = P(dp, None, "model", None)

        def inner(ql, kl, vl):
            return _attn_local(ql, kl, vl, window=window,
                               block_q=bq, block_k=bk, min_seq=min_seq)

        return shard_map(inner, ctx.mesh, in_specs=(spec, spec, spec),
                         out_specs=spec)(q, k, v)

    if s % m == 0:
        # context-parallel: q stripes over "model", k/v whole; q_offset
        # keeps each stripe's causal mask globally positioned — through
        # the Pallas kernel's scalar-prefetched offset in fwd AND bwd
        chunk = s // m
        qspec = P(dp, "model", None, None)
        kvspec = P(dp, None, None, None)

        def inner(ql, kl, vl):
            off = jax.lax.axis_index("model") * chunk
            return _attn_local(ql, kl, vl, window=window, block_q=bq,
                               block_k=bk, min_seq=min_seq, q_offset=off)

        return shard_map(inner, ctx.mesh, in_specs=(qspec, kvspec, kvspec),
                         out_specs=qspec)(q, k, v)

    return _attn_local(q, k, v, window=window, block_q=bq, block_k=bk,
                       min_seq=min_seq)


# ------------------------------------------------------------------- decode

def _decode_local(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                  valid: jax.Array, window: int) -> jax.Array:
    """One-token attention against head-major caches on one shard.

    q: (B, 1, H, hd); caches: (B, KH, S, hd); valid: scalar int32 count of
    valid cache entries.  Routes to the Pallas flash-decode kernel on a
    TPU backend (decode is never differentiated), jnp oracle elsewhere.
    """
    b, _, h, hd = q.shape
    kh = k_cache.shape[1]
    g = h // kh
    smax = k_cache.shape[2]
    if jax.default_backend() == "tpu" and smax % 128 == 0:
        from repro.kernels.flash_decode import flash_decode
        qg = q[:, 0].reshape(b, kh, g, hd)
        out = flash_decode(qg, k_cache, v_cache, valid, window=window)
        return out.reshape(b, 1, h, v_cache.shape[-1])
    kt = jnp.transpose(k_cache, (0, 2, 1, 3))
    vt = jnp.transpose(v_cache, (0, 2, 1, 3))
    return decode_attention(q, kt, vt, cur_len=valid, window=window)


def decode_update_and_attend(q: jax.Array, k_new: jax.Array,
                             v_new: jax.Array, k_cache: jax.Array,
                             v_cache: jax.Array, cur_len, *, cfg=None,
                             window: int = 0):
    """Insert the new token at ``cur_len`` and attend over ``cur_len + 1``.

    q, k_new, v_new: (B, 1, H|KH, hd); caches head-major (B, KH, S, hd);
    cur_len: scalar int32 tokens already cached.  Returns
    (out (B, 1, H, hd_v), k_cache', v_cache').
    """
    ctx = current_ctx()
    b, _, h, hd = q.shape
    kh, smax = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    m = ctx.model_size
    cur = jnp.asarray(cur_len, jnp.int32)

    kn = jnp.transpose(k_new, (0, 2, 1, 3)).astype(k_cache.dtype)
    vn = jnp.transpose(v_new, (0, 2, 1, 3)).astype(v_cache.dtype)
    k_cache = jax.lax.dynamic_update_slice(k_cache, kn, (0, 0, cur, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, vn, (0, 0, cur, 0))

    if not ctx.active or ctx.pure_dp or m <= 1:
        out = _decode_local(q, k_cache, v_cache, cur + 1, window)
        return out, k_cache, v_cache

    dp = ctx.resolve("dp", b)
    if h % m == 0 and kh % m == 0:
        qspec = P(dp, None, "model", None)
        cspec = P(dp, "model", None, None)

        def inner(c, ql, kcl, vcl):
            return _decode_local(ql, kcl, vcl, c + 1, window)

        out = shard_map(inner, ctx.mesh,
                        in_specs=(P(), qspec, cspec, cspec),
                        out_specs=qspec)(cur, q, k_cache, v_cache)
        return out, k_cache, v_cache

    if smax % m == 0:
        # lse-combine: each device scans its §6 stripe of the cache,
        # partial softmaxes merge through a global max + psum
        chunk = smax // m
        scale = 1.0 / np.sqrt(hd)
        qspec = P(dp, None, None, None)
        cspec = P(dp, None, "model", None)

        def inner(c, ql, kcl, vcl):
            bl = ql.shape[0]
            r = jax.lax.axis_index("model")
            pos = r * chunk + jnp.arange(chunk)
            qg = ql[:, 0].reshape(bl, kh, g, hd).astype(jnp.float32)
            s = jnp.einsum("bkgh,bksh->bkgs", qg,
                           kcl.astype(jnp.float32)) * scale
            valid = pos < c + 1
            if window > 0:
                valid &= pos >= jnp.maximum(c + 1 - window, 0)
            s = jnp.where(valid[None, None, None, :], s, NEG_INF)
            m_loc = jnp.max(s, axis=-1)
            m_all = jax.lax.pmax(m_loc, "model")
            p = jnp.exp(s - m_all[..., None])
            p = jnp.where(valid[None, None, None, :], p, 0.0)
            num = jnp.einsum("bkgs,bksh->bkgh", p,
                             vcl.astype(jnp.float32))
            num = jax.lax.psum(num, "model")
            den = jax.lax.psum(jnp.sum(p, axis=-1), "model")
            out = num / jnp.maximum(den, 1e-37)[..., None]
            return out.reshape(bl, 1, h, -1).astype(ql.dtype)

        out = shard_map(inner, ctx.mesh,
                        in_specs=(P(), qspec, cspec, cspec),
                        out_specs=qspec)(cur, q, k_cache, v_cache)
        return out, k_cache, v_cache

    out = _decode_local(q, k_cache, v_cache, cur + 1, window)
    return out, k_cache, v_cache


# ------------------------------------------------------------- paged decode

def paged_update_and_attend(q: jax.Array, k_new: jax.Array, v_new: jax.Array,
                            k_pages: jax.Array, v_pages: jax.Array,
                            page_table: jax.Array, cur_lens: jax.Array,
                            active: jax.Array, *, window: int = 0):
    """Per-request paged decode over §6 pages of a shared cache pool.

    q, k_new, v_new: (B, 1, H|KH, hd); pools (P, KH, page, hd) — every
    request's KV lives in fixed-size pages of one pool, indexed through
    ``page_table`` (B, max_pages) int32 (entries past a row's page count
    are ignored).  ``cur_lens`` (B,) int32 tokens already cached per row;
    ``active`` (B,) bool — inactive rows write nothing and output zeros.

    The attention is the lse-combine math of the cache-stripe decode path
    applied per page: each page contributes a partial max/sum, merged
    through a global max — numerically identical to one masked softmax
    over the row's gathered pages.  Returns (out (B,1,H,hd_v), k_pages',
    v_pages').
    """
    b, _, h, hd = q.shape
    npages, kh, page, _ = k_pages.shape
    g = h // kh
    max_pages = page_table.shape[1]
    scale = 1.0 / np.sqrt(hd)
    cur = jnp.asarray(cur_lens, jnp.int32)
    rows = jnp.arange(b)

    # scatter the new token: row i writes page_table[i, cur//page] slot
    # cur%page; inactive rows aim past the pool and drop
    phys = page_table[rows, cur // page]
    phys = jnp.where(active, phys, npages)
    slot = cur % page
    kn = k_new[:, 0].astype(k_pages.dtype)          # (B, KH, hd)
    vn = v_new[:, 0].astype(v_pages.dtype)
    k_pages = k_pages.at[phys, :, slot].set(kn, mode="drop")
    v_pages = v_pages.at[phys, :, slot].set(vn, mode="drop")

    # gather each row's page list and lse-combine across pages
    kg = k_pages[page_table].astype(jnp.float32)    # (B, mp, KH, page, hd)
    vg = v_pages[page_table].astype(jnp.float32)
    qg = q[:, 0].reshape(b, kh, g, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bpksh->bkgps", qg, kg) * scale
    pos = (jnp.arange(max_pages)[:, None] * page
           + jnp.arange(page)[None, :])             # (mp, page)
    valid = pos[None] < (cur + 1)[:, None, None]
    if window > 0:
        valid &= pos[None] >= jnp.maximum(cur + 1 - window, 0)[:, None, None]
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    m_loc = jnp.max(s, axis=-1)                     # (B, KH, g, mp)
    m_all = jnp.max(m_loc, axis=-1)                 # (B, KH, g)
    p = jnp.exp(s - m_all[..., None, None])
    p = jnp.where(valid[:, None, None], p, 0.0)
    num = jnp.einsum("bkgps,bpksh->bkgh", p, vg)
    den = jnp.sum(p, axis=(-2, -1))
    out = num / jnp.maximum(den, 1e-37)[..., None]
    out = out * active[:, None, None, None]
    return (out.reshape(b, 1, h, -1).astype(q.dtype), k_pages, v_pages)


# ---------------------------------------------------------------- MLA decode

def mla_decode_attend(q_latent: jax.Array, q_rope: jax.Array,
                      c_new: jax.Array, kr_new: jax.Array,
                      c_kv: jax.Array, k_rope: jax.Array, cur_len, *,
                      scale: float):
    """Absorbed-matrix MLA decode in the compressed latent space.

    q_latent: (B, 1, H, rkv); q_rope: (B, 1, H, dr); new latents
    c_new (B, 1, rkv) / kr_new (B, 1, dr); caches c_kv (B, S, rkv) /
    k_rope (B, S, dr).  Returns (out_latent (B, 1, H, rkv), c_kv',
    k_rope').  Heads shard over "model" when they divide it (the caches
    are head-shared latents, so head-parallel needs no collective);
    otherwise the compute is latent-rank-bound and runs replicated.
    """
    ctx = current_ctx()
    b, _, h, _ = q_latent.shape
    m = ctx.model_size
    cur = jnp.asarray(cur_len, jnp.int32)

    c_kv = jax.lax.dynamic_update_slice(
        c_kv, c_new.astype(c_kv.dtype), (0, cur, 0))
    k_rope = jax.lax.dynamic_update_slice(
        k_rope, kr_new.astype(k_rope.dtype), (0, cur, 0))

    def attend(ql, qr, ckv, kr, c):
        smax = ckv.shape[1]
        s = (jnp.einsum("bshr,btr->bhst", ql, ckv)
             + jnp.einsum("bshk,btk->bhst", qr, kr)).astype(jnp.float32)
        s = s * scale
        valid = jnp.arange(smax) < c + 1
        s = jnp.where(valid[None, None, None, :], s, NEG_INF)
        probs = jax.nn.softmax(s, axis=-1).astype(ql.dtype)
        return jnp.einsum("bhst,btr->bshr", probs, ckv)

    if ctx.active and not ctx.pure_dp and m > 1 and h % m == 0:
        dp = ctx.resolve("dp", b)
        qspec = P(dp, None, "model", None)
        cspec = P(dp, None, None)

        def inner(ql, qr, ckv, kr, c):
            return attend(ql, qr, ckv, kr, c)

        out = shard_map(inner, ctx.mesh,
                        in_specs=(qspec, qspec, cspec, cspec, P()),
                        out_specs=qspec)(q_latent, q_rope, c_kv, k_rope, cur)
        return out, c_kv, k_rope

    out = attend(q_latent, q_rope, c_kv, k_rope, cur)
    return out, c_kv, k_rope
