"""Fault-tolerant trainer orchestrated through the OCR core runtime.

The step sequence is built with the paper's §4 labeled-GUID map: a map of
step tasks indexed by step number whose creator wires step *i* to depend on
step *i−1*'s output event — the 1-D degenerate case of the paper's 2-D
wavefront.  Checkpoint tasks hang off every k-th step event and write
through the §5 chunked file layer (async, off the step critical path, §3
issue-now/resolve-later).

Fault tolerance: ``run`` stops cleanly at a simulated failure step; a new
``Trainer`` with the same config resumes from the last *committed* manifest
and — because the data pipeline is stateless-per-step — replays exactly the
batches the lost steps would have seen (tested bit-exact in
``tests/test_trainer.py``).  A step-time watchdog flags stragglers.

Attention in the jitted step routes through ``repro.dist.flash``: above
``cfg.attn_flash_min_seq`` the differentiable Pallas flash kernel runs the
forward *and* both backward passes (compiled on TPU, interpret mode on
CPU), under ``use_mesh`` included — training no longer falls back to the
jnp flash twin.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import numpy as np

from repro import ckpt
from repro.core import (DbMode, EDT_PROP_MAPPED, NULL_GUID,
                        Runtime, UNINITIALIZED_GUID, spawn_main)
from repro.dist.sharding import use_mesh
from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig
from .steps import init_train_state, make_train_step


@dataclasses.dataclass
class TrainerConfig:
    ckpt_dir: str = ""
    ckpt_every: int = 0              # 0 → no checkpoints
    async_ckpt: bool = True
    fail_at_step: int = -1           # inject a failure (tests)
    straggler_factor: float = 3.0    # watchdog threshold × median step time
    log_every: int = 10


class Trainer:
    def __init__(self, model: LanguageModel, oc: OptimizerConfig,
                 data, tc: TrainerConfig, mesh=None):
        self.model = model
        self.oc = oc
        self.data = data
        self.tc = tc
        self.mesh = mesh
        self._step_fn = None
        self.history: List[Dict[str, float]] = []
        self.straggler_steps: List[int] = []
        self._ckpt_threads: List[Any] = []

    # ------------------------------------------------------------ lifecycle

    def _build(self):
        if self._step_fn is None:
            step = make_train_step(self.model, self.oc)
            self._step_fn = jax.jit(step, donate_argnums=(0,))
        return self._step_fn

    def init_or_restore(self, key) -> Dict[str, Any]:
        tc = self.tc
        if tc.ckpt_dir and ckpt.latest_step(tc.ckpt_dir) is not None:
            tree, step = ckpt.restore(tc.ckpt_dir)
            if self.mesh is not None:
                # reshard-on-restore: the §6 range manifest reassembles
                # full leaves whatever mesh wrote them; place them onto
                # *this* run's mesh via the suffix param rules
                from repro.dist.sharding import ShardCtx, param_shardings
                shapes = jax.tree_util.tree_map(
                    lambda a: jax.ShapeDtypeStruct(np.asarray(a).shape,
                                                   np.asarray(a).dtype), tree)
                shardings = param_shardings(shapes, ShardCtx(mesh=self.mesh))
                state = jax.tree_util.tree_map(jax.device_put, tree, shardings)
            else:
                state = jax.tree_util.tree_map(jax.numpy.asarray, tree)
            self.start_step = step
            return state
        self.start_step = 0
        return init_train_state(self.model, key, self.oc)

    # ----------------------------------------------------------------- run

    def run(self, state: Dict[str, Any], num_steps: int,
            start_step: Optional[int] = None) -> Dict[str, Any]:
        start = self.start_step if start_step is None else start_step
        step_fn = self._build()
        tc = self.tc
        holder = {"state": state}
        durations: List[float] = []

        rt = Runtime(num_nodes=2)
        smap_holder: Dict[str, Any] = {}

        def step_body(paramv, depv, api):
            idx = paramv[0]
            i = start + idx
            if tc.fail_at_step >= 0 and i == tc.fail_at_step:
                api.rt.kill_node(0)      # fail-stop: nothing after this runs
                return NULL_GUID
            t0 = time.perf_counter()
            batch = self.data.get(i)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            with use_mesh(self.mesh):
                holder["state"], metrics = step_fn(holder["state"], batch)
            dt = time.perf_counter() - t0
            durations.append(dt)
            med = float(np.median(durations))
            if len(durations) > 5 and dt > tc.straggler_factor * med:
                self.straggler_steps.append(i)
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["step_time"] = dt
            self.history.append(m)
            # stamp step metrics into the monitoring registry: the train.*
            # namespace is live alongside the runtime's io.*/spill.* gauges,
            # which is what an elastic supervisor would watch mid-run
            reg = rt.registry
            reg.set("train.step", float(i))
            reg.set("train.loss", m.get("loss", 0.0))
            reg.set("train.step_time_s", dt)
            reg.inc("train.steps")
            if rt._mon is not None:
                reg.histogram("train.step_wall_s").observe(dt)
            if tc.ckpt_every and tc.ckpt_dir and (i + 1) % tc.ckpt_every == 0:
                # checkpoint hangs off this step's event; §5 chunked write,
                # §3 issue-now/resolve-later.  async_ckpt snapshots at
                # issue time and overlaps inside the runtime's IO queue
                # (virtual time); the call itself completes before the
                # next step runs.  Under a mesh the NamedShardings ride
                # along and
                # ckpt.save takes the §6 sharded path: each node writes
                # exactly its own byte ranges, no host-side gather.
                if self.mesh is not None:
                    with use_mesh(self.mesh):
                        if tc.async_ckpt:
                            self._ckpt_threads.append(ckpt.async_save(
                                tc.ckpt_dir, holder["state"], i + 1))
                        else:
                            ckpt.save(tc.ckpt_dir, holder["state"], i + 1)
                else:
                    host = jax.tree_util.tree_map(np.asarray, holder["state"])
                    if tc.async_ckpt:
                        self._ckpt_threads.append(
                            ckpt.async_save(tc.ckpt_dir, host, i + 1))
                    else:
                        ckpt.save(tc.ckpt_dir, host, i + 1)
            # the paper's wavefront pattern: this task satisfies the next
            # step task's pre-slot via the §4 labeled map
            if idx + 1 < num_steps:
                nxt = api.map_get(smap_holder["map"], idx + 1)
                api.add_dependence(NULL_GUID, nxt, 0, DbMode.NULL)
            return NULL_GUID

        def creator(ctx_api, object_lid, index, paramv, guidv):
            deps = [NULL_GUID] if index == 0 else [UNINITIALIZED_GUID]
            ctx_api.edt_create(guidv[0], paramv=[index], depv=deps,
                               props=EDT_PROP_MAPPED, mapped_id=object_lid)

        def main(paramv, depv, api):
            tmpl = api.edt_template_create(step_body, 1, 1)
            smap = api.map_create(num_steps, creator, guidv=[tmpl])
            smap_holder["map"] = smap
            api.map_get(smap, 0)     # seed the chain
            return NULL_GUID

        spawn_main(rt, main)
        rt.run()
        for t in self._ckpt_threads:
            t.join()
        if self.history:
            last = self.history[-1]
            rt.stats.moe_dropped_tokens = int(
                last.get("moe_dropped_tokens", 0))
            rt.stats.moe_overflow_rate = float(
                last.get("moe_overflow_rate", 0.0))
            rt.stats.moe_a2a_bytes = int(last.get("moe_a2a_bytes", 0))
        self.last_runtime_stats = rt.stats
        self.registry = rt.registry
        return holder["state"]
