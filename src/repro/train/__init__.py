from .steps import TrainState, make_train_step, init_train_state
