"""Training step: loss + grad + AdamW update, with gradient accumulation.

``TrainState`` is a plain dict pytree (checkpoint-friendly):
  {"params": ..., "opt": {"m","v","step"}}

The step function is pure and jit/pjit-able; donation of the state buffers
(zero-copy in-place semantics — the paper's §6.3 ``DB_COPY_PARTITION``
degenerate case) is applied by the caller via ``donate_argnums=(0,)``.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig, adamw_update, init_opt_state

TrainState = Dict[str, Any]


def init_train_state(model: LanguageModel, key, oc: OptimizerConfig
                     ) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": init_opt_state(params, oc)}


def make_train_step(model: LanguageModel, oc: OptimizerConfig):
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        return model.train_loss(params, batch)

    def grads_of(params, batch):
        if oc.accum_steps <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return grads, metrics

        a = oc.accum_steps

        acc_dt = jnp.bfloat16 if oc.accum_dtype == "bfloat16" else jnp.float32

        def micro(carry, mb):
            g_acc, m_acc = carry
            (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            g_acc = jax.tree_util.tree_map(
                lambda a_, g_: a_ + g_.astype(a_.dtype), g_acc, g)
            m_acc = jax.tree_util.tree_map(jnp.add, m_acc, m)
            return (g_acc, m_acc), None

        micro_batch = jax.tree_util.tree_map(
            lambda x: x.reshape(a, x.shape[0] // a, *x.shape[1:]), batch)
        g0 = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, acc_dt), params)
        m0 = {k: jnp.zeros((), jnp.float32)
              for k in ("loss", "ce_loss", "z_loss", "accuracy", "tokens",
                        "aux_loss", "moe_dropped_tokens",
                        "moe_overflow_rate", "moe_a2a_bytes")}
        (g, m), _ = jax.lax.scan(micro, (g0, m0), micro_batch)
        g = jax.tree_util.tree_map(lambda x: x / a, g)
        summed = ("tokens", "moe_dropped_tokens", "moe_a2a_bytes")
        m = {k: v / a if k not in summed else v for k, v in m.items()}
        return g, m

    def train_step(state: TrainState, batch: Dict[str, jax.Array]
                   ) -> Tuple[TrainState, Dict[str, jax.Array]]:
        grads, metrics = grads_of(state["params"], batch)
        params, opt, opt_metrics = adamw_update(
            oc, grads, state["params"], state["opt"])
        metrics = {**metrics, **opt_metrics}
        return {"params": params, "opt": opt}, metrics

    return train_step
