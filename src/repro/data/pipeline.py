"""Deterministic token pipelines.

``SyntheticTokens`` — stateless per-step PRNG batches (any step is
reconstructable, which the fault-tolerance tests rely on: a restarted run
re-reads exactly the batches it would have seen).

``FileTokens`` — the paper's §5 file IO as a data source: every batch maps
a *disjoint chunk* of the token file into a data block via
``ocrFileGetChunk`` (read-only acquire ⇒ no write-back), going through the
core runtime rather than raw ``fopen`` — no side effects outside the
runtime, per the paper's resilience argument.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.core import DbMode, NULL_GUID, Runtime, spawn_main


def make_batch(tokens: np.ndarray) -> Dict[str, np.ndarray]:
    """tokens (B, S+1) -> {"tokens": (B,S), "targets": (B,S)}."""
    return {"tokens": tokens[:, :-1].astype(np.int32),
            "targets": tokens[:, 1:].astype(np.int32)}


@dataclasses.dataclass
class SyntheticTokens:
    vocab_size: int
    batch: int
    seq: int
    seed: int = 0
    mode: str = "uniform"            # uniform | markov (learnable bigrams)

    def get(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.PCG64(
            (self.seed << 32) ^ (step + 1)))
        if self.mode == "uniform":
            toks = rng.integers(0, self.vocab_size,
                                size=(self.batch, self.seq + 1),
                                dtype=np.int64)
        else:
            # deterministic affine bigram chain + 10% noise: learnable
            v = self.vocab_size
            toks = np.empty((self.batch, self.seq + 1), dtype=np.int64)
            toks[:, 0] = rng.integers(0, v, size=self.batch)
            noise = rng.random((self.batch, self.seq)) < 0.1
            rand = rng.integers(0, v, size=(self.batch, self.seq))
            for i in range(self.seq):
                nxt = (toks[:, i] * 31 + 7) % v
                toks[:, i + 1] = np.where(noise[:, i], rand[:, i], nxt)
        return make_batch(toks)


class FileTokens:
    """Token file (int32 little-endian) read through §5 file-mapped chunks."""

    def __init__(self, path: str, vocab_size: int, batch: int, seq: int,
                 num_nodes: int = 2):
        self.path = path
        self.vocab_size = vocab_size
        self.batch = batch
        self.seq = seq
        self.num_nodes = num_nodes
        self._bytes_per_batch = batch * (seq + 1) * 4
        self.total_tokens: Optional[int] = None

    def num_batches(self) -> int:
        import os
        return os.path.getsize(self.path) // self._bytes_per_batch

    def get(self, step: int) -> Dict[str, np.ndarray]:
        """Read batch ``step`` (mod file size) via a read-only chunk."""
        n = max(self.num_batches(), 1)
        offset = (step % n) * self._bytes_per_batch
        out: Dict[str, np.ndarray] = {}
        rt = Runtime(num_nodes=self.num_nodes)

        grabbed = {}

        def reader(paramv, depv, api):
            data = depv[0].ptr
            toks = np.frombuffer(bytes(data), dtype=np.int32).reshape(
                self.batch, self.seq + 1)
            grabbed["tokens"] = toks.copy()
            api.db_destroy(depv[0].guid)
            return NULL_GUID

        def main(paramv, depv, api):
            fg, desc = api.file_open(self.path, "rb")

            def after_open(pv, dv, api2):
                f = api2.file_get_guid(dv[0].ptr)
                chunk = api2.file_get_chunk(f, offset, self._bytes_per_batch)
                api2.file_release(f)
                api2.db_destroy(dv[0].guid)
                tmpl2 = api2.edt_template_create(reader, 0, 1)
                api2.edt_create(tmpl2, depv=[chunk], dep_modes=[DbMode.RO])
                return NULL_GUID

            tmpl = api.edt_template_create(after_open, 0, 1)
            api.edt_create(tmpl, depv=[desc])
            return NULL_GUID

        spawn_main(rt, main)
        rt.run()
        toks = grabbed["tokens"] % self.vocab_size
        return make_batch(toks)


def write_token_file(path: str, tokens: np.ndarray) -> None:
    tokens.astype(np.int32).tofile(path)
