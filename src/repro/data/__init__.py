from .pipeline import SyntheticTokens, FileTokens, make_batch
