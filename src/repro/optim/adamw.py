"""AdamW with optional int8-quantized moment states.

For ≥100B-parameter MoE configs (arctic-480b, deepseek-v2-236b) the fp32
Adam moments don't fit 16 GB/chip HBM alongside fp32 master weights, so
``state_dtype="int8"`` stores both moments in 8 bits with per-row scales:

* ``m`` — signed linear quantization (row max-abs / 127);
* ``v`` — non-negative, huge dynamic range → quartic-root companding:
  ``q = round(255 · (v / vmax)^(1/4))`` so small entries keep relative
  resolution (linear quant would zero them and blow up the update).

This is a distributed-optimization memory trick in the spirit of 8-bit
Adam; tests assert a small model still descends with int8 states.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    state_dtype: str = "float32"      # float32 | int8
    accum_steps: int = 1
    accum_dtype: str = "float32"      # bfloat16 halves the grad accumulator


def lr_at(oc: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = oc.peak_lr * step / max(oc.warmup_steps, 1)
    prog = jnp.clip((step - oc.warmup_steps)
                    / max(oc.total_steps - oc.warmup_steps, 1), 0.0, 1.0)
    cos = oc.min_lr_frac + (1 - oc.min_lr_frac) * 0.5 * (1 + jnp.cos(np.pi * prog))
    return jnp.where(step < oc.warmup_steps, warm, oc.peak_lr * cos)


# ----------------------------------------------------------- int8 compansion

def _quant_m(m: jax.Array) -> Dict[str, jax.Array]:
    scale = jnp.max(jnp.abs(m), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-20)
    q = jnp.clip(jnp.round(m / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "scale": scale.astype(jnp.float32)}


def _dequant_m(s: Dict[str, jax.Array]) -> jax.Array:
    return s["q"].astype(jnp.float32) * s["scale"]


def _quant_v(v: jax.Array) -> Dict[str, jax.Array]:
    vmax = jnp.max(v, axis=-1, keepdims=True)
    vmax = jnp.maximum(vmax, 1e-30)
    q = jnp.round(255.0 * jnp.sqrt(jnp.sqrt(v / vmax)))
    return {"q": jnp.clip(q, 0, 255).astype(jnp.uint8),
            "scale": vmax.astype(jnp.float32)}


def _dequant_v(s: Dict[str, jax.Array]) -> jax.Array:
    r = s["q"].astype(jnp.float32) / 255.0
    return jnp.square(jnp.square(r)) * s["scale"]


def _zeros_like_state(p: jax.Array, quant: bool, signed: bool):
    if not quant:
        return jnp.zeros(p.shape, jnp.float32)
    scale_shape = p.shape[:-1] + (1,) if p.ndim else (1,)
    return {"q": jnp.zeros(p.shape, jnp.int8 if signed else jnp.uint8),
            "scale": jnp.zeros(scale_shape, jnp.float32)}


def init_opt_state(params: Any, oc: OptimizerConfig) -> Dict[str, Any]:
    quant = oc.state_dtype == "int8"
    m = jax.tree_util.tree_map(lambda p: _zeros_like_state(p, quant, True), params)
    v = jax.tree_util.tree_map(lambda p: _zeros_like_state(p, quant, False), params)
    return {"m": m, "v": v, "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


_NO_DECAY = {"scale", "bias", "A_log", "dt_bias", "D", "b_q", "b_k", "b_v",
             "b_in", "b_out", "conv_b_x", "conv_b_B", "conv_b_C"}

# Stacked leaves above this size update layer-by-layer (in-place scan) so
# fp32 dequant temporaries stay one-layer-sized; tests may lower it.
CHUNK_BYTES = 128 * 1024 * 1024


def adamw_update(oc: OptimizerConfig, grads: Any, params: Any,
                 opt_state: Dict[str, Any]
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    quant = oc.state_dtype == "int8"
    step = opt_state["step"] + 1
    lr = lr_at(oc, step)
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12))
    t = step.astype(jnp.float32)
    bc1 = 1.0 - oc.b1 ** t
    bc2 = 1.0 - oc.b2 ** t

    paths_p = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    flat_p = paths_p
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])

    def leaf_update(p, g, m_s, v_s, decay: bool):
        g = g.astype(jnp.float32) * clip
        m = _dequant_m(m_s) if quant else m_s
        v = _dequant_v(v_s) if quant else v_s
        m = oc.b1 * m + (1 - oc.b1) * g
        v = oc.b2 * v + (1 - oc.b2) * jnp.square(g)
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + oc.eps)
        if decay:
            upd = upd + oc.weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        return p2, (_quant_m(m) if quant else m), (_quant_v(v) if quant else v)

    def chunked_update(p, g, m_s, v_s, decay):
        n = p.shape[0]

        def body(carry, i):
            p_b, m_b, v_b = carry
            take = lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                          keepdims=False)
            p_i = take(p_b)
            m_i = jax.tree_util.tree_map(take, m_b)
            v_i = jax.tree_util.tree_map(take, v_b)
            p2, m2, v2 = leaf_update(p_i, take(g), m_i, v_i, decay)
            put = lambda b, x: jax.lax.dynamic_update_index_in_dim(
                b, x.astype(b.dtype), i, 0)
            p_b = put(p_b, p2)
            m_b = jax.tree_util.tree_map(put, m_b, m2)
            v_b = jax.tree_util.tree_map(put, v_b, v2)
            return (p_b, m_b, v_b), None

        (p2, m2, v2), _ = jax.lax.scan(body, (p, m_s, v_s),
                                       jnp.arange(n))
        return p2, m2, v2

    new_p, new_m, new_v = [], [], []
    for (path, p), g, m_s, v_s in zip(flat_p, flat_g, flat_m, flat_v):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        decay = bool(oc.weight_decay) and name not in _NO_DECAY
        # chunk ONLY over a genuine layer-stack dim (small leading extent,
        # ndim>=3).  Chunking a 2-D leaf (embedding/lm_head) would scan
        # over a model-sharded dim: measured 16.7 TB of per-row collectives.
        if p.size * 4 > CHUNK_BYTES and p.ndim >= 3 and 1 < p.shape[0] <= 256:
            p2, m2, v2 = chunked_update(p, g, m_s, v_s, decay)
        else:
            p2, m2, v2 = leaf_update(p, g, m_s, v_s, decay)
        new_p.append(p2)
        new_m.append(m2)
        new_v.append(v2)

    params2 = treedef.unflatten(new_p)
    state2 = {"m": treedef.unflatten(new_m), "v": treedef.unflatten(new_v),
              "step": step}
    metrics = {"lr": lr, "grad_norm": gnorm}
    return params2, state2, metrics
