from .adamw import (OptimizerConfig, adamw_update, init_opt_state, lr_at,
                    global_norm)
