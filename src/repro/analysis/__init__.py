"""``repro.analysis`` — the OCR sanitizer (``ocrsan``).

A happens-before race detector plus invariant lints over the runtime's
event stream.  Enable with ``Runtime(sanitize=True)`` (record-only),
``Runtime(sanitize="strict")`` (raise :class:`OcrSanError` at ``run()``
return on hard findings), or the ``REPRO_SANITIZE`` environment variable
(``1``/``strict`` → strict, ``record`` → record-only).

See the README "Sanitizer" section for finding kinds and the
vector-clock witness format.
"""
from .hb import Access, Clock, RaceDetector, join, ordered
from .report import (
    DANGLING_SLOT,
    Finding,
    GUID_DOUBLE_CREATE,
    GUID_NON_MEMOIZED,
    HARD_KINDS,
    HB_RACE,
    LEAK,
    LID_ESCAPE,
    LOST_WAKEUP,
    OcrSanError,
    PARTITION_OVERLAP,
    PARENT_BEFORE_CHILDREN,
    SanitizerReport,
)
from .trace import Sanitizer, active_sanitizers, load_trace

__all__ = [
    "Access", "Clock", "RaceDetector", "join", "ordered",
    "Finding", "SanitizerReport", "OcrSanError", "HARD_KINDS",
    "HB_RACE", "LID_ESCAPE", "GUID_DOUBLE_CREATE", "GUID_NON_MEMOIZED",
    "PARTITION_OVERLAP", "PARENT_BEFORE_CHILDREN", "LOST_WAKEUP",
    "LEAK", "DANGLING_SLOT",
    "Sanitizer", "active_sanitizers", "load_trace",
]
