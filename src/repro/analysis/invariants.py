"""Quiescence lints: checks that only make sense once the heap drains.

Two tiers:

- :func:`quiescence_lost_wakeups` finds parked waiters nothing will ever
  wake — **hard** findings (the runtime lost a wakeup, or a release path
  forgot ``_wake_waiters``).  Only run when the event heap is empty: a
  waiter with in-flight messages may still be woken.
- :func:`quiescence_advisories` reports leaked objects and dangling
  dependence slots — **advisory** findings, computed fresh on demand and
  never raised, because many programs legitimately end with live DBs the
  driver reads after ``run()`` returns.
"""
from __future__ import annotations

from typing import TYPE_CHECKING, List

from repro.core.guid import DbMode, Guid
from repro.core.objects import DbObj, EdtObj, EventObj

from .report import DANGLING_SLOT, Finding, LEAK, LOST_WAKEUP

if TYPE_CHECKING:  # pragma: no cover
    from .trace import Sanitizer


def _deps_available(rt, edt: EdtObj) -> bool:
    """Would ``_try_grant`` succeed for ``edt`` right now?"""
    for slot, mode in zip(edt.slots, edt.modes):
        if not isinstance(slot, Guid) or mode == DbMode.NULL:
            continue
        db = rt.try_lookup(slot)
        if db is None:
            continue        # grant skips missing DBs too
        if db.partitions:
            return False    # §6.2: parked until children release
        if not db.available(mode):
            return False
    return True


def quiescence_lost_wakeups(san: "Sanitizer") -> None:
    """Flag ready waiters parked on a DB that is free (hard findings).

    At quiescence every queue entry is stale, dead, or lost.  A live,
    ready EDT whose *entire* dependence set is grantable yet still sits
    in a waiter queue means some release path dropped its wakeup.
    """
    rt = san.rt
    for dbg, queue in rt._db_waiters.items():
        for edt in queue:
            if edt.waiting_on != dbg or edt.state != "ready":
                continue    # stale entry (already woken / re-parked)
            if not rt.nodes[edt.node].alive:
                continue
            g = edt.guid
            db = rt.try_lookup(dbg)
            if db is None:
                san._add(
                    (LOST_WAKEUP, g),
                    Finding(LOST_WAKEUP, (g, dbg),
                            f"edt {g.node}:{g.seq} parked on destroyed "
                            f"db {dbg.node}:{dbg.seq} at quiescence — "
                            f"destroy path never woke its waiters",
                            t=rt.clock))
            elif _deps_available(rt, edt):
                san._add(
                    (LOST_WAKEUP, g),
                    Finding(LOST_WAKEUP, (g, dbg),
                            f"edt {g.node}:{g.seq} parked on free "
                            f"db {dbg.node}:{dbg.seq} at quiescence with "
                            f"every dependence grantable — lost wakeup",
                            t=rt.clock))


def quiescence_advisories(san: "Sanitizer") -> List[Finding]:
    """Leaked DBs/events and dangling dependence slots (advisory)."""
    rt = san.rt
    out: List[Finding] = []
    leaked_dbs: List[Guid] = []
    leaked_evs: List[Guid] = []
    dangling: List[Guid] = []
    for node in rt.nodes:
        if not node.alive:
            continue
        for obj in node.objects.values():
            if isinstance(obj, DbObj):
                if not obj.destroyed:
                    leaked_dbs.append(obj.guid)
            elif isinstance(obj, EventObj):
                if not obj.satisfied and not obj.destroyed:
                    leaked_evs.append(obj.guid)
            elif isinstance(obj, EdtObj):
                if obj.state == "created" and obj.pending > 0:
                    dangling.append(obj.guid)

    def _agg(kind: str, guids: List[Guid], what: str) -> None:
        sample = ", ".join(str(g) for g in guids[:4])
        more = f" (+{len(guids) - 4} more)" if len(guids) > 4 else ""
        out.append(Finding(kind, tuple(guids[:16]),
                           f"{len(guids)} {what} at quiescence: "
                           f"{sample}{more}",
                           t=rt.clock))

    if leaked_dbs:
        _agg(LEAK, leaked_dbs, "data block(s) never destroyed")
    if leaked_evs:
        _agg(LEAK, leaked_evs, "event(s) never satisfied nor destroyed")
    if dangling:
        _agg(DANGLING_SLOT, dangling,
             "EDT(s) with unsatisfied dependence slots")
    return out
