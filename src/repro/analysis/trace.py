"""The sanitizer facade: event recorder + checker driver (``ocrsan``).

One :class:`Sanitizer` instance hangs off a ``Runtime(sanitize=...)``.
The runtime calls the ``on_*`` hooks from its own choke points (send,
dispatch, grant, release, destroy, partition, copy, map-get, LID
alloc/bind, kill, run-return); every hook is behind a single
``if self._san is not None`` so the disabled path costs one attribute
check.

The recorder keeps a bounded structured trace (``trace_events``), feeds
the vector-clock engine (:mod:`repro.analysis.hb`) and the invariant
lints (:mod:`repro.analysis.invariants`), and accumulates
:class:`~repro.analysis.report.Finding` objects.  Activity / clock
bookkeeping:

- one **driver** activity per runtime (ambient ``TaskCtx`` calls between
  ``run()`` phases); at every ``run()`` return it joins the clocks of
  everything that retired — single-threaded DES makes that join
  physically sound, so cross-phase driver programs are never flagged;
- one activity per **granted EDT** (created at grant, base clock = join
  of creation context, slot satisfies, and acquired locks' release
  clocks);
- one activity per executed **db_copy** (forked from the issuing
  message's clock; the completion event inherits the copy's tick, so
  readers gated on the completion event are ordered and readers that
  skip it race — §6.3's actual contract).

Scope tokens (for §3 LID attribution) are orthogonal to clocks: the
driver token, the owning EDT's guid inside a task body, or the message
object during a handler.  A LID referenced before binding from any scope
other than the one that allocated it is an escape.
"""
from __future__ import annotations

import collections
import json
from typing import Any, Deque, Dict, List, Optional, Tuple
import weakref

from repro.core.guid import DbMode, Guid, Lid, ObjectKind
from repro.core.objects import DbObj, EdtObj, EventObj

from .hb import Access, Clock, RaceDetector, join
from .invariants import quiescence_advisories, quiescence_lost_wakeups
from .report import (
    Finding,
    GUID_DOUBLE_CREATE,
    GUID_NON_MEMOIZED,
    HB_RACE,
    LID_ESCAPE,
    OcrSanError,
    PARTITION_OVERLAP,
    PARENT_BEFORE_CHILDREN,
    SanitizerReport,
    fmt_clock,
    summarize,
)

_EXCL = (DbMode.RW, DbMode.EW)

# sanitizers with potentially-unreported findings (for the CI conftest
# fixture: after each test, anything recorded but never surfaced fails)
_ACTIVE: "weakref.WeakSet[Sanitizer]" = weakref.WeakSet()


def active_sanitizers() -> List["Sanitizer"]:
    return list(_ACTIVE)


class Sanitizer:
    """Happens-before race detector + OCR-invariant checker."""

    TRACE_CAP = 200_000

    def __init__(self, rt: Any, strict: bool = False) -> None:
        self.rt = rt
        self.strict = strict
        # --- activities & clocks ---
        self._next_act = 0
        self.names: Dict[int, str] = {}
        self._driver = self._new_act("driver")
        self._driver_clock: Clock = {self._driver: 0}
        self.cur: Clock = self._driver_clock
        self.cur_act: Optional[int] = self._driver
        self.cur_scope: Any = self          # driver scope token
        self._task_clock: Dict[Guid, Clock] = {}
        self._task_act: Dict[Guid, int] = {}
        self._ev_clock: Dict[Guid, Clock] = {}
        self._rel_excl: Dict[Guid, Clock] = {}
        self._rel_shared: Dict[Guid, Clock] = {}
        # §6.3 copy streams: copies touching one root DB execute in
        # arrival order at its owner (the runtime's documented
        # last-writer-wins / reads-see-earlier-writes batch semantics),
        # so successive copies chain through this per-root clock
        self._copy_seq: Dict[Guid, Clock] = {}
        self._done: Clock = {}             # retired work, joined at run() return
        # --- checkers ---
        self.races = RaceDetector()
        self._race_count = 0
        self._children: Dict[Guid, Dict[Guid, Tuple[int, int]]] = {}
        self._lid_scope: Dict[Lid, Any] = {}
        self._map_entries: Dict[Tuple[Guid, int], Guid] = {}
        self._map_creates: Dict[Tuple[Guid, int], int] = {}
        # --- findings & trace ---
        self.findings: List[Finding] = []
        self._keys: set = set()
        self._consumed = 0                 # hard findings already surfaced
        self.n_events = 0
        self.trace_events: Deque[Tuple] = collections.deque(maxlen=self.TRACE_CAP)
        self._copy_n = 0
        _ACTIVE.add(self)

    # ------------------------------------------------------------ plumbing

    def _new_act(self, name: str) -> int:
        a = self._next_act
        self._next_act = a + 1
        self.names[a] = name
        return a

    def _enter(self, clock: Clock, act: Optional[int], scope: Any):
        tok = (self.cur, self.cur_act, self.cur_scope)
        self.cur, self.cur_act, self.cur_scope = clock, act, scope
        return tok

    def _exit(self, tok) -> None:
        self.cur, self.cur_act, self.cur_scope = tok

    def _ev(self, kind: str, *info: Any) -> None:
        self.n_events += 1
        self.trace_events.append((self.rt.clock, kind) + info)

    def _add(self, key: Tuple, f: Finding) -> None:
        if key in self._keys:
            return
        self._keys.add(key)
        self.findings.append(f)

    def _scope_name(self, scope: Any) -> str:
        if scope is self:
            return "driver"
        if isinstance(scope, Guid):
            return f"edt {scope.node}:{scope.seq}"
        return f"handler {type(scope).__name__}#{getattr(scope, 'uid', '?')}"

    def _root(self, db: DbObj, off: int = 0) -> Tuple[Guid, int]:
        """Map ``db`` (+ local offset) to (root guid, offset in root)."""
        rt = self.rt
        while db.parent is not None:
            off += db.offset_in_parent
            p = rt.try_lookup(db.parent)
            if p is None:
                break
            db = p
        return db.guid, off

    # --------------------------------------------------- message transport

    def on_send(self, msg: Any) -> None:
        if self.cur_act is not None:
            # program order within an activity: each send is a fresh tick
            self.cur[self.cur_act] = self.cur.get(self.cur_act, 0) + 1
        msg._san_clock = dict(self.cur)

    def msg_begin(self, msg: Any):
        clk = msg._san_clock
        return self._enter(dict(clk) if clk is not None else {}, None, msg)

    def ctx_end(self, tok) -> None:
        self._exit(tok)

    # --------------------------------------------------------- task edges

    def on_task_created(self, guid: Guid) -> None:
        self._task_clock[guid] = dict(self.cur)

    def on_slot_satisfied(self, guid: Guid) -> None:
        base = self._task_clock.get(guid)
        if base is not None:
            join(base, self.cur)
        self._ev("satisfy-slot", guid)

    def on_event_satisfied(self, ev: EventObj) -> None:
        ec = self._ev_clock.setdefault(ev.guid, {})
        join(ec, self.cur)
        # the fan-out (if this satisfy fires the event) must carry the
        # join of *every* satisfier — latches accumulate across calls
        join(self.cur, ec)
        self._ev("satisfy-event", ev.guid)

    def on_event_replay(self, guid: Guid) -> None:
        # late dependence on an already-satisfied event (sticky / §3
        # tombstone): the dependent inherits the event's full history
        ec = self._ev_clock.get(guid)
        if ec:
            join(self.cur, ec)

    def on_grant(self, edt: EdtObj, deps: List[Tuple[DbObj, DbMode]]) -> None:
        g = edt.guid
        base = self._task_clock.pop(g, None)
        if base is None:
            base = dict(self.cur)
        act = self._new_act(f"edt {g.node}:{g.seq}")
        base[act] = 1
        for db, mode in deps:
            # lock-order edges: any acquisition orders after past exclusive
            # releases; an exclusive acquisition also orders after past
            # shared releases (§6 acquire protocol)
            rc = self._rel_excl.get(db.guid)
            if rc:
                join(base, rc)
            if mode in _EXCL:
                rs = self._rel_shared.get(db.guid)
                if rs:
                    join(base, rs)
        snap = dict(base)
        t = self.rt.clock
        for db, mode in deps:
            excl = mode in _EXCL
            root, b = self._root(db)
            d = db.guid
            label = (f"edt {g.node}:{g.seq} {mode.name} "
                     f"db {d.node}:{d.seq}[{b}:{b + db.size}) @t={t:g}")
            hit = self.races.record(
                root, Access(act, 1, snap, excl, b, b + db.size, label, t))
            if hit is not None:
                self._race(root, hit)
        self._task_act[g] = act
        self._task_clock[g] = base
        self._ev("grant", g, tuple(d.guid for d, _ in deps))

    def task_begin(self, guid: Guid):
        return self._enter(self._task_clock[guid], self._task_act[guid], guid)

    def task_end_begin(self, guid: Guid):
        clock = self._task_clock.get(guid)
        act = self._task_act.get(guid)
        if clock is None or act is None:      # defensive: unseen grant
            clock, act = dict(self.cur), None
        else:
            clock[act] = clock.get(act, 0) + 1
        return self._enter(clock, act, guid)

    def task_end_finish(self, guid: Guid, tok) -> None:
        self._exit(tok)
        done = self._task_clock.pop(guid, None)
        if done:
            join(self._done, done)
        self._task_act.pop(guid, None)

    def task_lost(self, guid: Guid) -> None:
        self._task_clock.pop(guid, None)
        self._task_act.pop(guid, None)

    # -------------------------------------------------------- locks & DBs

    def on_release(self, db: DbObj, exclusive: bool) -> None:
        tgt = self._rel_excl if exclusive else self._rel_shared
        join(tgt.setdefault(db.guid, {}), self.cur)
        self._ev("release", db.guid, "excl" if exclusive else "shared")

    def on_partition_create(self, parent: DbObj,
                            kids: List[Tuple[Guid, int, int]],
                            zero_copy: bool = False) -> None:
        reg = self._children.setdefault(parent.guid, {})
        rx = self._rel_excl.get(parent.guid)
        rs = self._rel_shared.get(parent.guid)
        for (g, o, s) in kids:
            lo, hi = o, o + s
            for og, (olo, ohi) in reg.items():
                if lo < ohi and olo < hi:
                    self._add(
                        (PARTITION_OVERLAP, parent.guid, g, og),
                        Finding(PARTITION_OVERLAP, (parent.guid, g, og),
                                f"partitions of {parent.guid} overlap: "
                                f"{g}[{lo}:{hi}) vs {og}[{olo}:{ohi}) — §6 "
                                f"partitions must be pairwise disjoint",
                                t=self.rt.clock))
            reg[g] = (lo, hi)
            # children inherit the parent's release order (§6.2): a child
            # writer is ordered after whoever released the parent before
            # the partitioning, and after the partitioning context itself
            ce = dict(self.cur)
            if rx:
                join(ce, rx)
            self._rel_excl[g] = ce
            self._rel_shared[g] = dict(rs) if rs else {}
        self._ev("partition-create", parent.guid, tuple(g for g, _, _ in kids),
                 "zero-copy" if zero_copy else "view")

    def on_db_destroyed(self, db: DbObj) -> None:
        g = db.guid
        kids = self._children.pop(g, None)
        if kids:
            self._add(
                (PARENT_BEFORE_CHILDREN, g),
                Finding(PARENT_BEFORE_CHILDREN, (g,) + tuple(kids),
                        f"{g} destroyed while {len(kids)} partition(s) live "
                        f"({', '.join(str(k) for k in list(kids)[:4])}) — "
                        f"§6.2 requires children released first",
                        t=self.rt.clock))
        p = db.parent
        if p is not None:
            # §6.2 quiescence edge: the child's lifetime (its lock history
            # and its destruction context) folds into the parent's release
            # clock, ordering parent tasks granted after child quiescence
            tgt = self._rel_excl.setdefault(p, {})
            for src in (self._rel_excl.pop(g, None),
                        self._rel_shared.pop(g, None)):
                if src:
                    join(tgt, src)
            join(tgt, self.cur)
            preg = self._children.get(p)
            if preg:
                preg.pop(g, None)
            self._ev("partition-release", g, p)
        else:
            self._rel_excl.pop(g, None)
            self._rel_shared.pop(g, None)
            self._copy_seq.pop(g, None)
            self.races.drop_root(g)
            self._ev("db-destroy", g)

    # ------------------------------------------------------------- copies

    def copy_begin(self, msg: Any):
        clk = dict(msg._san_clock) if msg._san_clock is not None else {}
        self._copy_n += 1
        act = self._new_act(f"copy#{self._copy_n}")
        clk[act] = 1
        return self._enter(clk, act, msg)

    def copy_end(self, tok) -> None:
        join(self._done, self.cur)
        self._exit(tok)

    def on_copy_access(self, db: DbObj, off: int, size: int,
                       write: bool) -> None:
        rc = self._rel_excl.get(db.guid)
        if rc:
            join(self.cur, rc)
        if write:
            rs = self._rel_shared.get(db.guid)
            if rs:
                join(self.cur, rs)
        root, b = self._root(db, off)
        cs = self._copy_seq.get(root)
        if cs:
            join(self.cur, cs)
        act = self.cur_act
        d = db.guid
        t = self.rt.clock
        label = (f"{self.names.get(act, 'copy')} "
                 f"{'write' if write else 'read'} "
                 f"db {d.node}:{d.seq}[{b}:{b + size}) @t={t:g}")
        hit = self.races.record(
            root, Access(act, self.cur.get(act, 1), dict(self.cur),
                         write, b, b + size, label, t))
        if hit is not None:
            self._race(root, hit)
        join(self._copy_seq.setdefault(root, {}), self.cur)
        self._ev("copy", d, off, size, "w" if write else "r")

    def _race(self, root: Guid, hit: Tuple[Access, Access]) -> None:
        old, new = hit
        self._race_count += 1
        self._add(
            (HB_RACE, old.act, old.tick, new.act, new.lo, new.hi),
            Finding(HB_RACE, (root, old.label, new.label),
                    f"unordered conflicting accesses to bytes of {root}: "
                    f"{old.label} vs {new.label}",
                    witness=((old.label, fmt_clock(old.clock, self.names)),
                             (new.label, fmt_clock(new.clock, self.names))),
                    t=self.rt.clock))

    # ------------------------------------------------------ LIDs & maps

    def on_lid_alloc(self, lid: Lid) -> None:
        self._lid_scope[lid] = self.cur_scope

    def on_lid_bound(self, lid: Lid, guid: Guid) -> None:
        self._lid_scope.pop(lid, None)
        self._ev("lid-bind", lid, guid)

    def on_ref(self, x: Any) -> None:
        """§3: an unbound LID is only meaningful in its creating scope.

        The driver scope is exempt as a *referrer*: the main program
        sequence created every task transitively and inspecting a LID
        from a driver-level ``TaskCtx`` (the standard post-``run()``
        poke in tests and benches) is not the concurrent-actor handoff
        §3 warns about — escapes between EDTs, and into message
        handlers, still flag."""
        if type(x) is not Lid:
            return
        if self.cur_scope is self:
            return
        home = self._lid_scope.get(x)
        if home is not None and home is not self.cur_scope:
            self._add(
                (LID_ESCAPE, x, id(self.cur_scope)),
                Finding(LID_ESCAPE, (x,),
                        f"{x} referenced from {self._scope_name(self.cur_scope)} "
                        f"before binding, but its §3 home scope is "
                        f"{self._scope_name(home)}",
                        t=self.rt.clock))

    def on_map_get(self, m: Any, index: int, created: bool,
                   guid: Guid) -> None:
        key = (m.guid, index)
        if created:
            n = self._map_creates.get(key, 0)
            self._map_creates[key] = n + 1
            if n or key in self._map_entries:
                self._add(
                    (GUID_DOUBLE_CREATE, key, n),
                    Finding(GUID_DOUBLE_CREATE, (m.guid, index),
                            f"labeled map {m.guid}[{index}] ran its creator "
                            f"{n + 1} times — §4 requires exactly-once "
                            f"creation per index",
                            t=self.rt.clock))
            self._map_entries[key] = guid
            self._ev("map-create", m.guid, index, guid)
        else:
            prev = self._map_entries.setdefault(key, guid)
            if prev != guid:
                self._add(
                    (GUID_NON_MEMOIZED, key),
                    Finding(GUID_NON_MEMOIZED, (m.guid, index),
                            f"labeled map {m.guid}[{index}] returned {guid} "
                            f"but previously returned {prev} — §4 requires "
                            f"memoized reuse of one GUID per index",
                            t=self.rt.clock))

    # -------------------------------------------------- trace-only events

    def on_io_done(self, op: Any) -> None:
        self._ev("io-done", op.kind, op.path, op.offset, op.size)

    def on_spill(self, victims: int, node: int) -> None:
        self._ev("spill", node, victims)

    def on_unspill(self, guid: Guid) -> None:
        self._ev("unspill", guid)

    def on_kill_node(self, idx: int) -> None:
        self._ev("kill-node", idx)

    # ------------------------------------------------------------ results

    def on_run_return(self) -> None:
        # the driver observes everything that retired: single-threaded DES
        # makes run()-return a real synchronization point for driver code
        join(self._driver_clock, self._done)
        self._done = {}
        if not self.rt._heap:
            quiescence_lost_wakeups(self)
        st = self.rt.stats
        st.san_events = self.n_events
        st.san_races = self._race_count
        st.san_findings = len(self.findings)
        st.san_advisories = len(quiescence_advisories(self)) \
            if not self.rt._heap else 0
        if self.strict and len(self.findings) > self._consumed:
            self._consumed = len(self.findings)
            raise OcrSanError(summarize(self.findings))

    def report(self) -> SanitizerReport:
        if not self.rt._heap:
            quiescence_lost_wakeups(self)
            adv = quiescence_advisories(self)
        else:
            adv = []
        self._consumed = len(self.findings)
        return SanitizerReport(findings=list(self.findings),
                               advisories=adv, events=self.n_events)

    def unconsumed_hard(self) -> List[Finding]:
        return self.findings[self._consumed:]

    def consume(self) -> None:
        self._consumed = len(self.findings)

    def export_trace(self, path: str) -> int:
        """Dump the structured event ring buffer as JSONL for offline
        analysis (one ``{"t", "kind", "info"}`` object per line; Guid /
        Lid / tuple values are tagged so :func:`load_trace` round-trips
        them exactly).  Returns the number of events written — at most
        ``TRACE_CAP``, the ring bound."""
        n = 0
        with open(path, "w") as f:
            for ev in self.trace_events:
                rec = {"t": ev[0], "kind": ev[1],
                       "info": [_enc_trace(x) for x in ev[2:]]}
                f.write(json.dumps(rec) + "\n")
                n += 1
        return n


def _enc_trace(x: Any) -> Any:
    if isinstance(x, Guid):
        return {"guid": [x.node, x.seq, x.kind.value]}
    if isinstance(x, Lid):
        return {"lid": [x.node, x.seq]}
    if isinstance(x, tuple):
        return {"tuple": [_enc_trace(v) for v in x]}
    return x


def _dec_trace(x: Any) -> Any:
    if isinstance(x, dict):
        if "guid" in x:
            node, seq, kind = x["guid"]
            return Guid(node, seq, ObjectKind(kind))
        if "lid" in x:
            return Lid(*x["lid"])
        if "tuple" in x:
            return tuple(_dec_trace(v) for v in x["tuple"])
    return x


def load_trace(path: str) -> List[Tuple]:
    """Read a :meth:`Sanitizer.export_trace` JSONL file back into the
    in-memory event-tuple form (``(t, kind, *info)``)."""
    out: List[Tuple] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            out.append((rec["t"], rec["kind"])
                       + tuple(_dec_trace(x) for x in rec["info"]))
    return out
