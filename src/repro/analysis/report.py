"""Findings and reports for the OCR sanitizer.

A :class:`Finding` is one detected violation of a paper invariant (or a
happens-before race).  Hard findings fail strict runs; advisory findings
(leaks, dangling slots) are reported but never raise, because many tests
legitimately end with live objects that the driver inspects after
``run()`` returns.

The vector-clock witness attached to a race names the two unordered
accesses with their clocks, so a report reader can see *why* the
sanitizer considers them concurrent: neither clock contains the other
access's ``(activity, tick)`` component.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

from repro.core.objects import OcrError

# ------------------------------------------------------------ finding kinds

HB_RACE = "hb-race"
LID_ESCAPE = "lid-escape"
GUID_DOUBLE_CREATE = "guid-double-create"
GUID_NON_MEMOIZED = "guid-non-memoized"
PARTITION_OVERLAP = "partition-overlap"
PARENT_BEFORE_CHILDREN = "parent-released-before-children"
LOST_WAKEUP = "lost-wakeup"
LEAK = "leak"                      # advisory
DANGLING_SLOT = "dangling-slot"    # advisory

HARD_KINDS = frozenset({
    HB_RACE, LID_ESCAPE, GUID_DOUBLE_CREATE, GUID_NON_MEMOIZED,
    PARTITION_OVERLAP, PARENT_BEFORE_CHILDREN, LOST_WAKEUP,
})


class OcrSanError(OcrError):
    """Raised at ``run()`` return in strict mode when hard findings exist."""


def fmt_clock(clock: Dict[Any, int], names: Dict[int, str]) -> str:
    """Render a vector clock as ``{name@tick, ...}`` with stable order."""
    items = sorted(clock.items())
    return "{" + ", ".join(
        f"{names.get(a, f'act{a}')}@{t}" for a, t in items) + "}"


@dataclasses.dataclass
class Finding:
    kind: str
    objects: Tuple[Any, ...]
    message: str
    # vector-clock witness: list of (label, rendered clock) pairs
    witness: Tuple[Tuple[str, str], ...] = ()
    t: float = 0.0

    @property
    def hard(self) -> bool:
        return self.kind in HARD_KINDS

    def __str__(self) -> str:
        lines = [f"[{self.kind}] t={self.t:g} {self.message}"]
        for label, clk in self.witness:
            lines.append(f"    {label}: {clk}")
        return "\n".join(lines)


@dataclasses.dataclass
class SanitizerReport:
    findings: List[Finding]          # hard findings
    advisories: List[Finding]        # leaks / dangling slots
    events: int = 0                  # trace events recorded

    def kinds(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings + self.advisories:
            out[f.kind] = out.get(f.kind, 0) + 1
        return out

    def __bool__(self) -> bool:
        return bool(self.findings)

    def __str__(self) -> str:
        if not self.findings and not self.advisories:
            return f"ocrsan: clean ({self.events} events)"
        parts = [f"ocrsan: {len(self.findings)} finding(s), "
                 f"{len(self.advisories)} advisory(ies), "
                 f"{self.events} events"]
        parts += [str(f) for f in self.findings]
        parts += [str(f) for f in self.advisories]
        return "\n".join(parts)


def summarize(findings: Sequence[Finding]) -> str:
    kinds: Dict[str, int] = {}
    for f in findings:
        kinds[f.kind] = kinds.get(f.kind, 0) + 1
    body = ", ".join(f"{k}×{n}" for k, n in sorted(kinds.items()))
    head = f"ocrsan: {len(findings)} hard finding(s): {body}"
    detail = "\n".join(str(f) for f in list(findings)[:8])
    return head + ("\n" + detail if detail else "")
