"""Vector-clock happens-before engine for the OCR sanitizer.

Clocks are sparse dicts mapping an *activity id* (one per executed EDT,
plus one ambient "driver" activity per runtime and one per executed
``db_copy``) to that activity's tick.  Happens-before edges come from
exactly the places the runtime itself creates order:

- **EDT dependence edges** — a task's base clock is the join of its
  creation context and every ``_satisfy_slot`` context.
- **Event satisfaction** — an event accumulates every satisfier's clock
  and releases the join to its dependents (latches included: the fan-out
  only happens once all decrements arrived, so dependents inherit all).
- **Message send/receive** — every message carries a snapshot of its
  sender's clock; the handler runs under it.
- **Lock order** — per-DB release clocks (``rel_excl`` for writers,
  ``rel_shared`` for readers).  A grant joins ``rel_excl`` always and
  ``rel_shared`` for exclusive modes.  This mirrors the §6 acquire
  protocol: two RW tasks on *one* DB are serialized by the runtime's
  lock, which is real order, not a race — but overlapping accesses
  through *different* DbObjs (overlapping partitions, or a ``db_copy``
  landing into a block someone else holds) share no lock and are
  flagged.
- **Partition lifecycle (§6.2)** — children inherit the parent's release
  clocks at ``db_partition``; destroying the last child joins the
  children's clocks back into the parent's, so a parent task granted
  after quiescence is ordered after every child writer.

Accesses are mapped to byte ranges of the *root* DB (walking the §6
view chain), so disjoint partition siblings never conflict and
overlapping ones conflict exactly on the shared bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

Clock = Dict[int, int]


def join(dst: Clock, src: Clock) -> None:
    """In-place elementwise max."""
    for a, t in src.items():
        if dst.get(a, 0) < t:
            dst[a] = t


def ordered(act: int, tick: int, clock: Clock) -> bool:
    """True iff the event ``(act, tick)`` happens-before ``clock``."""
    return clock.get(act, 0) >= tick


@dataclasses.dataclass
class Access:
    act: int          # activity that performed the access
    tick: int         # that activity's tick at access time
    clock: Clock      # snapshot at access time (witness + hb test)
    write: bool
    lo: int           # byte range in root-DB coordinates
    hi: int
    label: str        # e.g. "edt 0:5 EW db 0:3[64:128)"
    t: float          # virtual time


class RaceDetector:
    """Per-root-DB access histories with covered-access pruning."""

    def __init__(self) -> None:
        self._hist: Dict[Any, List[Access]] = {}

    def record(self, root: Any, acc: Access) -> Optional[Tuple[Access, Access]]:
        """Record ``acc`` against root ``root``.

        Returns the first racing (old, new) pair found, or None.  The
        history is pruned: an old access that happens-before the new
        one, is range-covered by it, and is shadowed for conflict
        purposes (the new access writes, or neither writes) can never
        race with anything the old one wouldn't also race with through
        the new access, so it is dropped — serialized chains keep O(1)
        history.
        """
        hist = self._hist.get(root)
        if hist is None:
            self._hist[root] = [acc]
            return None
        race = None
        kept: List[Access] = []
        for old in hist:
            if old.hi > acc.lo and acc.hi > old.lo and \
                    (old.write or acc.write) and \
                    not ordered(old.act, old.tick, acc.clock):
                if race is None:
                    race = (old, acc)
            if ordered(old.act, old.tick, acc.clock) and \
                    old.lo >= acc.lo and old.hi <= acc.hi and \
                    (acc.write or not old.write):
                continue            # covered: prune
            kept.append(old)
        kept.append(acc)
        self._hist[root] = kept
        return race

    def drop_root(self, root: Any) -> None:
        self._hist.pop(root, None)

    def history_len(self, root: Any) -> int:
        return len(self._hist.get(root, ()))
