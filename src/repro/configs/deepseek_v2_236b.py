"""deepseek-v2-236b [moe]: 60L d_model=5120 128H MLA kv_lora=512,
160 routed experts top-6 + 2 shared, moe_d_ff=1536, vocab=102400
[arXiv:2405.04434].  First layer dense (d_ff=12288).  int8 optimizer
states to fit HBM."""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        num_layers=60,
        d_model=5120,
        num_heads=128,
        num_kv_heads=128,
        head_dim=128,
        d_ff=1536,
        vocab_size=102400,
        num_experts=160,
        experts_per_token=6,
        num_shared_experts=2,
        moe_d_ff=1536,
        first_k_dense=1,
        use_mla=True,
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
        optimizer_state_dtype="int8",
        train_accum_steps=4,
    )
