"""Model / run configuration schema.

One frozen dataclass describes every assigned architecture; family-specific
fields are zero/empty when unused.  ``reduced()`` derives the small smoke
variant of the same family (few layers, narrow width, tiny vocab) used by
CPU tests; the full configs are exercised only via the AOT dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 → d_model // num_heads

    # --- attention flavour ---
    qkv_bias: bool = False           # qwen2
    sliding_window: int = 0          # SWA (danube3, mistral)
    rope_theta: float = 10000.0

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    num_shared_experts: int = 0      # deepseek-v2: always-on experts
    moe_d_ff: int = 0                # per-expert hidden (deepseek: 1536)
    moe_dense_residual: bool = False # arctic: dense FFN in parallel with MoE
    first_k_dense: int = 0           # deepseek-v2: leading dense layers
    capacity_factor: float = 1.25
    # EP combine under a "model" mesh axis: "a2a" exchanges capacity
    # buckets with all_to_all (default); "psum" replicates tokens over
    # "model" and psums the combine (legacy baseline, and the automatic
    # fallback when seq does not divide the model axis)
    moe_dispatch: str = "a2a"

    # --- MLA (deepseek-v2) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    conv_kernel: int = 4
    expand: int = 2

    # --- hybrid (zamba2): shared attention block every N mamba blocks ---
    attn_every: int = 0

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 0             # precomputed frame embeddings (stub frontend)

    # --- VLM (llava): prefix patch embeddings (stub frontend) ---
    num_patches: int = 0

    # --- numerics / training policy ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"          # compute dtype (per-layer cast)
    param_dtype: str = "float32"     # fp32 master weights
    remat: str = "layer"             # none | layer | dots
    optimizer_state_dtype: str = "float32"   # float32 | int8 (≥100B configs)
    loss_chunk: int = 1024           # sequence-chunked CE loss
    train_accum_steps: int = 1       # gradient accumulation microbatches
    # flash-attention tile OVERRIDES: None (default) lets the trace-time
    # autotuner (repro.kernels.autotune) pick blocks per shape; ints pin
    # a hand-tuned layout (fwd AND bwd tiles).
    attn_block_q: Optional[int] = None
    attn_block_k: Optional[int] = None
    attn_flash_min_seq: int = 2048   # below max(2·block_q, this): dense ref
    use_scan: bool = True            # lax.scan over layers (compile scalability)
    pure_dp: bool = False            # small models: batch over ALL mesh axes,
    #                                  weights replicated (no TP/SP/FSDP)

    # set True on archs where long_500k is runnable (sub-quadratic)
    subquadratic: bool = False

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def reduced(self) -> "ModelConfig":
        """Small same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=min(self.num_layers, 4 if self.family == "hybrid" else 2),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            capacity_factor=8.0,     # no token dropping in smoke tests
            moe_d_ff=64 if self.moe_d_ff else 0,
            q_lora_rank=64 if self.q_lora_rank else 0,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_nope_head_dim=16 if self.qk_nope_head_dim else 0,
            qk_rope_head_dim=16 if self.qk_rope_head_dim else 0,
            v_head_dim=32 if self.v_head_dim else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32 if self.ssm_state else 64,
            ssm_chunk=16,
            attn_every=2 if self.attn_every else 0,
            num_encoder_layers=min(self.num_encoder_layers, 2),
            encoder_seq=24 if self.encoder_seq else 0,
            num_patches=8 if self.num_patches else 0,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            dtype="float32",
            param_dtype="float32",
            loss_chunk=32,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """An assigned (input-shape × step-kind) cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_by_name(name: str) -> ShapeConfig:
    for s in SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    """Cell-applicability rules (see DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k":
        return cfg.subquadratic          # SSM / hybrid only
    return True
