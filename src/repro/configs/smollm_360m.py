"""smollm-360m [dense]: 32L d_model=960 15H (kv=5) d_ff=2560
vocab=49152 [hf:HuggingFaceTB]."""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m",
        family="dense",
        num_layers=32,
        d_model=960,
        num_heads=15,
        num_kv_heads=5,
        head_dim=64,
        d_ff=2560,
        vocab_size=49152,
        tie_embeddings=True,
        # pure_dp=True was A/B'd for this arch (§Perf): collectives -76%
        # but the as-lowered memory term regressed +10% (full-S² jnp
        # attention tiles per device); default recipe retained.
    )
