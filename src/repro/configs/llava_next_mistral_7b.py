"""llava-next-mistral-7b [vlm]: mistral-7b backbone 32L d_model=4096
32H (kv=8) d_ff=14336 vocab=32000 [hf:llava-hf].  Anyres tiling frontend
is a STUB: ``input_specs`` provides 576 precomputed patch embeddings."""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-mistral-7b",
        family="vlm",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        sliding_window=4096,
        num_patches=576,
    )
