"""llama3.2-3b [dense]: 28L d_model=3072 24H (kv=8) d_ff=8192
vocab=128256 [hf:meta-llama]."""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        num_layers=28,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=128256,
        rope_theta=500000.0,
        tie_embeddings=True,
    )
