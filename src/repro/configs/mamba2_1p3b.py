"""mamba2-1.3b [ssm]: 48L d_model=2048 attn-free vocab=50280
ssm_state=128 (SSD) [arXiv:2405.21060].  O(1)-state decode ⇒ long_500k."""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b",
        family="ssm",
        num_layers=48,
        d_model=2048,
        num_heads=0,
        num_kv_heads=0,
        head_dim=64,
        d_ff=0,
        vocab_size=50280,
        ssm_state=128,
        ssm_head_dim=64,
        expand=2,
        tie_embeddings=True,
        subquadratic=True,
    )
