"""arctic-480b [moe]: 35L d_model=7168 56H (kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual [hf:Snowflake].

Memory recipe for 16 GB/chip HBM: int8 optimizer moments + bf16 master
weights (fp32 Adam math per layer-chunk, rounded back to bf16) + 4-way
gradient accumulation — see repro.optim and EXPERIMENTS.md §Dry-run."""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        num_experts=128,
        experts_per_token=2,
        moe_d_ff=4864,
        moe_dense_residual=True,
        optimizer_state_dtype="int8",
        param_dtype="bfloat16",
        train_accum_steps=4,
    )
