"""Assigned-architecture registry: ``get_config(arch_id)``."""
from .base import (LONG_500K, DECODE_32K, PREFILL_32K, TRAIN_4K, ModelConfig,
                   SHAPES, ShapeConfig, applicable, shape_by_name)

_REGISTRY = {}


def register(fn):
    cfg = fn()
    _REGISTRY[cfg.name] = cfg
    return fn


def get_config(name: str) -> ModelConfig:
    from . import (zamba2_1p2b, whisper_small, h2o_danube3_4b, llama3p2_3b,
                   smollm_360m, qwen2_7b, mamba2_1p3b, arctic_480b,
                   deepseek_v2_236b, llava_next_mistral_7b)  # noqa: F401
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    return _REGISTRY[name]


def all_arch_names():
    get_config("smollm-360m")  # force registration
    return sorted(_REGISTRY)
