"""h2o-danube-3-4b [dense]: 24L d_model=3840 32H (kv=8) d_ff=10240
vocab=32000, sliding-window attention [arXiv:2401.16818]."""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        num_layers=24,
        d_model=3840,
        num_heads=32,
        num_kv_heads=8,
        head_dim=120,
        d_ff=10240,
        vocab_size=32000,
        sliding_window=4096,
    )
