"""whisper-small [audio/enc-dec]: 12L enc + 12L dec, d_model=768, 12H,
d_ff=3072, vocab=51865 [arXiv:2212.04356].  Conv frontend is a STUB:
``input_specs`` provides precomputed frame embeddings (B, 1504, 768)
(1500 mel frames padded to 1504 for clean sharding).  RoPE replaces the
learned positional table (noted deviation, DESIGN.md §9)."""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-small",
        family="encdec",
        num_layers=12,
        d_model=768,
        num_heads=12,
        num_kv_heads=12,
        head_dim=64,
        d_ff=3072,
        vocab_size=51865,
        is_encoder_decoder=True,
        num_encoder_layers=12,
        encoder_seq=1504,
    )
