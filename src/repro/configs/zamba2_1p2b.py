"""zamba2-1.2b [hybrid]: Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf].  Shared transformer block applied every 6 mamba
blocks (weights shared across applications — the paper's §4 labeled-map
object dedup).  Sub-quadratic ⇒ runs long_500k.
"""
from . import register
from .base import ModelConfig


@register
def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        head_dim=64,
        d_ff=8192,
        vocab_size=32000,
        ssm_state=64,
        ssm_head_dim=64,
        expand=2,
        attn_every=6,
        tie_embeddings=True,
        subquadratic=True,
    )
