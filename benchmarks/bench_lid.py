"""§3 — local identifiers: blocking GUID creation vs LID futures.

Measures, for a task creating N remote objects and wiring a dependence to
each: blocking round-trips, total messages, deferred-message count, and the
virtual-time makespan (net latency L=5).  The paper's claim: LIDs remove
every creation round-trip from the critical path.
"""
import time

from repro.core import (DbMode, EDT_PROP_LID, NULL_GUID, Runtime,
                        UNINITIALIZED_GUID, spawn_main)


def _chain(use_lid: bool, n: int, latency: float = 5.0):
    rt = Runtime(num_nodes=4, net_latency=latency)

    def noop(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(noop, 0, 1)
        for i in range(n):
            t, _ = api.edt_create(tmpl, depv=[UNINITIALIZED_GUID],
                                  props=EDT_PROP_LID if use_lid else 0,
                                  placement=1 + (i % 3))
            api.add_dependence(NULL_GUID, t, 0, DbMode.NULL)
        return NULL_GUID

    spawn_main(rt, main)
    return rt.run()


def run():
    rows = []
    for n in (8, 64, 256):
        t0 = time.perf_counter()
        blk = _chain(False, n)
        lid = _chain(True, n)
        us = (time.perf_counter() - t0) / (2 * n) * 1e6
        rows.append((
            f"lid.chain_n{n}", f"{us:.1f}",
            f"blocking_roundtrips={blk.blocking_roundtrips}->"
            f"{lid.blocking_roundtrips};makespan={blk.makespan:.0f}->"
            f"{lid.makespan:.0f};deferred={lid.messages_deferred};"
            f"rescans={lid.deferred_rescans};"
            f"speedup={blk.makespan / lid.makespan:.2f}x"))
    return rows


def summary():
    """Machine-readable snapshot for BENCH_lid.json (perf trajectory)."""
    t0 = time.perf_counter()
    blk = _chain(False, 256)
    lid = _chain(True, 256)
    wall = time.perf_counter() - t0
    return {
        "n_objects": 256,
        "makespan_blocking": blk.makespan,
        "makespan_lid": lid.makespan,
        "messages_sent": blk.messages_sent + lid.messages_sent,
        "messages_deferred": lid.messages_deferred,
        "deferred_rescans": lid.deferred_rescans,
        "wall_time_s": wall,
    }


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
