"""Wall-clock microbenchmarks of the step functions on reduced configs
(CPU; the real targets are AOT artifacts — see bench_roofline)."""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticTokens
from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig
from repro.train.steps import init_train_state, make_train_step


def _bench_arch(arch: str, steps: int = 8):
    cfg = get_config(arch).reduced()
    model = LanguageModel(cfg)
    oc = OptimizerConfig()
    data = SyntheticTokens(cfg.vocab_size, batch=4, seq=64, seed=0)
    step = jax.jit(make_train_step(model, oc), donate_argnums=(0,))
    st = init_train_state(model, jax.random.PRNGKey(0), oc)
    b = {k: jnp.asarray(v) for k, v in data.get(0).items()}
    st, _ = step(st, b)                       # compile
    jax.block_until_ready(st)
    t0 = time.perf_counter()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.get(i + 1).items()}
        st, m = step(st, b)
    jax.block_until_ready(st)
    dt = (time.perf_counter() - t0) / steps
    toks = 4 * 64
    return dt * 1e6, toks / dt


def run():
    rows = []
    for arch in ("smollm-360m", "mamba2-1.3b", "deepseek-v2-236b",
                 "zamba2-1.2b"):
        us, tps = _bench_arch(arch)
        rows.append((f"train.step_{arch}-smoke", f"{us:.0f}",
                     f"tokens_per_s={tps:.0f} (reduced cfg, CPU)"))
    return rows
