"""Wall-clock microbenchmarks of the step functions on reduced configs
(CPU; the real targets are AOT artifacts — see bench_roofline), plus
real AOT dry-run cells for the production MoE configs at TRUE expert
counts (deepseek-v2-236b E=160, arctic-480b E=128): full-size train
step lowered + compiled on a 16-device mesh matching the production
"model"-axis width, with ``hlo_cost``-parsed collective bytes per cell
— the capacity-bucketed all-to-all shows up as ``all-to-all`` traffic
in the compiled SPMD HLO (the 16×16 production mesh compiles the same
cells but takes ~10 min/cell on CPU; 1×16 keeps the per-device expert
and bucket layout identical at bench-friendly compile times)."""
import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticTokens
from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig
from repro.train.steps import init_train_state, make_train_step

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")

_dryrun_cache = {}


def _dryrun_cell(arch: str):
    """Lower + compile the FULL config's train step (no reduced()) on a
    (1, 16) mesh and parse collective traffic from the SPMD HLO."""
    if arch in _dryrun_cache:
        return _dryrun_cache[arch]
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=16'\n"
            f"import sys\nsys.path.insert(0, {_SRC!r})\n"
            + textwrap.dedent(f"""
        import json, time
        import jax
        from repro.configs import get_config
        from repro.configs.base import shape_by_name
        from repro.dist.sharding import use_mesh
        from repro.launch import hlo_cost
        from repro.launch.dryrun import lower_cell

        cfg = get_config("{arch}")
        shape = shape_by_name("train_4k")
        mesh = jax.make_mesh((1, 16), ("data", "model"))
        t0 = time.time()
        with use_mesh(mesh) as ctx:
            lowered, _ = lower_cell(cfg, shape, mesh, ctx)
            compiled = lowered.compile()
            cost = hlo_cost.analyze(compiled.as_text())
        print(json.dumps({{
            "compile_s": time.time() - t0,
            "flops": cost.flops,
            "coll_bytes": cost.coll_total,
            "per_kind": {{k: v for k, v in cost.coll_bytes.items() if v}},
            "num_experts": cfg.num_experts,
        }}))
    """))
    out = None
    try:
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=560)
        rec = json.loads(out.stdout.strip().splitlines()[-1])
    except Exception as e:
        rec = {"error": f"{type(e).__name__}: {e}"}
        if out is not None and out.returncode != 0:
            rec["error"] = (f"exit={out.returncode}: "
                            + out.stderr.strip()[-500:].replace("\n", " | "))
    _dryrun_cache[arch] = rec
    return rec


def _bench_arch(arch: str, steps: int = 8):
    cfg = get_config(arch).reduced()
    model = LanguageModel(cfg)
    oc = OptimizerConfig()
    data = SyntheticTokens(cfg.vocab_size, batch=4, seq=64, seed=0)
    step = jax.jit(make_train_step(model, oc), donate_argnums=(0,))
    st = init_train_state(model, jax.random.PRNGKey(0), oc)
    b = {k: jnp.asarray(v) for k, v in data.get(0).items()}
    st, _ = step(st, b)                       # compile
    jax.block_until_ready(st)
    t0 = time.perf_counter()
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.get(i + 1).items()}
        st, m = step(st, b)
    jax.block_until_ready(st)
    dt = (time.perf_counter() - t0) / steps
    toks = 4 * 64
    return dt * 1e6, toks / dt


def run():
    rows = []
    for arch in ("smollm-360m", "mamba2-1.3b", "deepseek-v2-236b",
                 "zamba2-1.2b"):
        us, tps = _bench_arch(arch)
        rows.append((f"train.step_{arch}-smoke", f"{us:.0f}",
                     f"tokens_per_s={tps:.0f} (reduced cfg, CPU)"))
    # the production MoE configs as real AOT cells at true expert counts
    for arch in ("deepseek-v2-236b", "arctic-480b"):
        rec = _dryrun_cell(arch)
        name = f"train.dryrun_{arch}_train4k_1x16"
        if "error" in rec:
            rows.append((name + ".SKIP", "0", rec["error"]))
            continue
        kinds = ";".join(f"{k}={v:.3e}"
                         for k, v in sorted(rec["per_kind"].items()))
        rows.append((name, f"{rec['compile_s'] * 1e6:.0f}",
                     f"E={rec['num_experts']};flops={rec['flops']:.3e};"
                     f"coll_bytes={rec['coll_bytes']:.3e};{kinds}"))
    return rows
