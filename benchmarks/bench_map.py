"""§4 — labeled GUIDs: concurrent-creation storms and the wavefront grid.

Measures creator-call counts under racing ``map_get`` (must equal the map
size — the exactly-once guarantee), message totals, and wavefront makespan
scaling.
"""
import time

from repro.core import (DbMode, EDT_PROP_MAPPED, NULL_GUID, Runtime,
                        UNINITIALIZED_GUID, spawn_main)


def _storm(size: int, gets_per_index: int, nodes: int = 6):
    rt = Runtime(num_nodes=nodes, seed=1, jitter=2.0)

    def creator(ctx, lid, index, paramv, guidv):
        ctx.edt_create(guidv[0], paramv=[index], depv=[UNINITIALIZED_GUID],
                       props=EDT_PROP_MAPPED)

    def noop(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(noop, 1, 1)
        m = api.map_create(size, creator, guidv=[tmpl])
        for i in range(size):
            for _ in range(gets_per_index):
                api.map_get(m, i)
        return NULL_GUID

    spawn_main(rt, main)
    return rt.run()


def _wavefront(w: int, h: int):
    from tests.test_core_runtime import run_wavefront
    return run_wavefront(w, h, num_nodes=8)


def run():
    rows = []
    for size, gets in ((16, 4), (64, 8), (256, 4)):
        t0 = time.perf_counter()
        stats = _storm(size, gets)
        us = (time.perf_counter() - t0) / (size * gets) * 1e6
        rows.append((
            f"map.storm_s{size}_g{gets}", f"{us:.1f}",
            f"creator_calls={stats.creator_calls}(expect {size});"
            f"msgs={stats.messages_sent}"))
    for w, h in ((4, 4), (8, 8)):
        t0 = time.perf_counter()
        executed, stats = _wavefront(w, h)
        us = (time.perf_counter() - t0) / (w * h) * 1e6
        rows.append((
            f"map.wavefront_{w}x{h}", f"{us:.1f}",
            f"tasks={len(executed)};makespan={stats.makespan:.0f};"
            f"critical_path={w + h - 1}"))
    return rows
