"""§4 — labeled GUIDs: concurrent-creation storms and the wavefront grid.

Measures creator-call counts under racing ``map_get`` (must equal the map
size — the exactly-once guarantee), message totals, and wavefront makespan
scaling.  Also one sharded train-step row (the trainer's step chain is the
§4 map's 1-D wavefront, and the sharded step exercises the ``repro.dist``
bridge on 8 forced host devices) so the dist subsystem shows up in the
perf trajectory (``BENCH_map.json``).
"""
import json
import os
import subprocess
import sys
import textwrap
import time

from repro.core import (DbMode, EDT_PROP_MAPPED, NULL_GUID, Runtime,
                        UNINITIALIZED_GUID, spawn_main)

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")


def _storm(size: int, gets_per_index: int, nodes: int = 6):
    rt = Runtime(num_nodes=nodes, seed=1, jitter=2.0)

    def creator(ctx, lid, index, paramv, guidv):
        ctx.edt_create(guidv[0], paramv=[index], depv=[UNINITIALIZED_GUID],
                       props=EDT_PROP_MAPPED)

    def noop(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(noop, 1, 1)
        m = api.map_create(size, creator, guidv=[tmpl])
        for i in range(size):
            for _ in range(gets_per_index):
                api.map_get(m, i)
        return NULL_GUID

    spawn_main(rt, main)
    return rt.run()


def _wavefront(w: int, h: int):
    from tests.test_core_runtime import run_wavefront
    return run_wavefront(w, h, num_nodes=8)


_sharded_cache = {}


def _sharded_step(arch: str = "smollm-360m", steps: int = 3):
    """Per-step wall time of a sharded train step on 8 forced host devices.

    Runs in a subprocess (XLA_FLAGS must be set before any jax import).
    Cached so ``run()`` and ``summary()`` pay the compile once.
    """
    if arch in _sharded_cache:
        return _sharded_cache[arch]
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            f"import sys\nsys.path.insert(0, {_SRC!r})\n"
            + textwrap.dedent(f"""
        import json, time
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.data import SyntheticTokens
        from repro.dist.sharding import use_mesh
        from repro.models.model import LanguageModel
        from repro.optim import OptimizerConfig
        from repro.train.steps import init_train_state, make_train_step

        cfg = get_config("{arch}").reduced()
        model = LanguageModel(cfg)
        oc = OptimizerConfig()
        data = SyntheticTokens(cfg.vocab_size, batch=8, seq=32, seed=0)
        state = init_train_state(model, jax.random.PRNGKey(0), oc)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        b = {{k: jnp.asarray(v) for k, v in data.get(0).items()}}
        with use_mesh(mesh):
            fn = jax.jit(make_train_step(model, oc))
            state, _ = fn(state, b)
            jax.block_until_ready(state)            # compile
            t0 = time.perf_counter()
            for _ in range({steps}):
                state, _ = fn(state, b)
            jax.block_until_ready(state)
        dt = (time.perf_counter() - t0) / {steps}
        print(json.dumps({{"step_ms": dt * 1e3,
                           "devices": jax.device_count()}}))
    """))
    out = None
    try:
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=560)
        rec = json.loads(out.stdout.strip().splitlines()[-1])
    except Exception as e:  # report (with the subprocess's own failure,
        rec = {"error": f"{type(e).__name__}: {e}"}   # not just ours)
        if out is not None and out.returncode != 0:
            rec["error"] = (f"exit={out.returncode}: "
                            + out.stderr.strip()[-500:].replace("\n", " | "))
    _sharded_cache[arch] = rec
    return rec


def run():
    rows = []
    for size, gets in ((16, 4), (64, 8), (256, 4)):
        t0 = time.perf_counter()
        stats = _storm(size, gets)
        us = (time.perf_counter() - t0) / (size * gets) * 1e6
        rows.append((
            f"map.storm_s{size}_g{gets}", f"{us:.1f}",
            f"creator_calls={stats.creator_calls}(expect {size});"
            f"msgs={stats.messages_sent}"))
    for w, h in ((4, 4), (8, 8)):
        t0 = time.perf_counter()
        executed, stats = _wavefront(w, h)
        us = (time.perf_counter() - t0) / (w * h) * 1e6
        rows.append((
            f"map.wavefront_{w}x{h}", f"{us:.1f}",
            f"tasks={len(executed)};makespan={stats.makespan:.0f};"
            f"critical_path={w + h - 1}"))
    sh = _sharded_step()
    if "step_ms" in sh:
        rows.append(("map.sharded_step_smollm360m_8dev",
                     f"{sh['step_ms'] * 1e3:.0f}",
                     f"devices={sh['devices']};mesh=2x4"))
    else:
        rows.append(("map.sharded_step_smollm360m_8dev.SKIP", "0",
                     sh.get("error", "")))
    return rows


def summary():
    """Machine-readable snapshot for BENCH_map.json (perf trajectory)."""
    t0 = time.perf_counter()
    stats = _storm(64, 8)
    executed, wf = _wavefront(8, 8)
    sh = _sharded_step()
    wall = time.perf_counter() - t0
    return {
        "storm_creator_calls": stats.creator_calls,
        "storm_messages": stats.messages_sent,
        "wavefront_tasks": len(executed),
        "makespan_wavefront_8x8": wf.makespan,
        "sharded_step_ms": sh.get("step_ms", -1.0),
        "sharded_devices": sh.get("devices", 0),
        "wall_time_s": wall,
    }
