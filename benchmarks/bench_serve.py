"""Serve engine: continuous batching vs static batching, plus spill pressure.

Open-loop Poisson arrivals into the paged-KV continuous-batching engine
(`repro.serve.engine`) with the deterministic SyntheticBackend — every
number is virtual time, so the trajectory is noise-free.  Rows:

* head-to-head at two offered loads: continuous must beat the static-batch
  baseline on tokens/s at equal-or-better p99 (the acceptance bar);
* a spill-pressure row where concurrent sessions exceed the resident
  budget: cold sessions' archives write back through the IO queue and
  resume via grant deferral — the row completes with ``spilled > 0`` and
  the backend byte-checks every resumed page.
"""
import time

from repro.serve.engine import (ServeEngine, SyntheticBackend,
                                poisson_workload, run_static)

_LOADS = (  # (tag, rate req/s, n, b_cap, pool_pages)
    ("r120", 120.0, 40, 8, 64),
    ("r400", 400.0, 60, 8, 96),
)
_SPILL = dict(rate=300.0, n=30, b_cap=8, pool_pages=20, max_pages=6,
              resident_budget=4)


def _head_to_head(rate, n, b_cap, pool_pages):
    reqs = poisson_workload(n, rate, prompt_len=(8, 32), gen=(4, 16), seed=0)
    # monitor=True exercises the registry hooks under the bench workload:
    # virtual metrics must stay bit-identical to the monitor-off snapshot
    # (the one-check-per-hook contract), and the serve.* histograms make
    # p99 a measured distribution (p99_hist_* keys)
    eng = ServeEngine(SyntheticBackend(page_size=8), b_cap=b_cap,
                      pool_pages=pool_pages, max_pages=8, monitor=True)
    cont = eng.run(reqs)
    stat = run_static(reqs, b_cap=b_cap)
    return cont, stat


def _spill_row():
    reqs = poisson_workload(_SPILL["n"], _SPILL["rate"], prompt_len=(8, 24),
                            gen=(8, 24), seed=1)
    eng = ServeEngine(SyntheticBackend(page_size=8), b_cap=_SPILL["b_cap"],
                      pool_pages=_SPILL["pool_pages"],
                      max_pages=_SPILL["max_pages"],
                      resident_budget=_SPILL["resident_budget"],
                      monitor=True)
    m = eng.run(reqs)
    ok = all(len(r.out) == r.gen for r in reqs)
    return m, ok


def run():
    rows = []
    for tag, rate, n, b_cap, pool in _LOADS:
        t0 = time.perf_counter()
        cont, stat = _head_to_head(rate, n, b_cap, pool)
        us = (time.perf_counter() - t0) / n * 1e6
        rows.append((
            f"serve.continuous_{tag}", f"{us:.1f}",
            f"tok_per_s={cont['tok_per_s']:.0f};"
            f"p99_ms={cont['p99_latency_s'] * 1e3:.2f};"
            f"speedup={cont['tok_per_s'] / stat['tok_per_s']:.2f}x"))
        rows.append((
            f"serve.static_{tag}", "0.0",
            f"tok_per_s={stat['tok_per_s']:.0f};"
            f"p99_ms={stat['p99_latency_s'] * 1e3:.2f}"))
    t0 = time.perf_counter()
    m, ok = _spill_row()
    us = (time.perf_counter() - t0) / _SPILL["n"] * 1e6
    rows.append((
        "serve.spill_pressure", f"{us:.1f}",
        f"tok_per_s={m['tok_per_s']:.0f};spilled={m['spilled_objects']:.0f};"
        f"evictions={m['evictions']:.0f};resumes={m['resumes']:.0f};"
        f"complete={'yes' if ok else 'NO'}"))
    return rows


def summary():
    """Machine-readable snapshot for BENCH_serve.json (perf trajectory).

    ``tok_per_s_*`` keys are higher-is-better (bench_diff handles the
    direction); ``p50_/p99_`` latency keys are deterministic virtual time,
    thresholded tight like makespans."""
    t0 = time.perf_counter()
    cont, stat = _head_to_head(*[v for v in _LOADS[0][1:]])
    spill, ok = _spill_row()
    wall = time.perf_counter() - t0
    return {
        "tok_per_s_continuous": cont["tok_per_s"],
        "tok_per_s_static": stat["tok_per_s"],
        "p50_latency_s_continuous": cont["p50_latency_s"],
        "p99_latency_s_continuous": cont["p99_latency_s"],
        "p99_latency_s_static": stat["p99_latency_s"],
        "makespan_continuous": cont["makespan_s"],
        "makespan_static": stat["makespan_s"],
        "speedup_tok_per_s": cont["tok_per_s"] / stat["tok_per_s"],
        "spill_tok_per_s": spill["tok_per_s"],
        "spill_spilled_objects": spill["spilled_objects"],
        "spill_evictions": spill["evictions"],
        "spill_resumes": spill["resumes"],
        "spill_complete": 1 if ok else 0,
        "creator_calls": cont["creator_calls"],
        # histogram-sourced quantiles (monitoring registry, fixed bucket
        # edges): deterministic lower-is-better, thresholded tight
        "p50_hist_latency_s_continuous": cont["p50_hist_latency_s"],
        "p99_hist_latency_s_continuous": cont["p99_hist_latency_s"],
        "p99_hist_ttft_s_continuous": cont["p99_hist_ttft_s"],
        "p99_hist_latency_s_spill": spill["p99_hist_latency_s"],
        "wall_time_s": wall,
    }
