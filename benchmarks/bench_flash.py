"""Differentiable flash attention: Pallas kernels vs the jnp twin.

Times forward and backward (fwd+bwd of a scalar loss) through
``repro.kernels.flash_attention`` — the custom-VJP Pallas path (interpret
mode on CPU, compiled on TPU) — against ``flash_attention_jnp``, the
blockwise jnp oracle the training path used before the backward kernels
existed.  Wall-clock only (no virtual time here), so the JSON keys use the
``*_ms`` loose-threshold convention of ``scripts/bench_diff.py``.
"""
import time

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.attention import flash_attention_jnp

B, S, H, KH, HD = 1, 256, 4, 2, 32
BQ = BK = 64
WINDOW = 48


def _data():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, HD))
    k = jax.random.normal(ks[1], (B, S, KH, HD))
    v = jax.random.normal(ks[2], (B, S, KH, HD))
    return q, k, v


def _time_ms(fn, *args, iters=3):
    jax.block_until_ready(fn(*args))            # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e3


def _bench(window: int):
    q, k, v = _data()

    def fwd_pallas(q_, k_, v_):
        return kops.flash_attention(q_, k_, v_, causal=True, window=window,
                                    block_q=BQ, block_k=BK)

    def fwd_jnp(q_, k_, v_):
        return flash_attention_jnp(q_, k_, v_, jnp.zeros((), jnp.float32),
                                   True, window, BQ, BK)

    grad_pallas = jax.jit(jax.grad(
        lambda q_, k_, v_: jnp.sum(fwd_pallas(q_, k_, v_)),
        argnums=(0, 1, 2)))
    grad_jnp = jax.jit(jax.grad(
        lambda q_, k_, v_: jnp.sum(fwd_jnp(q_, k_, v_)),
        argnums=(0, 1, 2)))

    return {
        "fwd_pallas_ms": _time_ms(fwd_pallas, q, k, v),
        "fwd_jnp_ms": _time_ms(fwd_jnp, q, k, v),
        "bwd_pallas_ms": _time_ms(grad_pallas, q, k, v),
        "bwd_jnp_ms": _time_ms(grad_jnp, q, k, v),
    }


_CACHE = {}


def _results():
    if not _CACHE:
        t0 = time.perf_counter()
        _CACHE["causal"] = _bench(0)
        _CACHE["window"] = _bench(WINDOW)
        _CACHE["wall_time_s"] = time.perf_counter() - t0
    return _CACHE


def run():
    res = _results()
    rows = []
    mode = "interpret" if jax.default_backend() != "tpu" else "compiled"
    for variant in ("causal", "window"):
        w = WINDOW if variant == "window" else 0
        for key, ms in res[variant].items():
            rows.append((f"flash.{variant}_{key[:-3]}", f"{ms * 1e3:.0f}",
                         f"{mode}; B={B} S={S} H={H}/{KH} bq={BQ} "
                         f"bk={BK} window={w}"))
    return rows


def summary():
    """Machine-readable snapshot for BENCH_flash.json (perf trajectory)."""
    res = _results()
    out = {"seq": S, "heads": H, "kv_heads": KH, "block_q": BQ,
           "block_k": BK, "window": WINDOW,
           "wall_time_s": res["wall_time_s"]}
    for variant in ("causal", "window"):
        for key, ms in res[variant].items():
            out[f"{variant}_{key}"] = ms
    return out


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
