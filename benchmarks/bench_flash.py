"""Differentiable flash attention: Pallas kernels vs the jnp twin, swept.

Times forward and backward (fwd+bwd of a scalar loss) through
``repro.kernels.flash_attention`` — the custom-VJP Pallas path (interpret
mode on CPU, compiled on TPU) — against ``flash_attention_jnp``, the
blockwise jnp oracle the training path used before the backward kernels
existed.  The sweep covers seq ∈ {256, 1024, 4096} × head_dim ∈ {64, 128},
causal and sliding-window, with the trace-time autotuner choosing the
kernel structure per shape (single-step megakernel, grid tiles, fused or
two-call backward); each row reports the chosen blocks.

Timing is min-of-reps (the robust estimator for a shared machine);
iteration counts shrink with the shape so the S=4096 rows stay affordable.
Wall-clock only (no virtual time here), so the JSON keys use the ``*_ms``
loose-threshold convention of ``scripts/bench_diff.py``.
"""
import functools
import time

import jax
import jax.numpy as jnp

from repro.kernels import autotune
from repro.kernels import ops as kops
from repro.models.attention import flash_attention_jnp

B, H, KH = 1, 4, 2
SEQS = (256, 1024, 4096)
HEAD_DIMS = (64, 128)
WINDOW = 48


def _data(seq: int, hd: int):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, seq, H, hd))
    k = jax.random.normal(ks[1], (B, seq, KH, hd))
    v = jax.random.normal(ks[2], (B, seq, KH, hd))
    return q, k, v


def _time_ms(fn, *args, reps=3, iters=2):
    """Min over ``reps`` timing windows of ``iters`` calls each."""
    jax.block_until_ready(fn(*args))            # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters)
    return best * 1e3


def _plan(seq: int, hd: int, window: int) -> autotune.AttnPlan:
    """The plan the kernel will choose for this row (for reporting)."""
    backend = "interpret" if jax.default_backend() != "tpu" else "tpu"
    return autotune.plan_attention(seq, seq, hd, hd, H // KH, KH, B, 32,
                                   True, window, seq, backend=backend)


def _bench(seq: int, hd: int, window: int):
    q, k, v = _data(seq, hd)
    # shrink the timing effort as the per-call cost grows
    reps, iters = (3, 2) if seq <= 1024 else (2, 1)

    fwd_pallas = jax.jit(functools.partial(
        kops.flash_attention, causal=True, window=window))

    def fwd_jnp(q_, k_, v_):
        return flash_attention_jnp(q_, k_, v_, jnp.zeros((), jnp.float32),
                                   True, window)

    grad_pallas = jax.jit(jax.grad(
        lambda q_, k_, v_: jnp.sum(fwd_pallas(q_, k_, v_)),
        argnums=(0, 1, 2)))
    grad_jnp = jax.jit(jax.grad(
        lambda q_, k_, v_: jnp.sum(fwd_jnp(q_, k_, v_)),
        argnums=(0, 1, 2)))

    return {
        "fwd_pallas_ms": _time_ms(fwd_pallas, q, k, v,
                                  reps=reps, iters=iters),
        "fwd_jnp_ms": _time_ms(jax.jit(fwd_jnp), q, k, v,
                               reps=reps, iters=iters),
        "bwd_pallas_ms": _time_ms(grad_pallas, q, k, v,
                                  reps=reps, iters=iters),
        "bwd_jnp_ms": _time_ms(grad_jnp, q, k, v, reps=reps, iters=iters),
    }


_CACHE = {}


def _results():
    if not _CACHE:
        t0 = time.perf_counter()
        for seq in SEQS:
            for hd in HEAD_DIMS:
                for variant, w in (("causal", 0), ("window", WINDOW)):
                    _CACHE[(seq, hd, variant)] = _bench(seq, hd, w)
        _CACHE["wall_time_s"] = time.perf_counter() - t0
    return _CACHE


def _rows():
    res = _results()
    for seq in SEQS:
        for hd in HEAD_DIMS:
            for variant, w in (("causal", 0), ("window", WINDOW)):
                yield seq, hd, variant, w, res[(seq, hd, variant)]


def run():
    rows = []
    mode = "interpret" if jax.default_backend() != "tpu" else "compiled"
    for seq, hd, variant, w, r in _rows():
        blocks = _plan(seq, hd, w).describe()
        for key, ms in r.items():
            rows.append((f"flash.s{seq}_hd{hd}_{variant}_{key[:-3]}",
                         f"{ms * 1e3:.0f}",
                         f"{mode}; B={B} H={H}/{KH} window={w}; {blocks}"))
    return rows


def summary():
    """Machine-readable snapshot for BENCH_flash.json (perf trajectory)."""
    res = _results()
    out = {"heads": H, "kv_heads": KH, "window": WINDOW,
           "wall_time_s": res["wall_time_s"]}
    for seq, hd, variant, w, r in _rows():
        prefix = f"s{seq}_hd{hd}_{variant}"
        out[f"{prefix}_blocks"] = _plan(seq, hd, w).describe()
        for key, ms in r.items():
            out[f"{prefix}_{key}"] = ms
    return out


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
