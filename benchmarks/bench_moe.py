"""MoE dispatch: capacity-bucketed all-to-all vs replicate-and-psum.

Compares the two EP combines of ``repro.models.moe`` at expert counts
E ∈ {8, 64, 128} on 8 forced host devices (mesh 1×8, tokens/experts over
"model").  Per cell: collective traffic parsed out of the compiled SPMD
HLO by ``repro.launch.hlo_cost`` (per-device operand bytes for one
fwd+bwd step — deterministic, noise-free) and wall step time.

The point of the a2a path: its exchange moves ``2·E·C·D`` bucket bytes
per device regardless of the model-axis width, while the psum combine
moves the *full* (T, D) token block per psum — so the byte gap widens
with E (capacity C shrinks as 1/E while the psum stays fixed).  The
acceptance line, asserted in CI via BENCH_moe.json + bench_diff's
``*_bytes`` lower-is-better rule: strictly fewer bytes than psum at
E ≥ 64, no step-time regression at E = 8.

Cells run in subprocesses (XLA_FLAGS must be set before jax imports),
cached so ``run()`` and ``summary()`` compile each once.
"""
import json
import os
import subprocess
import sys
import textwrap

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")

EXPERT_COUNTS = (8, 64, 128)
_cache = {}


def _cell(num_experts: int, dispatch: str, steps: int = 5):
    """One (E, dispatch) cell: HLO collective bytes + wall step time."""
    key = (num_experts, dispatch)
    if key in _cache:
        return _cache[key]
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            f"import sys\nsys.path.insert(0, {_SRC!r})\n"
            + textwrap.dedent(f"""
        import dataclasses, json, time
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.dist.sharding import use_mesh
        from repro.launch import hlo_cost
        from repro.models import moe as M

        cfg = get_config("deepseek-v2-236b").reduced()
        cfg = dataclasses.replace(
            cfg, num_experts={num_experts}, experts_per_token=2,
            capacity_factor=1.25, num_shared_experts=0,
            moe_dispatch="{dispatch}")
        params = M.moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 128, cfg.d_model))

        def loss(p, xx):
            y, aux = M.moe_ffn(p, xx, cfg)
            return jnp.sum(y ** 2) + 0.01 * aux["loss"], aux

        mesh = jax.make_mesh((1, 8), ("data", "model"))
        with use_mesh(mesh):
            fn = jax.jit(jax.value_and_grad(loss, has_aux=True))
            lowered = fn.lower(params, x)
            compiled = lowered.compile()
            cost = hlo_cost.analyze(compiled.as_text())
            (l0, aux), g = compiled(params, x)
            jax.block_until_ready(g)                 # compile + warm
            t0 = time.perf_counter()
            for _ in range({steps}):
                (l0, aux), g = compiled(params, x)
            jax.block_until_ready(g)
        dt = (time.perf_counter() - t0) / {steps}
        print(json.dumps({{
            "step_ms": dt * 1e3,
            "coll_bytes": cost.coll_total,
            "per_kind": {{k: v for k, v in cost.coll_bytes.items() if v}},
            "dropped": float(aux["dropped"]),
            "overflow_rate": float(aux["dropped"])
                             / max(float(aux["routed"]), 1.0),
            "a2a_bytes_gauge": float(aux["a2a_bytes"]),
        }}))
    """))
    out = None
    try:
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=560)
        rec = json.loads(out.stdout.strip().splitlines()[-1])
    except Exception as e:
        rec = {"error": f"{type(e).__name__}: {e}"}
        if out is not None and out.returncode != 0:
            rec["error"] = (f"exit={out.returncode}: "
                            + out.stderr.strip()[-500:].replace("\n", " | "))
    _cache[key] = rec
    return rec


def run():
    rows = []
    for e in EXPERT_COUNTS:
        for dispatch in ("a2a", "psum"):
            rec = _cell(e, dispatch)
            name = f"moe.step_E{e}_{dispatch}_8dev"
            if "error" in rec:
                rows.append((name + ".SKIP", "0", rec["error"]))
                continue
            kinds = ";".join(f"{k}={v:.0f}"
                             for k, v in sorted(rec["per_kind"].items()))
            rows.append((name, f"{rec['step_ms'] * 1e3:.0f}",
                         f"coll_bytes={rec['coll_bytes']:.0f};"
                         f"dropped={rec['dropped']:.0f};{kinds}"))
    return rows


def summary():
    """BENCH_moe.json: per-E bytes for both dispatches + the ratios the
    acceptance line and bench_diff's ``*_bytes`` rule watch."""
    out = {}
    for e in EXPERT_COUNTS:
        a2a, psum = _cell(e, "a2a"), _cell(e, "psum")
        if "error" in a2a or "error" in psum:
            out[f"E{e}_error"] = a2a.get("error") or psum.get("error")
            continue
        out[f"a2a_coll_bytes_E{e}"] = a2a["coll_bytes"]
        out[f"psum_coll_bytes_E{e}"] = psum["coll_bytes"]
        out[f"a2a_step_ms_E{e}"] = a2a["step_ms"]
        out[f"psum_step_ms_E{e}"] = psum["step_ms"]
        out[f"bytes_ratio_a2a_over_psum_E{e}"] = (
            a2a["coll_bytes"] / max(psum["coll_bytes"], 1.0))
        out[f"overflow_rate_E{e}"] = a2a["overflow_rate"]
        out[f"a2a_bytes_gauge_E{e}"] = a2a["a2a_bytes_gauge"]
    return out
