"""Benchmark driver: one section per paper extension + roofline + steps.

Prints ``name,us_per_call,derived`` CSV.  §3/§4/§6 makespans are in
deterministic virtual time (noise-free); file IO does real disk IO; the
roofline section reads the AOT dry-run artifact.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import (bench_fileio, bench_lid, bench_map,
                            bench_partition, bench_roofline, bench_train)
    print("name,us_per_call,derived")
    for mod in (bench_lid, bench_map, bench_fileio, bench_partition,
                bench_train, bench_roofline):
        for name, us, derived in mod.run():
            print(f"{name},{us},{derived}")


if __name__ == "__main__":
    main()
