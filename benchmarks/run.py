"""Benchmark driver: one section per paper extension + roofline + steps.

Prints ``name,us_per_call,derived`` CSV.  §3/§4/§6 makespans are in
deterministic virtual time (noise-free); file IO does real disk IO; the
roofline section reads the AOT dry-run artifact.

Modules exposing ``summary()`` also emit a machine-readable
``BENCH_<name>.json`` (makespan, messages_sent, wall-time, counters) into
``$BENCH_JSON_DIR`` (default: cwd) so the perf trajectory is tracked
across PRs.
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


_SECTIONS = ("bench_lid", "bench_map", "bench_guidtable", "bench_fileio",
             "bench_partition", "bench_contention", "bench_serve",
             "bench_flash", "bench_moe", "bench_train", "bench_roofline")


def main() -> None:
    import importlib

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--sections", default=None, metavar="NAME[,NAME...]",
        help="comma-separated subset to run (short names, e.g. "
             "'partition,contention'); default: all")
    opts = ap.parse_args()
    sections = _SECTIONS
    if opts.sections is not None:
        wanted = [s.strip() for s in opts.sections.split(",") if s.strip()]
        unknown = [s for s in wanted
                   if f"bench_{s}" not in _SECTIONS and s not in _SECTIONS]
        if unknown:
            ap.error(f"unknown section(s) {unknown}; choose from "
                     f"{[s[len('bench_'):] for s in _SECTIONS]}")
        sections = tuple(s if s in _SECTIONS else f"bench_{s}"
                         for s in wanted)

    mods = []
    print("name,us_per_call,derived")
    for name in sections:
        # a section with missing deps (e.g. an optional subsystem) reports
        # and is skipped instead of killing the whole driver
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
        except Exception as e:
            print(f"{name}.SKIP,0,import_error={type(e).__name__}: {e}")
            continue
        mods.append(mod)
        for row_name, us, derived in mod.run():
            print(f"{row_name},{us},{derived}")

    out_dir = os.environ.get("BENCH_JSON_DIR", ".")
    for mod in mods:
        summary = getattr(mod, "summary", None)
        if summary is None:
            continue
        name = mod.__name__.rsplit("bench_", 1)[-1]
        path = os.path.join(out_dir, f"BENCH_{name}.json")
        with open(path, "w") as f:
            json.dump(summary(), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
