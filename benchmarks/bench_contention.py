"""Lock-contention microbenchmark: W tasks serialize in RW on one block.

The seed scheduler kept one global waiter list and re-ran ``_try_grant``
(including the §6.2 ancestor walk) for *every* waiter on *every* release —
W·(W+1)/2 retries for W waiters.  The indexed scheduler parks waiters on a
per-DB FIFO queue and wakes only the head until someone re-blocks, so a
release costs O(1) retries; ``Stats.waiter_wakeups`` makes the difference
observable (and regressions visible) without profiling.
"""
import time

from repro.core import DbMode, NULL_GUID, Runtime, spawn_main


def _contend(num_waiters: int, mode: DbMode = DbMode.RW, duration: float = 1.0):
    rt = Runtime(num_nodes=1)

    def w(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(64)
        api.db_release(db)
        tmpl = api.edt_template_create(w, 0, 1)
        for _ in range(num_waiters):
            api.edt_create(tmpl, depv=[db], dep_modes=[mode],
                           duration=duration)
        return NULL_GUID

    spawn_main(rt, main)
    t0 = time.perf_counter()
    stats = rt.run()
    return stats, time.perf_counter() - t0


def run():
    rows = []
    for w in (64, 256):
        stats, wall = _contend(w)
        naive = w * (w + 1) // 2          # seed: every release retried all
        rows.append((
            f"contention.rw_w{w}", f"{wall / w * 1e6:.1f}",
            f"waiter_wakeups={stats.waiter_wakeups};naive_retries={naive};"
            f"reduction={naive / max(1, stats.waiter_wakeups):.0f}x;"
            f"makespan={stats.makespan:.0f}"))
    return rows


def summary():
    """Machine-readable snapshot for BENCH_contention.json."""
    stats, wall = _contend(256)
    return {
        "n_waiters": 256,
        "makespan": stats.makespan,
        "messages_sent": stats.messages_sent,
        "waiter_wakeups": stats.waiter_wakeups,
        "naive_retries": 256 * 257 // 2,
        "wall_time_s": wall,
    }


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
