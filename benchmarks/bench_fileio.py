"""§5 — file IO: chunked parallel read/modify/write vs whole-file, and
dirty-only checkpoint write-back."""
import os
import tempfile
import time

import numpy as np

from repro.core import DbMode, NULL_GUID, Runtime, spawn_main


def _rmw(path: str, nbytes: int, chunks: int, writers: int):
    """Read-modify-write the file through `chunks` §5 chunk data blocks."""
    rt = Runtime(num_nodes=writers, io_latency=2.0)
    per = nbytes // chunks

    def work(paramv, depv, api):
        arr = depv[0].ptr.view(np.uint32)
        arr *= 3
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb+")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            tmpl2 = api2.edt_template_create(work, 0, 1)
            for c in range(chunks):
                ch = api2.file_get_chunk(fg, c * per, per)
                api2.edt_create(tmpl2, depv=[ch], dep_modes=[DbMode.EW],
                                placement=c % writers, duration=4.0)
            api2.file_release(fg)
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    return rt.run()


def run():
    rows = []
    nbytes = 1 << 20
    for chunks, writers in ((1, 1), (4, 4), (16, 4), (64, 8)):
        path = tempfile.mktemp()
        np.arange(nbytes // 4, dtype=np.uint32).tofile(path)
        t0 = time.perf_counter()
        stats = _rmw(path, nbytes, chunks, writers)
        us = (time.perf_counter() - t0) / chunks * 1e6
        ok = np.array_equal(np.fromfile(path, np.uint32),
                            np.arange(nbytes // 4, dtype=np.uint32) * 3)
        os.unlink(path)
        rows.append((
            f"fileio.rmw_c{chunks}_w{writers}", f"{us:.0f}",
            f"makespan={stats.makespan:.0f};bytes_rw={stats.file_bytes_read}"
            f"+{stats.file_bytes_written};correct={ok}"))

    # dirty-only checkpoint write-back (§5 dirty tracking)
    from repro import ckpt
    import shutil
    tmp = tempfile.mkdtemp()
    rng = np.random.default_rng(0)
    tree = {"a": rng.normal(size=(256, 256)).astype(np.float32),
            "b": rng.normal(size=(64, 4096)).astype(np.float32)}
    t0 = time.perf_counter()
    s1 = ckpt.save(tmp, tree, 1, chunk_bytes=1 << 14)
    tree["a"][3, :8] = 0  # touch one chunk
    s2 = ckpt.save(tmp, tree, 2, chunk_bytes=1 << 14)
    us = (time.perf_counter() - t0) / 2 * 1e6
    shutil.rmtree(tmp)
    rows.append((
        "fileio.ckpt_dirty_skip", f"{us:.0f}",
        f"full={s1.chunks_written}/{s1.chunks_total};"
        f"delta={s2.chunks_written}/{s2.chunks_total}"))
    return rows
