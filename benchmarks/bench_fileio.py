"""§5 — file IO: async IO-queue overlap vs the synchronous baseline,
chunked parallel read/modify/write, write-back coalescing, dirty-only
checkpoint write-back, and the §6-sharded checkpoint path."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap
import time

import numpy as np

from repro.core import DbMode, NULL_GUID, Runtime, spawn_main

_SRC = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "src")


def _rmw(path: str, nbytes: int, chunks: int, writers: int,
         io_mode: str = "async"):
    """Read-modify-write the file through `chunks` §5 chunk data blocks."""
    rt = Runtime(num_nodes=writers, io_latency=2.0, io_mode=io_mode)
    per = nbytes // chunks

    def work(paramv, depv, api):
        arr = depv[0].ptr.view(np.uint32)
        arr *= 3
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb+")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            tmpl2 = api2.edt_template_create(work, 0, 1)
            for c in range(chunks):
                ch = api2.file_get_chunk(fg, c * per, per)
                api2.edt_create(tmpl2, depv=[ch], dep_modes=[DbMode.EW],
                                placement=c % writers, duration=4.0)
            api2.file_release(fg)
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    return rt.run()


def _scan(io_mode: str, chunks: int = 32, io_latency: float = 2.0,
          duration: float = 3.0):
    """Read-heavy chained scan: task *i* consumes chunk *i*, feeds *i+1*.

    The §5 overlap shape: with the async IO queue, read-ahead streams
    chunk i+1..n while task i computes; the sync baseline pays
    (read + compute) serially per link.
    """
    path = tempfile.mktemp()
    nbytes = 1 << 15
    np.arange(nbytes // 4, dtype=np.uint32).tofile(path)
    rt = Runtime(num_nodes=2, io_latency=io_latency, io_mode=io_mode)
    per = nbytes // chunks
    acc = {"v": 0}

    def work(paramv, depv, api):
        acc["v"] += int(depv[0].ptr.view(np.uint32).sum())
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            tmpl2 = api2.edt_template_create(work, 0, 2)
            prev = None
            for c in range(chunks):
                ch = api2.file_get_chunk(fg, c * per, per)
                depv2 = [ch, prev if prev is not None else NULL_GUID]
                _, ev = api2.edt_create(
                    tmpl2, depv=depv2, dep_modes=[DbMode.RO, DbMode.NULL],
                    duration=duration, output_event=True)
                prev = ev
            api2.file_release(fg)
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    os.unlink(path)
    expect = int(np.arange(nbytes // 4, dtype=np.uint64).sum())
    return stats, acc["v"] == expect


_sharded_cache = {}


def _sharded_ckpt():
    """§6-sharded checkpoint on 8 forced host devices (subprocess: the
    XLA device-count flag must be set before any jax import).  Saves a
    NamedSharding tree (no host gather), restores under a 2-device mesh,
    and verifies bit-exactness through the range manifest."""
    if _sharded_cache:
        return _sharded_cache["rec"]
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            f"import sys\nsys.path.insert(0, {_SRC!r})\n"
            + textwrap.dedent("""
        import json, tempfile, shutil, time
        import numpy as np
        import jax
        from jax.sharding import Mesh
        from repro import ckpt
        from repro.dist.sharding import ShardCtx, param_shardings

        rng = np.random.default_rng(0)
        tree = {"params": {
            "w_q": rng.normal(size=(64, 8, 16)).astype(np.float32),
            "w_down": rng.normal(size=(256, 64)).astype(np.float32),
            "embedding": rng.normal(size=(128, 64)).astype(np.float32)}}
        shapes = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        mesh8 = Mesh(np.array(jax.devices()).reshape(2, 4),
                     ("data", "model"))
        sh8 = param_shardings(shapes, ShardCtx(mesh=mesh8))
        dev = jax.tree_util.tree_map(jax.device_put, tree, sh8)
        tmp = tempfile.mkdtemp()
        t0 = time.perf_counter()
        st = ckpt.save(tmp, dev, 1, num_writers=8)
        wall_ms = (time.perf_counter() - t0) * 1e3
        mesh2 = Mesh(np.array(jax.devices()[:2]).reshape(1, 2),
                     ("data", "model"))
        sh2 = param_shardings(shapes, ShardCtx(mesh=mesh2))
        got, _ = ckpt.restore(tmp, shardings=sh2)
        exact = all(
            np.array_equal(tree["params"][k], np.asarray(got["params"][k]))
            for k in tree["params"])
        shutil.rmtree(tmp)
        print(json.dumps({
            "host_gathers": st.host_gathers, "ranges": st.chunks_total,
            "io_write_ops": st.io_write_ops,
            "io_coalesced_writes": st.io_coalesced_writes,
            "makespan": st.makespan, "wall_ms": wall_ms,
            "reshard_exact": bool(exact)}))
    """))
    out = None
    try:
        out = subprocess.run([sys.executable, "-c", code],
                             capture_output=True, text=True, timeout=560)
        rec = json.loads(out.stdout.strip().splitlines()[-1])
    except Exception as e:
        rec = {"error": f"{type(e).__name__}: {e}"}
        if out is not None and out.returncode != 0:
            rec["error"] = (f"exit={out.returncode}: "
                            + out.stderr.strip()[-500:].replace("\n", " | "))
    _sharded_cache["rec"] = rec
    return rec


def _ckpt_dirty():
    from repro import ckpt
    import shutil
    tmp = tempfile.mkdtemp()
    rng = np.random.default_rng(0)
    tree = {"a": rng.normal(size=(256, 256)).astype(np.float32),
            "b": rng.normal(size=(64, 4096)).astype(np.float32)}
    t0 = time.perf_counter()
    s1 = ckpt.save(tmp, tree, 1, chunk_bytes=1 << 14)
    tree["a"][3, :8] = 0  # touch one chunk
    s2 = ckpt.save(tmp, tree, 2, chunk_bytes=1 << 14)
    us = (time.perf_counter() - t0) / 2 * 1e6
    shutil.rmtree(tmp)
    return s1, s2, us


def run():
    rows = []
    nbytes = 1 << 20
    for chunks, writers in ((1, 1), (4, 4), (16, 4), (64, 8)):
        path = tempfile.mktemp()
        np.arange(nbytes // 4, dtype=np.uint32).tofile(path)
        t0 = time.perf_counter()
        stats = _rmw(path, nbytes, chunks, writers)
        us = (time.perf_counter() - t0) / chunks * 1e6
        ok = np.array_equal(np.fromfile(path, np.uint32),
                            np.arange(nbytes // 4, dtype=np.uint32) * 3)
        os.unlink(path)
        rows.append((
            f"fileio.rmw_c{chunks}_w{writers}", f"{us:.0f}",
            f"makespan={stats.makespan:.0f};bytes_rw={stats.file_bytes_read}"
            f"+{stats.file_bytes_written};correct={ok}"))

    # async IO queue vs synchronous baseline on the read-heavy scan
    for mode in ("sync", "async"):
        t0 = time.perf_counter()
        stats, ok = _scan(mode)
        us = (time.perf_counter() - t0) * 1e6 / 32
        overlap = stats.io_overlap_ticks / stats.makespan if stats.makespan \
            else 0.0
        rows.append((
            f"fileio.scan_{mode}", f"{us:.0f}",
            f"makespan={stats.makespan:.0f};overlap_ratio={overlap:.2f};"
            f"reads_inflight_max={stats.io_reads_inflight_max};"
            f"correct={ok}"))

    # dirty-only checkpoint write-back (§5) + write coalescing
    s1, s2, us = _ckpt_dirty()
    rows.append((
        "fileio.ckpt_dirty_skip", f"{us:.0f}",
        f"full={s1.chunks_written}/{s1.chunks_total};"
        f"delta={s2.chunks_written}/{s2.chunks_total};"
        f"coalesced={s1.io_coalesced_writes};write_ops={s1.io_write_ops}"))

    # §6-sharded checkpoint: no host gather, reshard-on-restore bit-exact
    sh = _sharded_ckpt()
    if "error" not in sh:
        rows.append((
            "fileio.ckpt_sharded_8dev", f"{sh['wall_ms'] * 1e3:.0f}",
            f"host_gathers={sh['host_gathers']};ranges={sh['ranges']};"
            f"write_ops={sh['io_write_ops']};"
            f"makespan={sh['makespan']:.0f};"
            f"reshard_exact={sh['reshard_exact']}"))
    else:
        rows.append(("fileio.ckpt_sharded_8dev.SKIP", "0", sh["error"]))
    return rows


def summary():
    """Machine-readable snapshot for BENCH_fileio.json (perf trajectory)."""
    t0 = time.perf_counter()
    sync_stats, _ = _scan("sync")
    async_stats, _ = _scan("async")
    s1, s2, _us = _ckpt_dirty()
    sh = _sharded_ckpt()
    wall = time.perf_counter() - t0
    return {
        "makespan_scan_sync": sync_stats.makespan,
        "makespan_scan_async": async_stats.makespan,
        "scan_overlap_ratio_async": (async_stats.io_overlap_ticks
                                     / async_stats.makespan),
        "scan_reads_inflight_max_async": async_stats.io_reads_inflight_max,
        "ckpt_write_ops": s1.io_write_ops,
        "ckpt_coalesced_writes": s1.io_coalesced_writes,
        "ckpt_delta_chunks_written": s2.chunks_written,
        "sharded_host_gathers": sh.get("host_gathers", -1),
        "sharded_ranges": sh.get("ranges", 0),
        "sharded_reshard_exact": int(bool(sh.get("reshard_exact", False))),
        "makespan_ckpt_sharded": sh.get("makespan", -1.0),
        "wall_time_s": wall,
    }
