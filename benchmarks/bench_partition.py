"""§6 — data block partitioning: EW-partition parallelism vs whole-block RW
serialization, and §6.3 zero-copy vs materialized copies."""
import time

import numpy as np

from repro.core import (DB_COPY_PARTITION, DB_COPY_PARTITION_BACK,
                        DB_PROP_NO_ACQUIRE, DbMode, NULL_GUID, Runtime,
                        spawn_main)


def _makespan(num_tasks: int, partitioned: bool, duration: float = 10.0):
    rt = Runtime(num_nodes=max(4, num_tasks))
    size = 1024 * num_tasks

    def w(paramv, depv, api):
        depv[0].ptr.view(np.uint32)[:] += 1
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def w_whole(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(size)
        api.db_release(db)
        if partitioned:
            parts = api.db_partition(
                db, [(i * 1024, 1024) for i in range(num_tasks)])
            tmpl = api.edt_template_create(w, 0, 1)
            for i, p in enumerate(parts):
                api.edt_create(tmpl, depv=[p], dep_modes=[DbMode.EW],
                               duration=duration, placement=i % rt.num_nodes)
        else:
            tmpl = api.edt_template_create(w_whole, 0, 1)
            for i in range(num_tasks):
                api.edt_create(tmpl, depv=[db], dep_modes=[DbMode.RW],
                               duration=duration, placement=i % rt.num_nodes)
        return NULL_GUID

    spawn_main(rt, main)
    return rt.run()


def _copy_modes(size: int):
    """§6.3: DB_COPY_PARTITION zero-copy vs plain materialized copy."""
    rt = Runtime()

    def main(paramv, depv, api):
        block, ptr = api.db_create(size)
        ptr[:] = 1
        api.db_release(block)
        half = size // 2
        zc, _ = api.db_create(half, props=DB_PROP_NO_ACQUIRE)
        api.db_copy(zc, 0, block, 0, half, DB_COPY_PARTITION)
        cp, _ = api.db_create(half)
        api.db_copy(cp, 0, block, half, half)    # plain copy
        return NULL_GUID

    spawn_main(rt, main)
    return rt.run()


def _fused_copy_scatter(num_parts: int, use_pallas: bool):
    """§6.3 partition-set materialization: ``num_parts`` disjoint ranges
    copied from one block into a shadow block, batched per virtual
    timestamp — one fused kernel launch (or numpy loop) per flush."""
    rt = Runtime(copy_backend="pallas" if use_pallas else "numpy")
    psize = 1024          # 128-byte aligned, NOT 32 KiB aligned
    size = psize * num_parts

    def main(paramv, depv, api):
        block, ptr = api.db_create(size)
        ptr[:] = 7
        api.db_release(block)
        shadow, _ = api.db_create(size)
        api.db_release(shadow)
        for i in range(num_parts):
            api.db_copy(shadow, i * psize, block, i * psize, psize)
        return NULL_GUID

    spawn_main(rt, main)
    return rt.run()


def run():
    rows = []
    for n in (2, 8, 32, 64):
        t0 = time.perf_counter()
        rw = _makespan(n, partitioned=False)
        ew = _makespan(n, partitioned=True)
        us = (time.perf_counter() - t0) / (2 * n) * 1e6
        rows.append((
            f"partition.par_n{n}", f"{us:.0f}",
            f"makespan_RW={rw.makespan:.0f};makespan_EW={ew.makespan:.0f};"
            f"speedup={rw.makespan / ew.makespan:.1f}x;"
            f"waiter_wakeups={rw.waiter_wakeups}"))
    for size in (1 << 16, 1 << 22):
        t0 = time.perf_counter()
        stats = _copy_modes(size)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"partition.copy_{size >> 10}k", f"{us:.0f}",
            f"zero_copy={stats.bytes_zero_copy};copied={stats.bytes_copied}"))

    # the TPU-kernel fallback path (§6.3 on-device copy)
    import jax.numpy as jnp
    from repro.kernels import ops
    blk = 256 * 128
    dst = jnp.zeros((8 * blk,), jnp.uint8)
    src = jnp.ones((8 * blk,), jnp.uint8)
    t0 = time.perf_counter()
    out = ops.partition_copy_bytes(dst, src, dst_off=0, src_off=blk,
                                   size=2 * blk, interpret=True)
    out.block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("partition.kernel_copy_64k", f"{us:.0f}",
                 "pallas interpret; 2 tiles"))

    # fused multi-range copy: N ragged (non-32KiB) ranges, one pallas_call
    ranges = tuple((i * 4096, i * 4096, 3 * 128) for i in range(64))
    dst = jnp.zeros((64 * 4096,), jnp.uint8)
    src = (jnp.arange(64 * 4096) % 251).astype(jnp.uint8)
    t0 = time.perf_counter()
    out = ops.multi_partition_copy_bytes(dst, src, ranges, interpret=True)
    out.block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("partition.fused_copy_64r", f"{us:.0f}",
                 "64 lane-aligned ranges in one pallas_call"))

    for backend, flag in (("numpy", False), ("pallas", True)):
        t0 = time.perf_counter()
        st = _fused_copy_scatter(64, use_pallas=flag)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"partition.batch_copy_{backend}", f"{us:.0f}",
                     f"copied={st.bytes_copied};fused={st.fused_copies};"
                     f"makespan={st.makespan:.0f}"))
    return rows


def summary():
    """Machine-readable snapshot for BENCH_partition.json (perf trajectory)."""
    t0 = time.perf_counter()
    rw = _makespan(64, partitioned=False)
    ew = _makespan(64, partitioned=True)
    wall = time.perf_counter() - t0
    return {
        "n_tasks": 64,
        "makespan_rw": rw.makespan,
        "makespan_ew": ew.makespan,
        "messages_sent": rw.messages_sent + ew.messages_sent,
        "waiter_wakeups": rw.waiter_wakeups + ew.waiter_wakeups,
        "wall_time_s": wall,
    }


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
