"""§6 — data block partitioning: EW-partition parallelism vs whole-block RW
serialization, and §6.3 zero-copy vs materialized copies."""
import time

import numpy as np

from repro.core import (DB_COPY_PARTITION, DB_COPY_PARTITION_BACK,
                        DB_PROP_NO_ACQUIRE, DbMode, NULL_GUID, Runtime,
                        spawn_main)


def _makespan(num_tasks: int, partitioned: bool, duration: float = 10.0):
    rt = Runtime(num_nodes=max(4, num_tasks))
    size = 1024 * num_tasks

    def w(paramv, depv, api):
        depv[0].ptr.view(np.uint32)[:] += 1
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def w_whole(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(size)
        api.db_release(db)
        if partitioned:
            parts = api.db_partition(
                db, [(i * 1024, 1024) for i in range(num_tasks)])
            tmpl = api.edt_template_create(w, 0, 1)
            for i, p in enumerate(parts):
                api.edt_create(tmpl, depv=[p], dep_modes=[DbMode.EW],
                               duration=duration, placement=i % rt.num_nodes)
        else:
            tmpl = api.edt_template_create(w_whole, 0, 1)
            for i in range(num_tasks):
                api.edt_create(tmpl, depv=[db], dep_modes=[DbMode.RW],
                               duration=duration, placement=i % rt.num_nodes)
        return NULL_GUID

    spawn_main(rt, main)
    return rt.run()


def _copy_modes(size: int):
    """§6.3: DB_COPY_PARTITION zero-copy vs plain materialized copy."""
    rt = Runtime()

    def main(paramv, depv, api):
        block, ptr = api.db_create(size)
        ptr[:] = 1
        api.db_release(block)
        half = size // 2
        zc, _ = api.db_create(half, props=DB_PROP_NO_ACQUIRE)
        api.db_copy(zc, 0, block, 0, half, DB_COPY_PARTITION)
        cp, _ = api.db_create(half)
        api.db_copy(cp, 0, block, half, half)    # plain copy
        return NULL_GUID

    spawn_main(rt, main)
    return rt.run()


def run():
    rows = []
    for n in (2, 8, 32):
        t0 = time.perf_counter()
        rw = _makespan(n, partitioned=False)
        ew = _makespan(n, partitioned=True)
        us = (time.perf_counter() - t0) / (2 * n) * 1e6
        rows.append((
            f"partition.par_n{n}", f"{us:.0f}",
            f"makespan_RW={rw.makespan:.0f};makespan_EW={ew.makespan:.0f};"
            f"speedup={rw.makespan / ew.makespan:.1f}x"))
    for size in (1 << 16, 1 << 22):
        t0 = time.perf_counter()
        stats = _copy_modes(size)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"partition.copy_{size >> 10}k", f"{us:.0f}",
            f"zero_copy={stats.bytes_zero_copy};copied={stats.bytes_copied}"))

    # the TPU-kernel fallback path (§6.3 on-device copy)
    import jax.numpy as jnp
    from repro.kernels import ops
    blk = 256 * 128
    dst = jnp.zeros((8 * blk,), jnp.uint8)
    src = jnp.ones((8 * blk,), jnp.uint8)
    t0 = time.perf_counter()
    out = ops.partition_copy_bytes(dst, src, dst_off=0, src_off=blk,
                                   size=2 * blk, interpret=True)
    out.block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("partition.kernel_copy_64k", f"{us:.0f}",
                 "pallas interpret; 2 tiles"))
    return rows
