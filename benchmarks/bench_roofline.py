"""Roofline table from the dry-run artifact (results/dryrun.json).

Rows: one per (arch × shape × mesh) cell with the three terms in seconds,
the dominant bottleneck, and MODEL_FLOPS/HLO_FLOPS.  Run the dry-run first:
``PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]``.
"""
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results",
                       "dryrun.json")


def run():
    rows = []
    if not os.path.exists(RESULTS):
        return [("roofline.missing", "0", "run repro.launch.dryrun first")]
    with open(RESULTS) as f:
        data = json.load(f)
    for key in sorted(data["cells"]):
        v = data["cells"][key]
        if v["status"] != "ok":
            continue
        r = v["roofline"]
        name = "roofline." + key.replace("|", ".")
        dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        us = dom_s * 1e6
        rows.append((
            name, f"{us:.0f}",
            f"compute={r['compute_s']:.4f}s;memory={r['memory_s']:.4f}s;"
            f"collective={r['collective_s']:.4f}s;dominant={r['dominant']};"
            f"useful_ratio={r['useful_ratio']:.2f};"
            f"tempGB={v['memory']['temp_size_in_bytes'] / 1e9:.1f}"))
    return rows
