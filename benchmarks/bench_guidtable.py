"""Sharded per-node GUID tables vs the flat-dict baseline (§2 storage).

The paper's GUIDs carry ``(node, seq, kind)`` so the runtime can exploit
creation-time structure; ``repro.core.objects.ObjectTable`` exploits it on
the storage side — kind + seq-range shard routing is O(1) arithmetic on
int keys, where a flat ``Dict[Guid, Any]`` hashes the triple and pays a
Python-level ``Guid.__eq__`` on every probe of a *message-decoded*
identifier (equal but not identical — the norm in a distributed runtime,
where guids arrive over the wire).  Probes here are therefore freshly
constructed Guids for both rows.

Rows at 10⁴–10⁶ live objects:

* ``create_nN`` — insert throughput (sharded routing is pure overhead
  here, so the flat dict wins this row; the ratio shows the cost paid).
* ``lookup_hot_nN`` — a 4 K hot working set probed over the full cold
  table: the regime the ROADMAP's "millions of live objects" scenarios
  live in.  Hot shards stay small and cache-resident.
* ``lookup_cold_nN`` — uniform shuffled probes over everything.
* ``destroy_nN`` — pop in creation order (how retirement actually
  arrives: EDTs retire roughly in creation order; map/file populations
  retire in bulk).
* ``failstop_nN`` — dropping the whole table (the `kill_node` path):
  O(shards) clear vs per-key deletion of the flat dict.
* ``spill_rt`` — end-to-end `Runtime(spill_threshold=…)` scenario in
  deterministic virtual time (makespan + spilled counts) so the spill
  path has a perf-trajectory row.

`summary()` emits BENCH_guidtable.json for scripts/bench_diff.py.
"""
import time

from repro.core import (DbMode, Guid, NULL_GUID, ObjectKind, ObjectTable,
                        Runtime, spawn_main)

_DB = ObjectKind.DATABLOCK


class _Obj:
    __slots__ = ("guid",)

    def __init__(self, g):
        self.guid = g


class _FlatTable:
    """The seed's layout: one flat Guid-keyed dict per node."""

    __slots__ = ("_objs",)

    def __init__(self):
        self._objs = {}

    def insert(self, obj):
        self._objs[obj.guid] = obj

    def get(self, gid, default=None):
        return self._objs.get(gid, default)

    def pop(self, gid, default=None):
        return self._objs.pop(gid, default)

    def clear(self):
        self._objs.clear()


def _guids(n):
    return [Guid(0, i, _DB) for i in range(1, n + 1)]


def _probes(n, hot=None, shuffle=True):
    """Freshly constructed (message-decoded) probe guids: lookups *and*
    destroys arrive over the wire (MDep/MSatisfy/MDestroy), so probes are
    equal-but-not-identical to the stored keys for both table layouts."""
    import random
    lo = 1 if hot is None else n - hot + 1
    out = [Guid(0, i, _DB) for i in range(lo, n + 1)]
    if shuffle:
        random.Random(0).shuffle(out)
    return out


def _best(fn, reps=3):
    return min(fn() for _ in range(reps))


def _populate(table_cls, objs):
    t = table_cls()
    ins = t.insert
    for o in objs:
        ins(o)
    return t


def _time_create(table_cls, objs):
    def run():
        t0 = time.perf_counter()
        _populate(table_cls, objs)
        return time.perf_counter() - t0
    return _best(run)


def _time_lookup(table, probes, reps=1):
    get = table.get

    def run():
        t0 = time.perf_counter()
        for _ in range(reps):
            for g in probes:
                get(g)
        return time.perf_counter() - t0
    return _best(run)


def _time_destroy(table_cls, objs, probes):
    def run():
        t = _populate(table_cls, objs)
        pop = t.pop
        t0 = time.perf_counter()
        for g in probes:
            pop(g)
        return time.perf_counter() - t0
    return _best(run)


def _time_failstop(table_cls, objs):
    def run():
        t = _populate(table_cls, objs)
        t0 = time.perf_counter()
        t.clear()
        return time.perf_counter() - t0
    return _best(run)


def _spill_scenario(threshold):
    """Deterministic virtual-time spill round trip (64 blocks, 1 node)."""
    rt = Runtime(io_latency=1.0, spill_threshold=threshold, shard_bits=4)
    made = []

    def maker(paramv, depv, api):
        for i in range(64):
            g, buf = api.db_create(256)
            buf[:] = i & 0xFF
            made.append(g)
        return NULL_GUID

    spawn_main(rt, maker)
    rt.run()
    spilled = rt.stats.spilled_objects
    rt.spill_threshold = None

    def reader(paramv, depv, api):
        return NULL_GUID

    def phase2(paramv, depv, api):
        tmpl = api.edt_template_create(reader, 0, 1)
        for g in made:
            api.edt_create(tmpl, depv=[g], dep_modes=[DbMode.RO])
        return NULL_GUID

    spawn_main(rt, phase2)
    stats = rt.run()
    rt.close()
    return stats, spilled


def run():
    rows = []
    hot_probe = 4096
    for n in (10_000, 100_000, 1_000_000):
        objs = [_Obj(g) for g in _guids(n)]
        cold = _probes(n)
        ordered = _probes(n, shuffle=False)
        hot = _probes(n, hot=min(hot_probe, n))
        hot_reps = max(1, (4 * n) // len(hot) // 8)

        c_flat = _time_create(_FlatTable, objs)
        c_shard = _time_create(ObjectTable, objs)
        flat = _populate(_FlatTable, objs)
        shard = _populate(ObjectTable, objs)
        lh_flat = _time_lookup(flat, hot, hot_reps)
        lh_shard = _time_lookup(shard, hot, hot_reps)
        lc_flat = _time_lookup(flat, cold)
        lc_shard = _time_lookup(shard, cold)
        d_flat = _time_destroy(_FlatTable, objs, ordered)
        d_shard = _time_destroy(ObjectTable, objs, ordered)
        f_flat = _time_failstop(_FlatTable, objs)
        f_shard = _time_failstop(ObjectTable, objs)

        nprobe_hot = len(hot) * hot_reps
        rows.append((f"guidtable.create_n{n}",
                     f"{c_shard / n * 1e6:.4f}",
                     f"flat_us={c_flat / n * 1e6:.4f};"
                     f"speedup={c_flat / c_shard:.2f}x"))
        rows.append((f"guidtable.lookup_hot_n{n}",
                     f"{lh_shard / nprobe_hot * 1e6:.4f}",
                     f"flat_us={lh_flat / nprobe_hot * 1e6:.4f};"
                     f"speedup={lh_flat / lh_shard:.2f}x"))
        rows.append((f"guidtable.lookup_cold_n{n}",
                     f"{lc_shard / n * 1e6:.4f}",
                     f"flat_us={lc_flat / n * 1e6:.4f};"
                     f"speedup={lc_flat / lc_shard:.2f}x"))
        rows.append((f"guidtable.destroy_n{n}",
                     f"{d_shard / n * 1e6:.4f}",
                     f"flat_us={d_flat / n * 1e6:.4f};"
                     f"speedup={d_flat / d_shard:.2f}x"))
        rows.append((f"guidtable.failstop_n{n}",
                     f"{f_shard * 1e6:.1f}",
                     f"flat_us={f_flat * 1e6:.1f};"
                     f"speedup={f_flat / f_shard:.2f}x"))

    stats, spilled = _spill_scenario(threshold=8)
    rows.append(("guidtable.spill_rt",
                 f"{stats.makespan:.0f}",
                 f"spilled={spilled};write_ops={stats.io_write_ops};"
                 f"read_ops={stats.io_read_ops};"
                 f"shards={stats.table_shards}"))
    return rows


def summary():
    """Machine-readable snapshot for BENCH_guidtable.json."""
    n = 1_000_000
    objs = [_Obj(g) for g in _guids(n)]
    cold = _probes(n)
    ordered = _probes(n, shuffle=False)
    hot = _probes(n, hot=4096)

    t0 = time.perf_counter()
    flat = _populate(_FlatTable, objs)
    shard = _populate(ObjectTable, objs)
    lh_flat = _time_lookup(flat, hot, 100)
    lh_shard = _time_lookup(shard, hot, 100)
    lc_flat = _time_lookup(flat, cold)
    lc_shard = _time_lookup(shard, cold)
    d_flat = _time_destroy(_FlatTable, objs, ordered)
    d_shard = _time_destroy(ObjectTable, objs, ordered)
    stats, spilled = _spill_scenario(threshold=8)
    wall = time.perf_counter() - t0
    return {
        "n_objects": n,
        "lookup_hot_sharded_s": lh_shard,
        "lookup_hot_flat_s": lh_flat,
        "lookup_hot_speedup": lh_flat / lh_shard,
        "lookup_cold_sharded_s": lc_shard,
        "lookup_cold_flat_s": lc_flat,
        "lookup_cold_speedup": lc_flat / lc_shard,
        "destroy_sharded_s": d_shard,
        "destroy_flat_s": d_flat,
        "destroy_speedup": d_flat / d_shard,
        "makespan_spill": stats.makespan,
        "spilled_objects": spilled,
        "spill_io_write_ops": stats.io_write_ops,
        "wall_time_s": wall,
    }


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us},{derived}")
