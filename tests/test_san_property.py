"""Property test: the sanitizer reports zero hard findings on *accepted*
schedules.

Random layered task graphs — event-chained layers, shared data blocks
under random acquire modes, §6 partition fan-outs with child-first
release — all synchronize exclusively through the runtime's own
protocols, so any hard finding is by construction a sanitizer false
positive.

The generator is exercised two ways: a seeded sweep that always runs,
and a ``hypothesis``-driven version (skipped when the package is absent,
e.g. outside CI) that searches the same space with shrinking.
"""
import random

import pytest

from repro.core import DbMode, NULL_GUID, Runtime, spawn_main

_MODES = (DbMode.RO, DbMode.RO, DbMode.RW, DbMode.EW)


def _task_body(paramv, depv, api):
    for d in depv:
        if d.ptr is not None:
            if d.mode in (DbMode.RW, DbMode.EW):
                d.ptr[:] = (int(d.ptr[0]) + 1) % 251
            else:
                _ = int(d.ptr[0])
    return NULL_GUID


def _ew_child(paramv, depv, api):
    depv[0].ptr[:] = paramv[0]
    api.db_destroy(depv[0].guid)
    return NULL_GUID


def _build_graph(rng, api):
    """One randomized but protocol-correct program, issued from main."""
    dbs = [api.db_create(rng.choice((32, 64)))[0]
           for _ in range(rng.randint(1, 4))]
    tmpl = api.edt_template_create(_task_body, 0, 6)
    prev_events = []
    for _layer in range(rng.randint(1, 3)):
        events = []
        for _ in range(rng.randint(1, 3)):
            my_dbs = rng.sample(dbs, rng.randint(0, min(2, len(dbs))))
            depv = list(prev_events) + my_dbs
            modes = [DbMode.RO] * len(prev_events) + \
                [rng.choice(_MODES) for _ in my_dbs]
            _g, done = api.edt_create(
                tmpl, depv=depv, dep_modes=modes, output_event=True,
                duration=rng.choice((0.5, 1.0, 2.0)))
            events.append(done)
        prev_events = events
    if rng.random() < 0.6:
        # §6 fan-out: disjoint EW writers, children destroyed child-first
        parent, _ = api.db_create(64)
        cut = rng.choice((16, 32, 48))
        kids = api.db_partition(parent, [(0, cut), (cut, 64 - cut)])
        ew = api.edt_template_create(_ew_child, 1, 1)
        for i, k in enumerate(kids):
            api.edt_create(ew, paramv=[i + 1], depv=[k],
                           dep_modes=[DbMode.EW])


def _run_one(seed):
    rt = Runtime(sanitize=True)
    rng = random.Random(seed)
    spawn_main(rt, lambda p, d, api: _build_graph(rng, api))
    rt.run()
    rep = rt.san_report()
    assert not rep.findings, f"seed {seed}:\n{rep}"
    assert rep.events > 0


@pytest.mark.parametrize("seed", range(12))
def test_accepted_schedules_are_clean_seeded(seed):
    _run_one(seed)


def test_accepted_schedules_are_clean_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=25, deadline=None,
                  database=None, derandomize=True)
    @hyp.given(st.integers(min_value=0, max_value=2 ** 16))
    def prop(seed):
        _run_one(seed)

    prop()
