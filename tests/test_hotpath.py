"""Message-layer hot paths: the zero-outstanding-LID send() fast path, the
per-LID deferred-message index (no re-deferral rescans), and the §6.3
same-timestamp copy batching with the fused-kernel backend."""
import numpy as np
import pytest

from repro.core import (DbMode, EDT_PROP_LID, EventKind, NULL_GUID, Runtime,
                        UNINITIALIZED_GUID, spawn_main)
from repro.core.guid import ObjectKind
from repro.core.messages import MSatisfy


def test_no_lids_no_deferral_bookkeeping():
    """A program that never requests LIDs exercises only the send() fast
    path: nothing is deferred, nothing parked, no unresolved-LID debt."""
    rt = Runtime(num_nodes=4, net_latency=2.0)

    def w(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(w, 0, 1)
        for i in range(8):
            t, _ = api.edt_create(tmpl, depv=[UNINITIALIZED_GUID],
                                  placement=1 + (i % 3))
            api.add_dependence(NULL_GUID, t, 0, DbMode.NULL)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.tasks_executed == 9
    assert stats.messages_deferred == 0
    assert stats.deferred_rescans == 0
    for node in rt.nodes:
        assert node.unresolved_lids == 0
        assert not node.deferred


def test_lid_debt_returns_to_zero():
    """Every allocated LID is eventually resolved and the per-node
    outstanding count returns to zero (the fast path re-arms)."""
    rt = Runtime(num_nodes=4, net_latency=5.0)

    def w(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(w, 0, 1)
        for i in range(12):
            t, _ = api.edt_create(tmpl, depv=[UNINITIALIZED_GUID],
                                  props=EDT_PROP_LID, placement=1 + (i % 3))
            api.add_dependence(NULL_GUID, t, 0, DbMode.NULL)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.messages_deferred == 12
    assert stats.deferred_patched == 12
    assert stats.deferred_rescans == 0       # single-LID messages: no rescans
    for node in rt.nodes:
        assert node.unresolved_lids == 0
        assert not node.deferred


def test_multi_lid_message_indexed_under_every_lid():
    """A message referencing two unresolved LIDs is parked under both; the
    first patch shrinks its blocked set (counted as a rescan-avoided
    touch), the second transmits it — exactly once."""
    rt = Runtime(num_nodes=2)
    from repro.core import TaskCtx
    ctx = TaskCtx(rt, 0, None)
    ev = ctx.event_create(EventKind.STICKY)
    db, _ = ctx.db_create(16)

    l1 = rt._alloc_lid(0)
    l2 = rt._alloc_lid(0)
    msg = MSatisfy(target=l1, slot=0, db=l2)
    rt.send(msg, 0, 0)
    assert rt.stats.messages_deferred == 1
    assert l1 in rt.nodes[0].deferred and l2 in rt.nodes[0].deferred

    rt._apply_lid_binding(l1, ev)
    assert rt.stats.deferred_patched == 1
    assert rt.stats.deferred_rescans == 1    # still parked under l2
    assert rt.stats.messages_sent == 0       # not transmitted yet

    rt._apply_lid_binding(l2, db)
    assert rt.stats.deferred_patched == 2
    assert rt.stats.messages_sent == 1       # released exactly once
    rt.run()
    assert rt.lookup(ev).satisfied
    assert rt.lookup(ev).payload == db
    assert rt.nodes[0].unresolved_lids == 0


def _scatter(backend, num_ranges=8, psize=1024):
    """num_ranges disjoint lane-aligned copies block→shadow at one
    timestamp; returns (shadow contents, stats)."""
    rt = Runtime(copy_backend=backend)
    out = {}
    size = psize * num_ranges

    def main(paramv, depv, api):
        block, ptr = api.db_create(size)
        ptr[:] = np.frombuffer(np.random.default_rng(7).bytes(size), np.uint8)
        api.db_release(block)
        shadow, _ = api.db_create(size)
        api.db_release(shadow)
        for i in range(num_ranges):
            api.db_copy(shadow, i * psize, block, i * psize, psize)
        out["block"] = block
        out["shadow"] = shadow
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    shadow = rt.lookup(out["shadow"]).buffer.copy()
    block = rt.lookup(out["block"]).buffer.copy()
    return block, shadow, stats


def test_copy_batching_numpy_backend():
    block, shadow, stats = _scatter("numpy")
    assert np.array_equal(shadow, block)
    assert stats.bytes_copied == 8 * 1024
    assert stats.fused_copies == 0


def test_copy_batching_pallas_backend_matches():
    """The fused Pallas kernel path is bit-exact vs the numpy backend and
    collapses the batch into one launch."""
    pytest.importorskip("jax")
    block, shadow, stats = _scatter("pallas")
    assert np.array_equal(shadow, block)
    assert stats.bytes_copied == 8 * 1024
    assert stats.fused_copies == 1


def test_copy_completion_events_fire_after_flush():
    """Completion events of batched copies are satisfied (same virtual
    time) and downstream tasks observe the copied bytes."""
    rt = Runtime()
    seen = {}

    def check(paramv, depv, api):
        seen["data"] = depv[1].ptr.copy()
        return NULL_GUID

    def main(paramv, depv, api):
        src, sptr = api.db_create(256)
        sptr[:] = 3
        api.db_release(src)
        dst, _ = api.db_create(256)
        api.db_release(dst)
        ev1 = api.db_copy(dst, 0, src, 0, 128)
        ev2 = api.db_copy(dst, 128, src, 128, 128)
        latch = api.event_create(EventKind.LATCH, latch_count=2)
        api.add_dependence(ev1, latch, 0, DbMode.NULL)
        api.add_dependence(ev2, latch, 0, DbMode.NULL)
        tmpl = api.edt_template_create(check, 0, 2)
        t, _ = api.edt_create(tmpl,
                              depv=[UNINITIALIZED_GUID, UNINITIALIZED_GUID])
        api.add_dependence(latch, t, 0, DbMode.NULL)
        api.add_dependence(dst, t, 1, DbMode.RO)
        seen["dst"] = dst
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert (seen["data"] == 3).all()
    dst_buf = rt.lookup(seen["dst"]).buffer
    assert (dst_buf == 3).all()


def test_partition_back_not_batched():
    """DB_COPY_PARTITION_BACK destroys its source synchronously — it must
    bypass the batch (same observable behavior as the seed runtime)."""
    from repro.core import (DB_COPY_PARTITION, DB_COPY_PARTITION_BACK,
                            DB_PROP_NO_ACQUIRE)
    rt = Runtime()
    out = {}

    def main(paramv, depv, api):
        block, ptr = api.db_create(256)
        ptr[:] = 9
        api.db_release(block)
        c, _ = api.db_create(128, props=DB_PROP_NO_ACQUIRE)
        api.db_copy(c, 0, block, 64, 128, DB_COPY_PARTITION)
        out["block"], out["chunk"] = block, c
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()

    def main2(paramv, depv, api):
        api.db_copy(out["block"], 64, out["chunk"], 0, 128,
                    DB_COPY_PARTITION_BACK)
        return NULL_GUID

    spawn_main(rt, main2)
    rt.run()
    assert rt.try_lookup(out["chunk"]) is None
    assert not rt.lookup(out["block"]).partitions
    assert rt.stats.bytes_zero_copy == 256      # view + aligned write-back


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_copy_then_same_timestamp_destroy(backend):
    """A db_copy followed by db_destroy of the source in the same task must
    land the copy before the destruction — batching may not reorder the
    flush past the MDestroy (seed semantics: copies applied at arrival)."""
    if backend == "pallas":
        pytest.importorskip("jax")
    rt = Runtime(copy_backend=backend)
    out = {}

    def main(paramv, depv, api):
        block, ptr = api.db_create(1024)
        ptr[:] = 5
        api.db_release(block)
        shadow, _ = api.db_create(1024)
        api.db_release(shadow)
        api.db_copy(shadow, 0, block, 0, 512)
        api.db_copy(shadow, 512, block, 512, 512)
        api.db_destroy(block)
        out["shadow"] = shadow
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert (rt.lookup(out["shadow"]).buffer == 5).all()
    assert rt.stats.bytes_copied == 1024


def test_overlapping_destinations_fall_back_to_sequential():
    """Same-timestamp copies with overlapping destinations are legal; the
    pallas backend must fall back to the numpy path's sequential
    last-writer-wins semantics instead of rejecting the batch."""
    pytest.importorskip("jax")
    results = {}
    for backend in ("numpy", "pallas"):
        rt = Runtime(copy_backend=backend)
        out = {}

        def main(paramv, depv, api):
            block, ptr = api.db_create(1024)
            ptr[:512] = 1
            ptr[512:] = 2
            api.db_release(block)
            shadow, _ = api.db_create(1024)
            api.db_release(shadow)
            api.db_copy(shadow, 0, block, 0, 512)
            api.db_copy(shadow, 256, block, 512, 512)   # overlaps first dst
            out["shadow"] = shadow
            return NULL_GUID

        spawn_main(rt, main)
        stats = rt.run()
        results[backend] = rt.lookup(out["shadow"]).buffer.copy()
        assert stats.fused_copies == 0      # overlap: fused path declined
    assert np.array_equal(results["numpy"], results["pallas"])
    assert (results["numpy"][256:768] == 2).all()       # last writer wins


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_same_dst_different_sources_keeps_arrival_order(backend):
    """Copies from different sources into the same destination range must
    apply in arrival order — grouping by (src, dst) may not reorder them
    (seed semantics: the copy issued first lands first)."""
    if backend == "pallas":
        pytest.importorskip("jax")
    rt = Runtime(copy_backend=backend)
    out = {}

    def main(paramv, depv, api):
        s1, p1 = api.db_create(256)
        p1[:] = 1
        api.db_release(s1)
        s2, p2 = api.db_create(256)
        p2[:] = 2
        api.db_release(s2)
        d, _ = api.db_create(256)
        api.db_release(d)
        api.db_copy(d, 0, s1, 0, 256)
        api.db_copy(d, 0, s2, 0, 256)
        api.db_copy(d, 0, s1, 0, 256)   # issued last: s1 must win
        out["d"] = d
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert (rt.lookup(out["d"]).buffer == 1).all()


def test_src_aliasing_dst_is_sequential_on_pallas():
    """A batched copy whose source is another copy's destination must see
    the earlier write (read-after-write), not a pre-batch snapshot."""
    pytest.importorskip("jax")
    bufs = {}
    for backend in ("numpy", "pallas"):
        rt = Runtime(copy_backend=backend)
        out = {}

        def main(paramv, depv, api):
            b, ptr = api.db_create(4096)
            ptr[:] = 0
            ptr[:128] = 1
            api.db_release(b)
            api.db_copy(b, 1024, b, 0, 128)
            api.db_copy(b, 2048, b, 1024, 128)   # reads copy 1's dst
            out["b"] = b
            return NULL_GUID

        spawn_main(rt, main)
        rt.run()
        bufs[backend] = rt.lookup(out["b"]).buffer.copy()
    assert np.array_equal(bufs["numpy"], bufs["pallas"])
    assert (bufs["numpy"][2048:2176] == 1).all()
