"""ocrsan: the happens-before race detector + OCR-invariant sanitizer.

A detector that only ever runs green is untested, so every checker class
here gets a *seeded-bug* test that makes it fire, next to a clean-program
test proving the same construct does not false-positive when the program
synchronizes properly:

* hb-race — a §6.3 ``db_copy`` mutating a block a concurrently-granted
  RO reader holds (copies bypass the lock protocol by design; the
  sanitizer is what catches the missing completion-event edge);
* lid-escape — a raw §3 LID handed to a task outside its home scope
  before the binding lands;
* guid-double-create / guid-non-memoized — §4 labeled-map invariants,
  seeded by corrupting the map's entry table between gets;
* partition-overlap / parent-released-before-children — §6 invariants,
  seeded by disabling the runtime's own validation so only the
  sanitizer's independent registry stands;
* lost-wakeup — ``_wake_waiters`` stubbed out, a parked-but-grantable
  EDT left behind at quiescence;
* leak / dangling-slot — advisory-only quiescence lints.

All seeded-bug runtimes use ``sanitize=True`` (record mode, explicit
parameter overriding ``REPRO_SANITIZE``) and consume their findings via
``san_report()`` so the conftest gate stays quiet.
"""
import numpy as np
import pytest

from repro.analysis import (
    DANGLING_SLOT,
    GUID_DOUBLE_CREATE,
    GUID_NON_MEMOIZED,
    HB_RACE,
    LEAK,
    LID_ESCAPE,
    LOST_WAKEUP,
    OcrSanError,
    PARENT_BEFORE_CHILDREN,
    PARTITION_OVERLAP,
    RaceDetector,
    SanitizerReport,
)
from repro.core import (
    DbMode,
    EDT_PROP_LID,
    EDT_PROP_MAPPED,
    NULL_GUID,
    Runtime,
    TaskCtx,
    spawn_main,
)
from repro.core.objects import DbObj


def _noop(paramv, depv, api):
    return NULL_GUID


# --------------------------------------------------------------- hb-race


def _race_graph(rt, sync_on_completion):
    """Reader holds ``x`` RO while a copy writes into it.  With
    ``sync_on_completion`` the reader deps on the copy's completion
    event — the sanctioned §6.3 ordering — and there is no race."""
    def main(paramv, depv, api):
        x, xb = api.db_create(128)
        y, yb = api.db_create(128)
        yb[:] = 7
        tmpl = api.edt_template_create(_noop, 0, 2)
        ev = api.db_copy(x, 0, y, 0, 64)
        deps = [ev, x] if sync_on_completion else [NULL_GUID, x]
        api.edt_create(tmpl, depv=deps,
                       dep_modes=[DbMode.RO, DbMode.RO], duration=50.0)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()


def test_copy_into_held_block_is_a_race():
    rt = Runtime(sanitize=True)
    _race_graph(rt, sync_on_completion=False)
    rep = rt.san_report()
    assert rep.kinds().get(HB_RACE, 0) >= 1
    f = next(f for f in rep.findings if f.kind == HB_RACE)
    # the witness names both accesses with their vector clocks
    assert len(f.witness) == 2
    assert all("@" in clk for _label, clk in f.witness)
    assert str(f).startswith("[hb-race]")


def test_copy_completion_event_orders_the_reader():
    rt = Runtime(sanitize=True)
    _race_graph(rt, sync_on_completion=True)
    rep = rt.san_report()
    assert not rep.findings, str(rep)


def test_strict_mode_raises_at_run_return():
    rt = Runtime(sanitize="strict")
    with pytest.raises(OcrSanError, match="hb-race"):
        _race_graph(rt, sync_on_completion=False)


def test_disjoint_ew_partition_writers_are_not_a_race():
    """§6: EW siblings on disjoint partitions are the paper's sanctioned
    parallelism — byte-range precision must keep them silent."""
    rt = Runtime(sanitize=True)

    def writer(paramv, depv, api):
        depv[0].ptr[:] = paramv[0]
        return NULL_GUID

    def main(paramv, depv, api):
        parent, _ = api.db_create(128)
        kids = api.db_partition(parent, [(0, 64), (64, 64)])
        tmpl = api.edt_template_create(writer, 1, 1)
        for i, k in enumerate(kids):
            api.edt_create(tmpl, paramv=[i + 1], depv=[k],
                           dep_modes=[DbMode.EW])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert not rt.san_report().findings


def test_serialized_rw_writers_are_not_a_race():
    """Lock-order edges: back-to-back RW grants on one block are ordered
    through its release clock."""
    rt = Runtime(sanitize=True)

    def main(paramv, depv, api):
        x, _ = api.db_create(64)
        tmpl = api.edt_template_create(_noop, 0, 1)
        for _ in range(3):
            api.edt_create(tmpl, depv=[x], dep_modes=[DbMode.RW])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert not rt.san_report().findings


# ------------------------------------------------------------- lid-escape


def test_raw_lid_crossing_scopes_is_flagged():
    """§3: a LID is only meaningful in the scope that allocated it.  The
    creator hands the raw (still unbound) LID to a zero-dep child task,
    which executes synchronously before the binding lands."""
    rt = Runtime(num_nodes=2, sanitize=True)

    def thief(paramv, depv, api):
        api.db_destroy(paramv[0])     # foreign unbound LID
        return NULL_GUID

    def main(paramv, depv, api):
        lid, _ = api.db_create(16, props=EDT_PROP_LID, placement=1)
        tmpl = api.edt_template_create(thief, 1, 0)
        api.edt_create(tmpl, paramv=[lid])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert rt.san_report().kinds().get(LID_ESCAPE, 0) == 1


def test_lid_used_in_home_scope_is_silent():
    rt = Runtime(num_nodes=2, sanitize=True)

    def main(paramv, depv, api):
        lid, _ = api.db_create(16, props=EDT_PROP_LID, placement=1)
        tmpl = api.edt_template_create(_noop, 0, 1)
        api.edt_create(tmpl, depv=[lid])    # same scope: fine
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert not rt.san_report().findings


# ------------------------------------------------- labeled-map invariants


def _mapped_db_creator(ctx, lid, index, paramv, guidv):
    ctx.db_create(8, props=EDT_PROP_MAPPED, mapped_id=lid)


def _fresh_map(rt):
    ctx = TaskCtx(rt, 0, None)
    m = ctx.map_create(4, _mapped_db_creator)
    ctx.map_get(m, 0)
    rt.run()
    return ctx, rt.lookup(m)


def test_map_double_create_is_flagged():
    """§4: the creator must run exactly once per index.  Wiping the entry
    table forces a second creator invocation for index 0."""
    rt = Runtime(sanitize=True)
    ctx, m = _fresh_map(rt)
    m.entries.clear()                 # seeded bug: lost memoization state
    ctx.map_get(m.guid, 0)
    rt.run()
    assert rt.san_report().kinds().get(GUID_DOUBLE_CREATE, 0) == 1


def test_map_non_memoized_reuse_is_flagged():
    """§4: every get of one index must return the same GUID."""
    rt = Runtime(sanitize=True)
    ctx, m = _fresh_map(rt)
    impostor, _ = ctx.db_create(8)
    m.entries[0] = impostor           # seeded bug: entry swapped out
    ctx.map_get(m.guid, 0)
    rt.run()
    assert rt.san_report().kinds().get(GUID_NON_MEMOIZED, 0) == 1


def test_map_memoized_reuse_is_silent():
    rt = Runtime(sanitize=True)
    ctx, m = _fresh_map(rt)
    for _ in range(3):
        ctx.map_get(m.guid, 0)
        ctx.map_get(m.guid, 1)
        rt.run()
    assert not rt.san_report().findings


# ------------------------------------------------- partition invariants


def test_partition_overlap_caught_independently(monkeypatch):
    """§6: partitions of one block must be disjoint.  With the runtime's
    own cross-call validation disabled, the sanitizer's registry is the
    only line of defense left — and it must hold."""
    rt = Runtime(sanitize=True)
    monkeypatch.setattr(DbObj, "overlaps", lambda self, o, s: False)

    def main(paramv, depv, api):
        parent, _ = api.db_create(128)
        api.db_partition(parent, [(0, 64)])
        api.db_partition(parent, [(32, 64)])   # overlaps the live child
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert rt.san_report().kinds().get(PARTITION_OVERLAP, 0) == 1


class _LyingDict(dict):
    """Falsy even when populated — models a partition-table bookkeeping
    bug that lets a parent destroy slip past the §6.2 deferral."""

    def __bool__(self):
        return False


def test_parent_released_before_children_is_flagged():
    rt = Runtime(sanitize=True)
    ctx = TaskCtx(rt, 0, None)
    parent, _ = ctx.db_create(128)
    ctx.db_partition(parent, [(0, 64), (64, 64)])
    p = rt.lookup(parent)
    p.partitions = _LyingDict(p.partitions)    # seeded bug
    ctx.db_destroy(parent)
    rt.run()
    assert rt.san_report().kinds().get(PARENT_BEFORE_CHILDREN, 0) == 1


def test_child_first_release_is_silent():
    rt = Runtime(sanitize=True)
    ctx = TaskCtx(rt, 0, None)
    parent, _ = ctx.db_create(128)
    kids = ctx.db_partition(parent, [(0, 64), (64, 64)])
    for k in kids:
        ctx.db_destroy(k)
    ctx.db_destroy(parent)
    rt.run()
    rep = rt.san_report()
    assert not rep.findings and not rep.advisories, str(rep)


# ------------------------------------------------------------ lost-wakeup


def test_lost_wakeup_at_quiescence():
    """A parked EDT whose every dependence is grantable at quiescence
    means a wake was lost.  Seed: stub out the waiter wakeup."""
    rt = Runtime(sanitize=True)

    def main(paramv, depv, api):
        x, _ = api.db_create(16)
        tmpl = api.edt_template_create(_noop, 0, 1)
        api.edt_create(tmpl, depv=[x], dep_modes=[DbMode.RW], duration=2.0)
        api.edt_create(tmpl, depv=[x], dep_modes=[DbMode.RW], duration=1.0)
        return NULL_GUID

    spawn_main(rt, main)
    rt._wake_waiters = lambda g: None          # seeded bug
    rt.run()
    assert rt.san_report().kinds().get(LOST_WAKEUP, 0) >= 1


# ------------------------------------------------- quiescence advisories


def test_leaks_and_dangling_slots_are_advisory_only():
    rt = Runtime(sanitize="strict")
    ctx = TaskCtx(rt, 0, None)
    ctx.db_create(32)                          # leaked data block
    ctx.event_create()                         # leaked event
    tmpl = ctx.edt_template_create(_noop, 0, 2)
    ctx.edt_create(tmpl, depv=[NULL_GUID])     # slot 1 never satisfied
    rt.run()                                   # strict — yet must not raise
    rep = rt.san_report()
    assert not rep.findings
    kinds = rep.kinds()
    assert kinds.get(LEAK, 0) >= 1
    assert kinds.get(DANGLING_SLOT, 0) == 1
    assert not bool(rep)                       # advisories never fail a run


# ------------------------------------------------------- plumbing & stats


def test_sanitize_off_leaves_no_trace():
    rt = Runtime(sanitize=False)               # explicit off beats the env
    _race_graph(rt, sync_on_completion=False)
    assert rt._san is None
    assert rt.stats.san_events == 0
    with pytest.raises(Exception, match="sanitizer not enabled"):
        rt.san_report()


def test_stats_gauges_populated():
    rt = Runtime(sanitize=True)
    _race_graph(rt, sync_on_completion=False)
    st = rt.stats
    assert st.san_events > 0
    assert st.san_races >= 1
    assert st.san_findings >= 1
    rt.san_report()


def test_clean_mixed_program_is_clean():
    """Tasks, events, copies, partitions, maps and file-free IO paths in
    one accepted program: zero findings, and the report renders."""
    rt = Runtime(sanitize=True, spill_threshold=4)

    def stage2(paramv, depv, api):
        assert int(depv[1].ptr[0]) == 5
        api.db_destroy(depv[1].guid)
        return NULL_GUID

    def stage1(paramv, depv, api):
        depv[0].ptr[:] = 5
        return depv[0].guid

    def main(paramv, depv, api):
        x, _ = api.db_create(64)
        t1 = api.edt_template_create(stage1, 0, 1)
        t2 = api.edt_template_create(stage2, 0, 2)
        g1, done = api.edt_create(t1, depv=[x], dep_modes=[DbMode.RW],
                                  output_event=True)
        api.edt_create(t2, depv=[done, x], dep_modes=[DbMode.RO, DbMode.RO])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    rep = rt.san_report()
    assert isinstance(rep, SanitizerReport)
    assert not rep.findings, str(rep)
    assert "ocrsan" in str(rep)


def test_race_detector_unit():
    """The VC engine itself: overlap + unordered fires, ordered or
    disjoint stays silent, covered history is pruned."""
    from repro.analysis import Access

    d = RaceDetector()
    root = object()
    a = Access(act=1, tick=1, clock={1: 1}, write=True, lo=0, hi=8,
               label="w0", t=0.0)
    assert d.record(root, a) is None
    # ordered successor (saw act 1 tick 1): silent, and it covers `a`
    b = Access(act=2, tick=1, clock={1: 1, 2: 1}, write=True, lo=0, hi=8,
               label="w1", t=1.0)
    assert d.record(root, b) is None
    assert d.history_len(root) == 1
    # disjoint concurrent write: silent
    c = Access(act=3, tick=1, clock={3: 1}, write=True, lo=8, hi=16,
               label="w2", t=1.0)
    assert d.record(root, c) is None
    # overlapping unordered read vs w1: race
    r = Access(act=4, tick=1, clock={4: 1}, write=False, lo=4, hi=12,
               label="r0", t=2.0)
    hit = d.record(root, r)
    assert hit is not None and hit[0].label == "w1"
    d.drop_root(root)
    assert d.history_len(root) == 0


# ------------------------------------------------------- trace export


def test_export_trace_jsonl_round_trips(tmp_path):
    """``Sanitizer.export_trace`` dumps the structured event ring as
    JSONL; ``load_trace`` reconstructs it exactly — Guids (kind tag
    included), Lids, nested tuples and floats all survive the trip."""
    from repro.analysis import load_trace

    rt = Runtime(num_nodes=2, sanitize=True)

    def thief(paramv, depv, api):
        api.db_destroy(paramv[0])     # LID escape: Lid payloads in events
        return NULL_GUID

    def main(paramv, depv, api):
        x, xb = api.db_create(64)
        y, yb = api.db_create(64)
        yb[:] = 7
        api.db_copy(x, 0, y, 0, 32)   # copy events carry (guid, lo, hi)
        lid, _ = api.db_create(16, props=EDT_PROP_LID, placement=1)
        tmpl = api.edt_template_create(thief, 1, 0)
        api.edt_create(tmpl, paramv=[lid])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    events = list(rt._san.trace_events)
    assert events, "workload produced no trace events"
    path = tmp_path / "trace.jsonl"
    n = rt._san.export_trace(str(path))
    assert n == len(events)
    assert len(path.read_text().splitlines()) == n
    loaded = load_trace(str(path))
    assert loaded == events
    # spot the payload shapes actually round-tripped, not just compared
    kinds = {ev[1] for ev in loaded}
    assert "copy" in kinds or "db_create" in kinds, kinds
    # the seeded LID escape put a Lid in the stream; consume the finding
    assert rt.san_report().kinds().get(LID_ESCAPE, 0) == 1
