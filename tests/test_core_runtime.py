"""Behavioural tests of the OCR-extensions core runtime against the paper's
own examples (§3 LIDs, §4 labeled wavefront, §6 partitioning)."""
import numpy as np
import pytest

from repro.core import (DB_COPY_PARTITION, DB_COPY_PARTITION_BACK,
                        DB_PROP_NO_ACQUIRE, DbMode, EDT_PROP_LID,
                        EDT_PROP_MAPPED, EventKind, NULL_GUID, IdType,
                        OcrError, PartitionDeadlockError,
                        PartitionOverlapError, PartitionStaticError, Runtime,
                        UNINITIALIZED_GUID, id_type, spawn_main)


def run_wavefront(w, h, seed=0, jitter=0.0, num_nodes=4):
    rt = Runtime(num_nodes=num_nodes, seed=seed, jitter=jitter)
    executed = []
    state = {}

    def creator(ctx, object_lid, index, paramv, guidv):
        width, _ = paramv
        x, y = index % width, index // width
        deps = [NULL_GUID if x == 0 else UNINITIALIZED_GUID,
                NULL_GUID if y == 0 else UNINITIALIZED_GUID]
        ctx.edt_create(guidv[0], paramv=[x, y], depv=deps,
                       props=EDT_PROP_MAPPED)

    def work(paramv, depv, api):
        x, y = paramv
        executed.append((x, y))
        if x == w - 1 and y == h - 1:
            api.shutdown()
            return NULL_GUID
        if x < w - 1:
            t = api.map_get(state["map"], (x + 1) + y * w)
            api.add_dependence(NULL_GUID, t, 0, DbMode.NULL)
        if y < h - 1:
            t = api.map_get(state["map"], x + (y + 1) * w)
            api.add_dependence(NULL_GUID, t, 1, DbMode.NULL)
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(work, 2, 2)
        state["map"] = api.map_create(w * h, creator, paramv=[w, h],
                                      guidv=[tmpl])
        api.map_get(state["map"], 0)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    return executed, stats


def test_wavefront_executes_all_and_in_order():
    executed, stats = run_wavefront(4, 3)
    assert len(executed) == 12
    pos = {c: i for i, c in enumerate(executed)}
    for (x, y) in executed:
        if x > 0:
            assert pos[(x - 1, y)] < pos[(x, y)]
        if y > 0:
            assert pos[(x, y - 1)] < pos[(x, y)]
    # §4 guarantee: creator ran exactly once per index despite racing gets
    assert stats.creator_calls == 12


def test_wavefront_duplicate_gets_same_guid():
    rt = Runtime(num_nodes=3)
    got = {}

    def creator(ctx, lid, index, paramv, guidv):
        ctx.edt_create(guidv[0], paramv=[index],
                       depv=[UNINITIALIZED_GUID], props=EDT_PROP_MAPPED)

    def noop(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(noop, 1, 1)
        m = api.map_create(4, creator, guidv=[tmpl])
        l1 = api.map_get(m, 2)
        l2 = api.map_get(m, 2)
        assert l1 != l2                       # distinct LIDs...
        got["g1"] = api.get_guid(l1)
        got["g2"] = api.get_guid(l2)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert got["g1"] == got["g2"]             # ...same resolved GUID (§4)


def test_lid_vs_blocking_roundtrips():
    def bench(use_lid, n=6):
        rt = Runtime(num_nodes=4, net_latency=5.0)

        def noop(paramv, depv, api):
            return NULL_GUID

        def main(paramv, depv, api):
            tmpl = api.edt_template_create(noop, 0, 1)
            for i in range(n):
                t, _ = api.edt_create(
                    tmpl, depv=[UNINITIALIZED_GUID],
                    props=EDT_PROP_LID if use_lid else 0,
                    placement=1 + (i % 3))
                assert id_type(t) == (IdType.LID if use_lid else IdType.GUID)
                api.add_dependence(NULL_GUID, t, 0, DbMode.NULL)
            return NULL_GUID

        spawn_main(rt, main)
        return rt.run()

    lid, blk = bench(True), bench(False)
    assert lid.blocking_roundtrips == 0
    assert blk.blocking_roundtrips == 6
    assert lid.makespan < blk.makespan
    assert lid.messages_deferred > 0          # deps waited for M_map (§3)
    assert lid.deferred_patched == lid.messages_deferred


def test_local_creation_returns_guid_even_if_lid_requested():
    """§3: the runtime may return a real GUID when no communication is
    needed — the application can detect this via ocrGetIdType."""
    rt = Runtime(num_nodes=2)
    seen = {}

    def noop(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(noop, 0, 1)
        t, _ = api.edt_create(tmpl, depv=[UNINITIALIZED_GUID],
                              props=EDT_PROP_LID, placement=0)  # local node
        seen["t"] = id_type(t)
        api.add_dependence(NULL_GUID, t, 0, DbMode.NULL)
        return NULL_GUID

    spawn_main(rt, main, node=0)
    rt.run()
    assert seen["t"] == IdType.GUID


def test_partition_parallelism_and_quiescence():
    """§6: EW partitions run in parallel; the parent is quiescent until all
    partitions are destroyed."""
    rt = Runtime(num_nodes=1)
    times = {}

    def work(paramv, depv, api):
        data = depv[0].ptr.view(np.uint32)
        data += np.uint32(paramv[0])
        times[paramv[0]] = api.rt.clock
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def finish(paramv, depv, api):
        data = depv[0].ptr.view(np.uint32)
        times["finish"] = api.rt.clock
        times["sum"] = int(data.sum())
        return NULL_GUID

    def main(paramv, depv, api):
        db, ptr = api.db_create(64)
        ptr.view(np.uint32)[:] = 1
        api.db_release(db)
        parts = api.db_partition(db, [(0, 32), (32, 32)])
        tmpl = api.edt_template_create(work, 1, 1)
        ftmpl = api.edt_template_create(finish, 0, 1)
        api.edt_create(tmpl, paramv=[10], depv=[parts[0]],
                       dep_modes=[DbMode.EW], duration=5)
        api.edt_create(tmpl, paramv=[20], depv=[parts[1]],
                       dep_modes=[DbMode.EW], duration=5)
        # finish acquires the parent: must wait for both partitions
        api.edt_create(ftmpl, depv=[db], dep_modes=[DbMode.RO])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert times["sum"] == 8 * 11 + 8 * 21
    assert times["finish"] >= max(times[10], times[20]) + 5


def test_partition_overlap_rejected():
    rt = Runtime()
    errs = []

    def main(paramv, depv, api):
        db, _ = api.db_create(100)
        api.db_partition(db, [(0, 50)])
        for bad in ([(40, 20)], [(0, 200)], [(-1, 10)]):
            try:
                api.db_partition(db, bad)
            except PartitionOverlapError:
                errs.append(bad[0])
        try:
            api.db_partition(db, [(50, 30), (60, 30)])
        except PartitionOverlapError:
            errs.append("mutual")
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert len(errs) == 4


def test_static_partitioning():
    from repro.core import OCR_DB_PARTITION_STATIC
    rt = Runtime()
    out = {}

    def main(paramv, depv, api):
        db, _ = api.db_create(100)
        parts = api.db_partition(db, [(0, 50)], props=OCR_DB_PARTITION_STATIC)
        try:
            api.db_partition(db, [(50, 50)])
            out["raised"] = False
        except PartitionStaticError:
            out["raised"] = True
        api.db_destroy(parts[0])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert out["raised"]
    # all partitions destroyed → static flag cleared, repartition allowed
    def main2(paramv, depv, api):
        out["ok"] = api.db_partition(out["db"], [(0, 10)]) is not None
        return NULL_GUID
    # (second runtime phase: reuse same runtime object)
    d = rt.nodes[0].objects
    out["db"] = next(g for g, o in d.items()
                     if getattr(o, "size", None) == 100)
    spawn_main(rt, main2)
    rt.run()
    assert out["ok"]


def test_parent_child_same_task_deadlock():
    rt = Runtime()
    raised = []

    def w(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(100)
        api.db_release(db)
        parts = api.db_partition(db, [(0, 50)])
        tmpl = api.edt_template_create(w, 0, 2)
        api.edt_create(tmpl, depv=[db, parts[0]],
                       dep_modes=[DbMode.RO, DbMode.EW])
        return NULL_GUID

    spawn_main(rt, main)
    with pytest.raises(PartitionDeadlockError):
        rt.run()


def test_db_copy_zero_copy_and_back():
    """§6.3: NO_ACQUIRE + DB_COPY_PARTITION → zero-copy view; PARTITION_BACK
    destroys the source and frees the parent."""
    rt = Runtime()
    out = {}

    def main(paramv, depv, api):
        block, ptr = api.db_create(256)
        ptr[:] = 9
        api.db_release(block)
        c, _ = api.db_create(128, props=DB_PROP_NO_ACQUIRE)
        ev = api.db_copy(c, 0, block, 64, 128, DB_COPY_PARTITION)
        out["block"] = block
        out["chunk"] = c
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert rt.stats.bytes_zero_copy == 128 and rt.stats.bytes_copied == 0
    chunk = rt.lookup(out["chunk"])
    assert chunk.is_view and chunk.parent == out["block"]
    parent = rt.lookup(out["block"])
    assert out["chunk"] in parent.partitions

    def main2(paramv, depv, api):
        api.db_copy(out["block"], 64, out["chunk"], 0, 128,
                    DB_COPY_PARTITION_BACK)
        return NULL_GUID

    spawn_main(rt, main2)
    rt.run()
    assert rt.try_lookup(out["chunk"]) is None        # source destroyed
    assert not rt.lookup(out["block"]).partitions     # parent free again


def test_event_kinds():
    rt = Runtime()
    fired = []

    def w(paramv, depv, api):
        fired.append(paramv[0])
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(w, 1, 1)
        sticky = api.event_create(EventKind.STICKY)
        api.event_satisfy(sticky)
        # dependence added AFTER satisfaction still fires (sticky)
        t, _ = api.edt_create(tmpl, paramv=["sticky"],
                              depv=[UNINITIALIZED_GUID])
        api.add_dependence(sticky, t, 0, DbMode.NULL)
        latch = api.event_create(EventKind.LATCH, latch_count=2)
        t2, _ = api.edt_create(tmpl, paramv=["latch"],
                               depv=[UNINITIALIZED_GUID])
        api.add_dependence(latch, t2, 0, DbMode.NULL)
        api.event_satisfy(latch)
        api.event_satisfy(latch)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert sorted(fired) == ["latch", "sticky"]


def test_recursive_partitioning():
    """§6.2: partitions can themselves be partitioned; the deadlock rule
    applies across levels (grandparent + grandchild in one task)."""
    rt = Runtime()
    out = {}

    def leaf_task(paramv, depv, api):
        depv[0].ptr.view(np.uint32)[:] = np.uint32(paramv[0])
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def check(paramv, depv, api):
        out["data"] = depv[0].ptr.view(np.uint32).copy()
        return NULL_GUID

    def main(paramv, depv, api):
        db, ptr = api.db_create(64)
        ptr.view(np.uint32)[:] = 0
        api.db_release(db)
        top = api.db_partition(db, [(0, 32), (32, 32)])
        sub = api.db_partition(top[0], [(0, 16), (16, 16)])   # recursive
        tmpl = api.edt_template_create(leaf_task, 1, 1)
        api.edt_create(tmpl, paramv=[5], depv=[sub[0]], dep_modes=[DbMode.EW])
        api.edt_create(tmpl, paramv=[6], depv=[sub[1]], dep_modes=[DbMode.EW])
        api.edt_create(tmpl, paramv=[7], depv=[top[1]], dep_modes=[DbMode.EW])
        out["db"] = db
        out["top0"] = top[0]
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()

    # grandparent+grandchild in one task → deadlock error
    def main2(paramv, depv, api):
        sub2 = api.db_partition(out["top0"], [(0, 16)])
        tmpl = api.edt_template_create(lambda p, d, a: NULL_GUID, 0, 2)
        api.edt_create(tmpl, depv=[out["db"], sub2[0]],
                       dep_modes=[DbMode.RO, DbMode.EW])
        return NULL_GUID

    spawn_main(rt, main2)
    with pytest.raises(PartitionDeadlockError):
        rt.run()


def test_recursive_partition_values_propagate_to_parent():
    """Writes through grandchild views are visible through the parent once
    the whole tree is destroyed (zero-copy views, §6.3 semantics)."""
    rt = Runtime()
    out = {}

    def w(paramv, depv, api):
        depv[0].ptr.view(np.uint32)[:] = np.uint32(paramv[0])
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def check(paramv, depv, api):
        out["data"] = depv[0].ptr.view(np.uint32).copy()
        return NULL_GUID

    def main(paramv, depv, api):
        db, ptr = api.db_create(32)
        ptr.view(np.uint32)[:] = 0
        api.db_release(db)
        top = api.db_partition(db, [(0, 16), (16, 16)])
        sub = api.db_partition(top[0], [(0, 8), (8, 8)])
        tmpl = api.edt_template_create(w, 1, 1)
        api.edt_create(tmpl, paramv=[1], depv=[sub[0]], dep_modes=[DbMode.EW])
        api.edt_create(tmpl, paramv=[2], depv=[sub[1]], dep_modes=[DbMode.EW])
        api.edt_create(tmpl, paramv=[3], depv=[top[1]], dep_modes=[DbMode.EW])
        # intermediate partition must also be destroyed to free the parent
        api.db_destroy(top[0])
        ctmpl = api.edt_template_create(check, 0, 1)
        api.edt_create(ctmpl, depv=[db], dep_modes=[DbMode.RO])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert list(out["data"]) == [1, 1, 2, 2, 3, 3, 3, 3]
