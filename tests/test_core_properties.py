"""Hypothesis property tests over the core runtime's §3–§6 invariants.

Interleavings are explored via seeded delivery jitter: the same program run
under any message ordering must preserve the paper's guarantees
(exactly-once creation, same-GUID resolution, partition safety,
write-back correctness).
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (DbMode, EDT_PROP_MAPPED, NULL_GUID, OcrError,
                        PartitionOverlapError, Runtime, UNINITIALIZED_GUID,
                        spawn_main)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), nodes=st.integers(1, 6),
       size=st.integers(1, 12), gets_per_index=st.integers(1, 4))
def test_map_creator_exactly_once_under_any_interleaving(
        seed, nodes, size, gets_per_index):
    """§4: concurrent ocrMapGet storms create each object exactly once and
    every LID for an index resolves to the same GUID."""
    rt = Runtime(num_nodes=nodes, seed=seed, jitter=3.0)
    resolved = {}

    def creator(ctx, lid, index, paramv, guidv):
        ctx.edt_create(guidv[0], paramv=[index], depv=[UNINITIALIZED_GUID],
                       props=EDT_PROP_MAPPED)

    def noop(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(noop, 1, 1)
        m = api.map_create(size, creator, guidv=[tmpl])
        lids = [(i, api.map_get(m, i))
                for i in range(size) for _ in range(gets_per_index)]
        for i, lid in lids:
            resolved.setdefault(i, []).append(api.get_guid(lid))
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.creator_calls == size
    for i, guids in resolved.items():
        assert len(set(guids)) == 1, f"index {i} resolved to {set(guids)}"


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 200), st.integers(1, 80)),
                min_size=1, max_size=12))
def test_partition_no_overlap_invariant(parts):
    """§6.2: the runtime accepts a partition request iff it is in-bounds and
    disjoint from every live partition."""
    rt = Runtime()
    accepted = []

    def main(paramv, depv, api):
        db, _ = api.db_create(256)
        for (off, size) in parts:
            try:
                api.db_partition(db, [(off, size)])
                accepted.append((off, size))
            except PartitionOverlapError:
                pass
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    # model check: greedy replay must accept exactly the same set
    model = []
    for (off, size) in parts:
        in_bounds = 0 <= off and size > 0 and off + size <= 256
        disjoint = all(off >= o + s or o >= off + size for (o, s) in model)
        if in_bounds and disjoint:
            model.append((off, size))
    assert accepted == model
    # and accepted partitions are pairwise disjoint
    for i, (o1, s1) in enumerate(accepted):
        for (o2, s2) in accepted[i + 1:]:
            assert o1 + s1 <= o2 or o2 + s2 <= o1


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       writes=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 250)),
                       min_size=1, max_size=4, unique_by=lambda t: t[0]))
def test_chunk_writeback_under_interleaving(tmp_path_factory, seed, writes):
    """§5: disjoint chunks written in EW mode land at their exact offsets
    regardless of task interleaving."""
    path = str(tmp_path_factory.mktemp("fio") / f"f_{seed}.bin")
    chunk = 64
    rt = Runtime(num_nodes=3, seed=seed, jitter=2.0)

    def writer(paramv, depv, api):
        val = paramv[0]
        depv[0].ptr[:] = np.uint8(val)
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, _ = api.file_open(path, "wb+")
        tmpl = api.edt_template_create(writer, 1, 1)
        for slot, (idx, val) in enumerate(writes):
            c = api.file_get_chunk(f, idx * chunk, chunk)
            api.edt_create(tmpl, paramv=[val], depv=[c],
                           dep_modes=[DbMode.EW], placement=slot % 3)
        api.file_release(f)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    data = np.fromfile(path, dtype=np.uint8)
    for (idx, val) in writes:
        got = data[idx * chunk: (idx + 1) * chunk]
        assert np.all(got == val), (idx, val, got[:4])


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), w=st.integers(1, 4), h=st.integers(1, 4))
def test_wavefront_order_any_interleaving(seed, w, h):
    from test_core_runtime import run_wavefront
    executed, stats = run_wavefront(w, h, seed=seed, jitter=4.0, num_nodes=5)
    assert len(executed) == w * h
    pos = {c: i for i, c in enumerate(executed)}
    for (x, y) in executed:
        if x > 0:
            assert pos[(x - 1, y)] < pos[(x, y)]
        if y > 0:
            assert pos[(x, y - 1)] < pos[(x, y)]
    assert stats.creator_calls == w * h


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), n=st.integers(1, 8))
def test_lid_chain_linearizable(seed, n):
    """§3: a chain of LID-created tasks linked by deferred dependences runs
    in chain order under any interleaving."""
    rt = Runtime(num_nodes=4, seed=seed, jitter=5.0)
    order = []

    def w(paramv, depv, api):
        order.append(paramv[0])
        return NULL_GUID

    def main(paramv, depv, api):
        from repro.core import EDT_PROP_LID
        tmpl = api.edt_template_create(w, 1, 1)
        prev_ev = None
        for i in range(n):
            t, ev = api.edt_create(tmpl, paramv=[i],
                                   depv=[UNINITIALIZED_GUID],
                                   props=EDT_PROP_LID, output_event=True,
                                   placement=i % 4)
            if prev_ev is None:
                api.add_dependence(NULL_GUID, t, 0, DbMode.NULL)
            else:
                api.add_dependence(prev_ev, t, 0, DbMode.NULL)
            prev_ev = ev
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert order == list(range(n))
