"""Sharded per-node GUID tables (§2 structure-in-the-identifier storage).

Covers the :class:`repro.core.objects.ObjectTable` itself (O(1) arithmetic
shard routing, per-shard live/destroyed counts, empty-shard reclamation),
the ``Stats.table_*`` gauges, the fail-stop semantics rebuilt on top of it
(a dead node's objects are *lost*: clean ``OcrError``, spilled files
reclaimed), the destroyed-map ``map_get`` guard, and remote db/event
creation through the §3 ``MCreate`` path.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core import (DbMode, EDT_PROP_LID, EventKind, GUID_SHARD_BITS,
                        Guid, Lid, NULL_GUID, ObjectKind, ObjectTable,
                        OcrError, Runtime, shard_index, shard_of, shard_span,
                        spawn_main)


@dataclasses.dataclass
class _Obj:
    guid: Guid


def _mk(seq, kind=ObjectKind.DATABLOCK, node=0):
    return _Obj(Guid(node, seq, kind))


# --------------------------------------------------------------- shard helpers


def test_shard_helpers_round_trip():
    for bits in (2, GUID_SHARD_BITS, 12):
        for seq in (0, 1, (1 << bits) - 1, 1 << bits, 12345):
            idx = shard_index(seq, bits)
            lo, hi = shard_span(idx, bits)
            assert lo <= seq < hi
            assert hi - lo == 1 << bits
    g = Guid(3, 777, ObjectKind.MAP)
    assert shard_of(g, 4) == (ObjectKind.MAP, 777 >> 4)


# ----------------------------------------------------------------- ObjectTable


def test_table_insert_get_pop_contains():
    t = ObjectTable(shard_bits=2)
    objs = [_mk(i) for i in range(1, 11)]
    for o in objs:
        t.insert(o)
    assert len(t) == 10
    for o in objs:
        assert t.get(o.guid) is o
        assert o.guid in t
    # probes with reconstructed (non-identical) guids route identically
    assert t.get(Guid(0, 5, ObjectKind.DATABLOCK)) is objs[4]
    # misses: unknown seq, unknown kind, sentinel, and a Lid probe
    assert t.get(Guid(0, 99, ObjectKind.DATABLOCK)) is None
    assert t.get(Guid(0, 5, ObjectKind.EVENT)) is None
    assert t.get(NULL_GUID) is None
    assert t.get(Lid(0, 5)) is None
    assert t.pop(Lid(0, 5)) is None
    got = t.pop(objs[0].guid)
    assert got is objs[0]
    assert t.pop(objs[0].guid) is None
    assert len(t) == 9


def test_table_items_values_iter_mixed_kinds():
    t = ObjectTable(shard_bits=2)
    a, b = _mk(1), _mk(2, ObjectKind.EVENT)
    t.insert(a)
    t[b.guid] = b          # dict-compat setitem
    assert dict(t.items()) == {a.guid: a, b.guid: b}
    assert set(t) == {a.guid, b.guid}
    assert t[a.guid] is a
    with pytest.raises(KeyError):
        t[Guid(0, 9, ObjectKind.MAP)]


def test_table_shard_counts_and_reclamation():
    t = ObjectTable(shard_bits=2)          # 4 seqs per shard
    for i in range(1, 9):                  # seqs 1..8 -> shards 0,1,2
        t.insert(_mk(i))
    assert t.shard_count() == 3
    assert t.live_count(ObjectKind.DATABLOCK) == 8
    assert t.hot_shard_count() == 3
    # drain shard 1 (seqs 4..7): it is reclaimed wholesale, its destroyed
    # count surviving in the per-kind aggregate
    for i in range(4, 8):
        t.pop(Guid(0, i, ObjectKind.DATABLOCK))
    assert t.shard_count() == 2
    assert t.destroyed_count(ObjectKind.DATABLOCK) == 4
    assert t.live_count(ObjectKind.DATABLOCK) == 4
    # per-shard destroyed counts stay visible on live shards
    t.pop(Guid(0, 1, ObjectKind.DATABLOCK))
    (idx0, sh0), (idx2, sh2) = t.shards(ObjectKind.DATABLOCK)
    assert (idx0, idx2) == (0, 2)
    assert sh0.destroyed == 1 and sh2.destroyed == 0
    assert t.destroyed_count(ObjectKind.DATABLOCK) == 5


def test_table_spilled_marks_drive_hot_shards():
    t = ObjectTable(shard_bits=2)
    for i in range(4, 8):                  # exactly one shard (idx 1)
        t.insert(_mk(i))
    assert t.hot_shard_count() == 1
    for i in range(4, 8):
        t.note_spilled(Guid(0, i, ObjectKind.DATABLOCK))
    assert t.hot_shard_count() == 0        # fully spilled shard is cold
    t.note_unspilled(Guid(0, 4, ObjectKind.DATABLOCK))
    assert t.hot_shard_count() == 1


def test_table_clear_is_bulk():
    t = ObjectTable(shard_bits=2)
    for i in range(1, 20):
        t.insert(_mk(i))
    t.clear()
    assert len(t) == 0 and t.shard_count() == 0
    assert t.destroyed_count(ObjectKind.DATABLOCK) == 19


def test_runtime_stats_gauges():
    rt = Runtime(shard_bits=2)
    keep = []

    def main(paramv, depv, api):
        for _ in range(10):
            g, _ = api.db_create(8)
            keep.append(g)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.table_shards >= 3          # 10 DBs at 4 seqs/shard
    assert 0 < stats.table_hot_shards <= stats.table_shards
    assert stats.spilled_objects == 0       # spill disabled by default


# ------------------------------------------------------------------ fail-stop


def test_failstop_loses_objects_clean_ocr_error():
    """Satellite regression: a survivor acquiring a dead node's DB gets a
    clean OcrError, not a silently-served stale object."""
    rt = Runtime(num_nodes=2)
    made = {}

    def main(paramv, depv, api):
        db, _ = api.db_create(64, placement=1)      # lives on node 1
        made["db"] = db
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    dead_db = made["db"]
    assert rt.lookup(dead_db).node == 1

    rt.kill_node(1)
    # direct lookup: clean error naming the fail-stop
    with pytest.raises(OcrError, match="fail-stopped"):
        rt.lookup(dead_db)
    assert rt.try_lookup(dead_db) is None
    # the dead node's tables are actually dropped
    assert len(rt.nodes[1].objects) == 0
    assert not rt.nodes[1].lid_table

    # a survivor wiring an acquire of the dead DB fails loudly too
    # (zero-dep main bodies run synchronously at spawn)
    def survivor(paramv, depv, api):
        tmpl = api.edt_template_create(lambda p, d, a: NULL_GUID, 0, 1)
        api.edt_create(tmpl, depv=[dead_db], dep_modes=[DbMode.RO],
                       placement=0)
        return NULL_GUID

    with pytest.raises(OcrError, match="fail-stopped"):
        spawn_main(rt, survivor)
        rt.run()

    # and explicit placement on the dead node is rejected outright
    def placer(paramv, depv, api):
        api.db_create(8, placement=1)
        return NULL_GUID

    with pytest.raises(OcrError, match="fail-stopped"):
        spawn_main(rt, placer)
        rt.run()


def test_failstop_reclaims_spill_file(tmp_path):
    """A dead node's spilled objects are unreachable and its spill file is
    deleted from disk."""
    rt = Runtime(num_nodes=2, spill_threshold=0, io_latency=1.0)
    made = []

    def maker(paramv, depv, api):
        for i in range(4):
            g, buf = api.db_create(32)
            buf[:] = i + 1
            made.append(g)
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(maker, 0, 0)
        api.edt_create(tmpl, depv=[], placement=1)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.spilled_objects == 4
    spill_path = rt.nodes[1].spill_path
    assert spill_path is not None and os.path.exists(spill_path)

    rt.kill_node(1)
    assert rt.stats.spilled_objects == 0
    assert not os.path.exists(spill_path)
    assert rt.nodes[1].spill_path is None
    with pytest.raises(OcrError, match="fail-stopped"):
        rt.lookup(made[0])


def test_failstop_force_resolve_rejects_dead_target():
    """force_resolve must not create objects on a fail-stopped node."""
    rt = Runtime(num_nodes=2, net_latency=5.0)
    out = {}

    def main(paramv, depv, api):
        out["lid"], _ = api.db_create(64, props=EDT_PROP_LID, placement=1)
        return NULL_GUID

    spawn_main(rt, main)
    # kill before the MCreate lands: the pending creation dies with node 1
    rt.kill_node(1)
    rt.run()
    from repro.core import TaskCtx
    ctx = TaskCtx(rt, 0, None)
    with pytest.raises(OcrError, match="fail-stopped"):
        ctx.get_guid(out["lid"])
    assert len(rt.nodes[1].objects) == 0


def test_failstop_wakes_parked_survivor_with_error():
    """An EDT already parked in a dead node's waiter queue fails loudly on
    the next run instead of hanging silently forever."""
    rt = Runtime(num_nodes=2)
    made = {}

    def writer(paramv, depv, api):
        return NULL_GUID

    def reader(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(32, placement=1)
        made["db"] = db
        wt = api.edt_template_create(writer, 0, 1)
        api.edt_create(wt, depv=[db], dep_modes=[DbMode.EW], duration=20.0,
                       placement=0)
        rtm = api.edt_template_create(reader, 0, 1)
        api.edt_create(rtm, depv=[db], dep_modes=[DbMode.RO], placement=0)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run(until=10.0)          # writer holds the DB, reader is parked
    rt.kill_node(1)
    with pytest.raises(OcrError, match="fail-stopped"):
        rt.run()


def test_failstop_from_own_task_body():
    """A task body fail-stopping its *own* node (the trainer's injected
    failure) must not crash the runtime at the task's retirement."""
    rt = Runtime(num_nodes=2)
    ran = []

    def suicidal(paramv, depv, api):
        api.rt.kill_node(api.node)
        return NULL_GUID

    def late(paramv, depv, api):
        ran.append("late")
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(suicidal, 0, 0)
        _, ev = api.edt_create(tmpl, depv=[], placement=1,
                               output_event=True)
        # gated on the dead task's output event: must never fire
        lt = api.edt_template_create(late, 0, 1)
        api.edt_create(lt, depv=[ev], dep_modes=[DbMode.NULL], placement=0)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()                      # completes without raising
    assert not rt.nodes[1].alive
    assert ran == []              # nothing downstream of the dead task ran


# ---------------------------------------------------- destroyed-map map_get


def test_map_destroy_then_get_is_clean_ocr_error():
    """Satellite regression: map_get racing map_destroy must raise a clean
    OcrError instead of touching the destroyed map's entries/creator."""
    rt = Runtime()

    def creator(api, lid, index, paramv, guidv):
        tmpl = api.edt_template_create(lambda p, d, a: NULL_GUID, 0, 1)
        api.edt_create(tmpl, depv=[NULL_GUID], props=0x2, mapped_id=lid)

    def main(paramv, depv, api):
        m = api.map_create(4, creator)
        api.map_destroy(m)
        api.map_get(m, 0)       # same timestamp, ordered after the destroy
        return NULL_GUID

    spawn_main(rt, main)
    with pytest.raises(OcrError, match="destroyed or unknown map"):
        rt.run()


def test_map_get_then_destroy_still_works():
    rt = Runtime()
    seen = {}

    def creator(api, lid, index, paramv, guidv):
        tmpl = api.edt_template_create(lambda p, d, a: NULL_GUID, 0, 1)
        api.edt_create(tmpl, depv=[NULL_GUID], props=0x2, mapped_id=lid)

    def main(paramv, depv, api):
        m = api.map_create(4, creator)
        seen["lid"] = api.map_get(m, 0)     # ordered before the destroy
        api.map_destroy(m)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.creator_calls == 1
    assert rt.resolve(seen["lid"]) != seen["lid"]   # resolved to a guid


# ------------------------------------------------------- remote db/event create


def test_remote_db_create_lid_path():
    """A placed db_create with EDT_PROP_LID rides the deferred-LID MCreate
    path instead of dying with 'unsupported remote-create kind'."""
    rt = Runtime(num_nodes=2, net_latency=5.0)
    out = {}

    def main(paramv, depv, api):
        lid, ptr = api.db_create(64, props=EDT_PROP_LID, placement=1)
        assert ptr is None                     # remote memory: no local ptr
        out["guid"] = api.get_guid(lid)        # §3 forced resolution
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    g = out["guid"]
    assert g.node == 1 and g.kind == ObjectKind.DATABLOCK
    assert rt.lookup(g).size == 64


def test_remote_db_create_flows_into_dependences():
    """Remote-created DB (blocking path) is acquirable end to end."""
    rt = Runtime(num_nodes=2, net_latency=2.0)
    seen = {}

    def reader(paramv, depv, api):
        seen["bytes"] = bytes(depv[0].ptr)
        return NULL_GUID

    def main(paramv, depv, api):
        db, ptr = api.db_create(16, placement=1)
        assert ptr is None and db.node == 1
        # fill it through a writer EDT on the owning node
        def writer(p, d, a):
            d[0].ptr[:] = 7
            return NULL_GUID
        wt = api.edt_template_create(writer, 0, 1)
        _, ev = api.edt_create(wt, depv=[db], dep_modes=[DbMode.EW],
                               placement=1, output_event=True)
        rt_ = api.edt_template_create(reader, 0, 2)
        api.edt_create(rt_, depv=[db, ev],
                       dep_modes=[DbMode.RO, DbMode.NULL])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.blocking_roundtrips >= 1      # the blocking create path
    assert seen["bytes"] == b"\x07" * 16


def test_remote_event_create_and_satisfy():
    rt = Runtime(num_nodes=2, net_latency=2.0)
    ran = []

    def main(paramv, depv, api):
        ev = api.event_create(EventKind.STICKY, placement=1)
        assert ev.node == 1 and ev.kind == ObjectKind.EVENT
        tmpl = api.edt_template_create(
            lambda p, d, a: ran.append(True) and NULL_GUID or NULL_GUID, 0, 1)
        api.edt_create(tmpl, depv=[ev], dep_modes=[DbMode.NULL])
        api.event_satisfy(ev)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert ran == [True]


def test_remote_event_create_lid_path():
    rt = Runtime(num_nodes=2, net_latency=5.0)
    out = {}

    def main(paramv, depv, api):
        lid = api.event_create(EventKind.STICKY, placement=1,
                               props=EDT_PROP_LID)
        out["lid"] = lid
        api.event_satisfy(lid)                 # LID-referencing msg defers
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    g = rt.resolve(out["lid"])
    assert isinstance(g, Guid) and g.node == 1
    assert rt.lookup(g).satisfied


def test_unsupported_remote_create_kind_is_actionable():
    rt = Runtime(num_nodes=2)
    with pytest.raises(OcrError, match="labeled map"):
        rt._create_object(1, "map", {})
    with pytest.raises(OcrError, match="only EDTs, data blocks and events"):
        rt._create_object(1, "file", {})
