"""Optimizer: AdamW math, schedules, int8 states, chunked big-leaf path."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (OptimizerConfig, adamw_update, global_norm,
                         init_opt_state, lr_at)
from repro.optim.adamw import _dequant_m, _dequant_v, _quant_m, _quant_v


def test_lr_schedule():
    oc = OptimizerConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(oc, jnp.asarray(0))) == 0.0
    assert abs(float(lr_at(oc, jnp.asarray(10))) - 1e-3) < 1e-9
    assert float(lr_at(oc, jnp.asarray(5))) < 1e-3
    end = float(lr_at(oc, jnp.asarray(100)))
    assert abs(end - 1e-4) < 1e-6          # min_lr_frac * peak


def test_quadratic_descent_fp32_and_int8():
    target = jnp.asarray([3.0, -2.0, 0.5, 8.0])
    for state_dtype in ("float32", "int8"):
        oc = OptimizerConfig(peak_lr=0.1, warmup_steps=1, total_steps=400,
                             weight_decay=0.0, state_dtype=state_dtype)
        params = {"w": jnp.zeros(4)}
        opt = init_opt_state(params, oc)
        for _ in range(300):
            g = {"w": 2 * (params["w"] - target)}
            params, opt, _ = adamw_update(oc, g, params, opt)
        err = float(jnp.max(jnp.abs(params["w"] - target)))
        assert err < 0.2, (state_dtype, err)


def test_int8_roundtrip_quality():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256)) * 0.1
    m = _dequant_m(_quant_m(x))
    rel = float(jnp.max(jnp.abs(m - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02
    v = jnp.square(x) + 1e-12
    v2 = _dequant_v(_quant_v(v))
    # quartic companding: small entries keep relative resolution
    big = v > 0.3 * float(v.max())
    assert float(jnp.max(jnp.abs(v2 - v) / v.max())) < 0.05


def test_grad_clipping():
    oc = OptimizerConfig(peak_lr=1e-2, warmup_steps=1, total_steps=10,
                         clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params, oc)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, metrics = adamw_update(oc, g, params, opt)
    assert float(metrics["grad_norm"]) == 100.0


def test_chunked_update_matches_unchunked(monkeypatch):
    """The big-leaf layer-by-layer (in-place scan) path must equal the
    whole-leaf math bit-for-bit."""
    from repro.optim import adamw
    key = jax.random.PRNGKey(1)
    p = jax.random.normal(key, (8, 64))
    g = jax.random.normal(jax.random.fold_in(key, 1), (8, 64))
    for state_dtype in ("float32", "int8"):
        oc = OptimizerConfig(peak_lr=1e-2, warmup_steps=1, total_steps=10,
                             state_dtype=state_dtype)
        params = {"w": p}
        opt = init_opt_state(params, oc)
        p_ref, opt_ref, _ = adamw_update(oc, {"w": g}, params, opt)
        monkeypatch.setattr(adamw, "CHUNK_BYTES", 16)   # force chunked
        p_chk, opt_chk, _ = adamw_update(oc, {"w": g}, params, opt)
        monkeypatch.setattr(adamw, "CHUNK_BYTES", 128 * 1024 * 1024)
        np.testing.assert_allclose(np.asarray(p_ref["w"]),
                                   np.asarray(p_chk["w"]),
                                   atol=1e-6, rtol=1e-6)
        for a, b in zip(jax.tree_util.tree_leaves(opt_ref["m"]),
                        jax.tree_util.tree_leaves(opt_chk["m"])):
            np.testing.assert_allclose(
                np.asarray(a, dtype=np.float32),
                np.asarray(b, dtype=np.float32), atol=2e-5, rtol=1e-5)


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert abs(float(global_norm(t)) - 5.0) < 1e-6
