"""Indexed waiter-wakeup scheduler: FIFO fairness, no starvation, O(1)
retries per release (observable via ``Stats.waiter_wakeups``), and the
``run(until=...)`` resume ordering fix."""
import numpy as np
import pytest

from repro.core import (DbMode, NULL_GUID, Runtime, UNINITIALIZED_GUID,
                        spawn_main)
from repro.core.messages import MSatisfy


def _contend(num_waiters, mode=DbMode.RW, duration=1.0):
    rt = Runtime(num_nodes=1)
    order = []

    def w(paramv, depv, api):
        order.append((paramv[0], api.rt.clock))
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(64)
        api.db_release(db)
        tmpl = api.edt_template_create(w, 1, 1)
        for i in range(num_waiters):
            api.edt_create(tmpl, paramv=[i], depv=[db], dep_modes=[mode],
                           duration=duration)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    return order, stats


def test_contention_fifo_grant_order():
    """Waiters on one DB are granted in arrival (FIFO) order."""
    order, stats = _contend(32)
    assert [i for i, _ in order] == list(range(32))
    # fully serialized: each RW holder occupies its whole duration
    assert stats.makespan == 32.0


def test_contention_wakeups_linear_not_quadratic():
    """One release retries O(1) waiters, not the whole queue: the wakeup
    count stays linear in W where the seed scheduler did W·(W+1)/2."""
    _, s64 = _contend(64)
    _, s256 = _contend(256)
    assert s64.waiter_wakeups <= 4 * 64
    assert s256.waiter_wakeups <= 4 * 256
    # and it actually scales linearly between the two sizes
    assert s256.waiter_wakeups <= 5 * s64.waiter_wakeups


def test_virtual_makespan_unchanged_by_scheduler():
    """The wakeup indexing is a wall-time optimization only: virtual-time
    makespans match full serialization exactly."""
    for w in (2, 8, 64):
        _, stats = _contend(w, duration=10.0)
        assert stats.makespan == 10.0 * w


def test_writer_not_starved_behind_reader_stream():
    """A writer queued before later readers runs before them (FIFO head
    priority), and readers behind it are then granted together."""
    rt = Runtime(num_nodes=1)
    events = []

    def holder(paramv, depv, api):
        events.append(("holder", api.rt.clock))
        return NULL_GUID

    def writer(paramv, depv, api):
        events.append(("writer", api.rt.clock))
        return NULL_GUID

    def reader(paramv, depv, api):
        events.append((paramv[0], api.rt.clock))
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(64)
        api.db_release(db)
        h = api.edt_template_create(holder, 0, 1)
        wt = api.edt_template_create(writer, 0, 1)
        rd = api.edt_template_create(reader, 1, 1)
        api.edt_create(h, depv=[db], dep_modes=[DbMode.RW], duration=5)
        api.edt_create(wt, depv=[db], dep_modes=[DbMode.RW], duration=5)
        api.edt_create(rd, paramv=["r1"], depv=[db], dep_modes=[DbMode.RO],
                       duration=5)
        api.edt_create(rd, paramv=["r2"], depv=[db], dep_modes=[DbMode.RO],
                       duration=5)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    t = dict(events)
    assert t["writer"] < t["r1"] and t["writer"] < t["r2"]
    assert t["r1"] == t["r2"]            # readers share the block
    assert stats.makespan == t["r1"] + 5


def _barge_setup(num_readers, bound, long_hold=10.0):
    """W0 holds RW; behind it queue: R_long (RO), Writer (RW), R1..Rn (RO).
    When W0 releases, R_long is granted, the Writer re-blocks on it, and
    the readers behind the Writer are candidates for batch granting."""
    rt = Runtime(num_nodes=1, reader_batch_bound=bound)
    t = {}

    def task(paramv, depv, api):
        t[paramv[0]] = api.rt.clock
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(64)
        api.db_release(db)
        tmpl = api.edt_template_create(task, 1, 1)
        api.edt_create(tmpl, paramv=["w0"], depv=[db],
                       dep_modes=[DbMode.RW], duration=3)
        api.edt_create(tmpl, paramv=["r_long"], depv=[db],
                       dep_modes=[DbMode.RO], duration=long_hold)
        api.edt_create(tmpl, paramv=["writer"], depv=[db],
                       dep_modes=[DbMode.RW], duration=5)
        for i in range(num_readers):
            api.edt_create(tmpl, paramv=[f"r{i}"], depv=[db],
                           dep_modes=[DbMode.RO], duration=1)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    return t, stats


def test_reader_batch_grant_behind_blocked_writer():
    """RO waiters queued behind a blocked writer share the block in the
    same wake batch instead of serializing after the writer."""
    t, stats = _barge_setup(num_readers=4, bound=8)
    # readers barged at the wake that re-blocked the writer (t=3), and the
    # writer still ran as soon as the long reader released
    for i in range(4):
        assert t[f"r{i}"] == 3.0, t
    assert t["writer"] == 3.0 + 10.0
    assert stats.reader_batch_grants == 4
    assert stats.makespan == 3.0 + 10.0 + 5.0


def test_reader_batch_grant_bound_is_cumulative_per_head():
    """The cap is per blocked head across its whole wait, not per wake:
    at bound=2 exactly two readers ever overtake the writer — the rest
    stay FIFO behind it (no cascade, no starvation under a backlog)."""
    t, stats = _barge_setup(num_readers=6, bound=2)
    starts = sorted(t[f"r{i}"] for i in range(6))
    # 2 barge at the t=3 wake; their releases do NOT re-open the scan for
    # this head (barged_past == bound); the other 4 follow the writer
    assert starts == [3.0, 3.0, 18.0, 18.0, 18.0, 18.0], t
    assert stats.reader_batch_grants == 2
    assert t["writer"] == 13.0      # still exactly when r_long released


def test_reader_batch_grant_disabled_at_zero_bound():
    """bound=0 restores the strict-FIFO seed behavior: readers behind the
    blocked writer wait for it."""
    t, stats = _barge_setup(num_readers=4, bound=0)
    assert stats.reader_batch_grants == 0
    assert t["writer"] == 13.0
    for i in range(4):
        assert t[f"r{i}"] == 18.0   # after the writer, strict FIFO


def test_wake_on_partition_teardown():
    """A waiter parked on a partitioned parent wakes when the last
    partition is destroyed — not on unrelated releases."""
    rt = Runtime(num_nodes=1)
    seen = {}

    def child(paramv, depv, api):
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def parent_task(paramv, depv, api):
        seen["parent_at"] = api.rt.clock
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(64)
        api.db_release(db)
        parts = api.db_partition(db, [(0, 32), (32, 32)])
        ct = api.edt_template_create(child, 0, 1)
        pt = api.edt_template_create(parent_task, 0, 1)
        api.edt_create(ct, depv=[parts[0]], dep_modes=[DbMode.EW], duration=3)
        api.edt_create(ct, depv=[parts[1]], dep_modes=[DbMode.EW], duration=7)
        api.edt_create(pt, depv=[db], dep_modes=[DbMode.RO], duration=1)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert seen["parent_at"] >= 7        # waited for the slower partition


def test_deadlock_check_cached_per_edt():
    """The §6.2 ancestor walk runs once per EDT even when the task is
    retried many times from the waiter queue."""
    rt = Runtime(num_nodes=1)
    walks = [0]
    orig = Runtime._check_deadlock

    def counting(self, deps):
        walks[0] += 1
        return orig(self, deps)

    rt._check_deadlock = counting.__get__(rt)

    def w(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(64)
        api.db_release(db)
        tmpl = api.edt_template_create(w, 0, 1)
        for _ in range(16):
            api.edt_create(tmpl, depv=[db], dep_modes=[DbMode.RW], duration=1)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    # one walk per EDT (16 workers + main), regardless of retries
    assert walks[0] == 17


def test_run_until_preserves_same_timestamp_order():
    """Interrupting run() with ``until`` must not reorder an event against
    same-timestamp peers when it is re-pushed (the fresh-tick bug)."""

    def build():
        rt = Runtime(num_nodes=1)
        fired = []

        def w(paramv, depv, api):
            fired.append(paramv[0])
            return NULL_GUID

        def main(paramv, depv, api):
            tmpl = api.edt_template_create(w, 1, 1)
            for name in ("first", "second"):
                t, _ = api.edt_create(tmpl, paramv=[name],
                                      depv=[UNINITIALIZED_GUID])
                # hand-deliver both satisfies at the same future timestamp
                api.rt.send(MSatisfy(target=t, slot=0, db=NULL_GUID),
                            0, 0, at=5.0)
            return NULL_GUID

        spawn_main(rt, main)
        return rt, fired

    rt, fired = build()
    rt.run()
    uninterrupted = list(fired)
    assert uninterrupted == ["first", "second"]

    rt2, fired2 = build()
    rt2.run(until=3.0)      # pops the t=5 head, must re-push with its tick
    rt2.run()
    assert fired2 == uninterrupted


def test_ancestor_cache_invalidated_by_late_partitioning():
    """§6.2: a zero-copy DB_COPY_PARTITION gives dst an ancestor *after*
    its (empty) ancestor chain may have been cached — the cache must be
    invalidated so parent+partition in one task still raises."""
    from repro.core import DB_COPY_PARTITION, DB_PROP_NO_ACQUIRE
    from repro.core.objects import PartitionDeadlockError
    rt = Runtime(num_nodes=1)

    shared = {}

    def w(paramv, depv, api):
        return NULL_GUID

    def copier(paramv, depv, api):
        # runs after A parked: view gains a parent AFTER its (empty)
        # ancestor chain was cached by A's deadlock check
        api.db_copy(shared["view"], 0, shared["parent"], 0, 128,
                    DB_COPY_PARTITION)
        # B acquires parent+partition in one task: the §6.2 violation a
        # stale cached () chain would silently miss
        api.edt_create(shared["tmpl"], depv=[shared["parent"], shared["view"]],
                       dep_modes=[DbMode.RO, DbMode.RO], duration=1)
        return NULL_GUID

    def main(paramv, depv, api):
        parent, ptr = api.db_create(256)
        ptr[:] = 1
        api.db_release(parent)
        blocker, _ = api.db_create(64)
        api.db_release(blocker)
        gate, _ = api.db_create(64)
        api.db_release(gate)
        view, _ = api.db_create(128, props=DB_PROP_NO_ACQUIRE)
        tmpl1 = api.edt_template_create(w, 0, 1)
        tmpl = api.edt_template_create(w, 0, 2)
        tmplc = api.edt_template_create(copier, 0, 1)
        shared.update(parent=parent, view=view, tmpl=tmpl)
        # L1 holds blocker RW until t=10; A's _try_grant primes the
        # ancestor cache for view (empty chain) and parks on blocker —
        # without ever materializing view's buffer.  L2 holds gate until
        # t=5, so the copier runs at t=5: after A's check, before A wakes.
        api.edt_create(tmpl1, depv=[blocker],
                       dep_modes=[DbMode.RW], duration=10)
        api.edt_create(tmpl1, depv=[gate],
                       dep_modes=[DbMode.RW], duration=5)
        api.edt_create(tmpl, depv=[view, blocker],
                       dep_modes=[DbMode.RO, DbMode.RO], duration=1)
        api.edt_create(tmplc, depv=[gate], dep_modes=[DbMode.RO])
        return NULL_GUID

    spawn_main(rt, main)
    with pytest.raises(PartitionDeadlockError):
        rt.run()


def test_reentrant_wake_does_not_strand_waiters():
    """A granted waiter's body can re-enter _wake_waiters for the same DB
    (explicit db_release + db_partition mid-body).  The outer wake loop
    must not keep working on a detached deque nor delete a queue that was
    re-created underneath it — that would strand the re-parked waiter
    forever (silent lost task)."""
    from repro.core import DB_COPY_PARTITION_BACK
    rt = Runtime(num_nodes=1)
    ran = []
    shared = {}

    def h(paramv, depv, api):
        return NULL_GUID

    def e1(paramv, depv, api):
        # release X: re-enters _wake_waiters(X) on the deque the outer
        # loop is iterating (and pops its dict entry)
        api.db_release(shared["x"])
        # partition X: makes it unavailable in any mode (§6.2)
        part = api.db_partition(shared["x"], [(0, 32)])[0]
        # release Y: wakes E4, which re-parks on X in a *new* deque
        api.db_release(shared["y"])
        # destroying the partition later re-enables X and must wake E4
        api.db_destroy(part)
        ran.append("e1")
        return NULL_GUID

    def e4(paramv, depv, api):
        ran.append("e4")
        return NULL_GUID

    def main(paramv, depv, api):
        x, _ = api.db_create(64)
        api.db_release(x)
        y, _ = api.db_create(64)
        api.db_release(y)
        shared["x"], shared["y"] = x, y
        tmpl_h = api.edt_template_create(h, 0, 2)
        tmpl_1 = api.edt_template_create(e1, 0, 2)
        tmpl_4 = api.edt_template_create(e4, 0, 2)
        api.edt_create(tmpl_h, depv=[x, y],
                       dep_modes=[DbMode.RW, DbMode.RW], duration=5)
        api.edt_create(tmpl_1, depv=[x, y],
                       dep_modes=[DbMode.RW, DbMode.RW], duration=1)
        api.edt_create(tmpl_4, depv=[y, x],
                       dep_modes=[DbMode.RW, DbMode.RW], duration=1)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert ran == ["e1", "e4"]          # E4 must eventually execute
    assert stats.tasks_executed == 4    # main + H + E1 + E4
    assert not rt._db_waiters           # nothing left parked


def test_batched_copy_not_reordered_past_partition_back():
    """A batchable plain copy issued BEFORE a non-batchable
    DB_COPY_PARTITION_BACK targeting overlapping bytes must land first
    (arrival order), not be deferred past it by the flush event."""
    from repro.core import DB_COPY_PARTITION_BACK
    rt = Runtime()
    out = {}

    def main(paramv, depv, api):
        d, dptr = api.db_create(128)
        dptr[:] = 0
        api.db_release(d)
        s, sptr = api.db_create(128)
        sptr[:] = 65
        api.db_release(s)
        q, qptr = api.db_create(128)     # materialized chunk, own buffer
        qptr[:] = 66
        api.db_release(q)
        api.db_copy(d, 0, s, 0, 128)                       # arrives 1st
        api.db_copy(d, 0, q, 0, 128,
                    DB_COPY_PARTITION_BACK)                # arrives 2nd
        out["d"] = d
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    # last writer (PARTITION_BACK, byte 66) must win
    assert (rt.lookup(out["d"]).buffer == 66).all()
