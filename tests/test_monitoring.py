"""The live observability plane (``repro.monitoring``).

Contracts under test: the registry's histograms are deterministic
(fixed bucket edges, interpolated quantiles); ``Stats``/``CkptStats``
stay field-compatible views whose committed bench metrics are
bit-identical with monitoring enabled (the one-check-per-hook pattern);
the serve engine takes mid-run snapshots whose live IO gauges actually
change within one ``run()``; and IO backpressure defers an admission
that the page/slot-only gate would have accepted.
"""
import json
import os
import sys

import numpy as np
import pytest

from repro.core import DbMode, NULL_GUID, Runtime, spawn_main
from repro.core.runtime import Stats
from repro.monitoring import DEFAULT_LATENCY_EDGES, Histogram, Registry

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

_SNAPDIR = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "snapshots")


def _snapshot(name):
    with open(os.path.join(_SNAPDIR, f"BENCH_{name}.json")) as f:
        return json.load(f)


# ------------------------------------------------------------- registry units


def test_registry_counters_gauges_and_snapshot_order():
    reg = Registry()
    reg.declare("a.count", 0)
    reg.inc("a.count")
    reg.inc("a.count", 3)
    reg.set("b.gauge", 2.5)
    assert reg.value("a.count") == 4
    assert reg.value("missing", default=-1) == -1
    snap = reg.snapshot()
    assert list(snap) == sorted(snap)
    assert snap == {"a.count": 4, "b.gauge": 2.5}
    # prefix filtering
    assert reg.snapshot("a.") == {"a.count": 4}


def test_histogram_deterministic_quantiles():
    h = Histogram("lat")
    assert h.quantile(0.5) == 0.0          # empty
    for x in (0.001, 0.002, 0.004, 0.008, 0.5):
        h.observe(x)
    assert h.count == 5
    assert h.total == pytest.approx(0.515)
    # order-independence: same observations, any order, same quantiles
    h2 = Histogram("lat")
    for x in (0.5, 0.004, 0.001, 0.008, 0.002):
        h2.observe(x)
    assert h.quantile(0.5) == h2.quantile(0.5)
    assert h.quantile(0.99) == h2.quantile(0.99)
    # quantiles are bracketed by the observation range's buckets
    assert 0.0 < h.quantile(0.5) < 0.5
    assert h.quantile(0.99) <= DEFAULT_LATENCY_EDGES[-1]
    # overflow clamps to the last edge
    ho = Histogram("big")
    ho.observe(1e9)
    assert ho.quantile(0.99) == DEFAULT_LATENCY_EDGES[-1]
    # summary contributes the four derived keys
    assert set(h.summary()) == {"lat.count", "lat.sum", "lat.p50", "lat.p99"}


def test_registry_histogram_in_snapshot():
    reg = Registry()
    reg.histogram("edt.execute.step").observe(0.25)
    snap = reg.snapshot()
    assert snap["edt.execute.step.count"] == 1
    assert snap["edt.execute.step.sum"] == pytest.approx(0.25)


# ------------------------------------------------------- Stats as a view


def test_stats_view_is_field_compatible():
    st = Stats()
    st.messages_sent += 7
    st.makespan = 3.5
    assert st.messages_sent == 7
    # the same numbers are visible under the dotted registry names
    assert st.registry.value("runtime.messages_sent") == 7
    assert st.registry.value("runtime.makespan") == 3.5
    snap = st.snapshot()
    assert snap["messages_sent"] == 7
    assert snap["makespan"] == 3.5
    # zero-value types survive (ints stay ints, floats stay floats)
    assert isinstance(snap["tasks_executed"], int)
    assert isinstance(snap["io_overlap_ticks"], float)


def test_runtime_stats_share_registry():
    rt = Runtime()
    assert rt.stats.registry is rt.registry

    def main(paramv, depv, api):
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert rt.registry.value("runtime.tasks_executed") == \
        stats.tasks_executed > 0


# ------------------------------------------- determinism vs committed benches


def test_contention_bench_bit_identical_with_monitoring(monkeypatch):
    """The PR 9 sanitize=off contract applied to monitoring hooks: with
    REPRO_MONITOR=1 every virtual metric of bench_contention matches the
    committed (monitor-off) snapshot bit for bit."""
    monkeypatch.setenv("REPRO_MONITOR", "1")
    from benchmarks.bench_contention import _contend
    stats, _wall = _contend(256)
    want = _snapshot("contention")
    assert stats.makespan == want["makespan"]
    assert stats.messages_sent == want["messages_sent"]
    assert stats.waiter_wakeups == want["waiter_wakeups"]


def test_serve_bench_bit_identical_with_monitoring():
    """bench_serve runs its engines with monitor=True; every virtual
    metric must match the committed snapshot exactly, and the new
    histogram-sourced p99 keys must be populated."""
    from benchmarks.bench_serve import _LOADS, _head_to_head
    cont, stat = _head_to_head(*_LOADS[0][1:])
    want = _snapshot("serve")
    assert cont["makespan_s"] == want["makespan_continuous"]
    assert cont["tok_per_s"] == want["tok_per_s_continuous"]
    assert cont["p99_latency_s"] == want["p99_latency_s_continuous"]
    assert stat["p99_latency_s"] == want["p99_latency_s_static"]
    assert cont["creator_calls"] == want["creator_calls"]
    assert cont["p99_hist_latency_s"] == want["p99_hist_latency_s_continuous"]
    assert cont["p99_hist_ttft_s"] == want["p99_hist_ttft_s_continuous"]
    assert cont["p99_hist_latency_s"] > 0.0


# ------------------------------------------------- EDT-class histograms


def test_per_edt_class_latency_histograms():
    rt = Runtime(monitor=True)

    def worker(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(16)
        api.db_release(db)
        tmpl = api.edt_template_create(worker, 0, 1)
        for _ in range(4):      # serialize in RW: nonzero grant waits
            api.edt_create(tmpl, depv=[db], dep_modes=[DbMode.RW],
                           duration=1.0)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    snap = rt.registry.snapshot("edt.")
    assert snap["edt.execute.worker.count"] == 4
    assert snap["edt.grant_wait.worker.count"] == 4
    # the last waiter waited ~3 time units behind the other three
    assert snap["edt.grant_wait.worker.sum"] > 0.0
    assert snap["edt.execute.main.count"] == 1


# ------------------------------------------------- serve engine: live gauges


def _spill_engine(**kw):
    from repro.serve.engine import ServeEngine, SyntheticBackend
    return ServeEngine(SyntheticBackend(page_size=8), b_cap=8,
                       pool_pages=20, max_pages=6, resident_budget=4,
                       **kw)


def _spill_load():
    from repro.serve.engine import poisson_workload
    return poisson_workload(30, 300.0, prompt_len=(8, 24), gen=(8, 24),
                            seed=1)


def test_mid_run_snapshots_show_live_io():
    """Mid-run snapshot() from inside the serve loop: queue depth and
    inflight IO are live — their values change across snapshots taken
    within one run() (the acceptance criterion)."""
    eng = _spill_engine(monitor_interval=0.005)
    eng.run(_spill_load())
    snaps = eng.monitor_snapshots
    assert len(snaps) >= 3
    inflight = [s["io.inflight_ops"] for s in snaps]
    depth = [s["io.queue_depth"] for s in snaps]
    assert len(set(inflight)) >= 2, inflight
    assert max(inflight) > 0
    assert max(depth) >= 0
    # engine gauges ride the same registry
    assert any(s["serve.active"] > 0 for s in snaps)
    assert all("spill.objects" in s for s in snaps)


def test_engine_monitor_callable_between_runs():
    eng = _spill_engine(monitor=True)
    snap = eng.monitor()
    assert snap["serve.free_slots"] == 8
    assert snap["serve.queued"] == 0
    assert snap["io.inflight_ops"] == 0


# ------------------------------------------------- backpressure admission


def test_backpressure_defers_admission_page_gate_would_accept():
    """With admit_max_inflight_io=0, any in-flight spill/unspill IO
    defers admissions even while pages and slots are free — the
    page/slot-only engine admits the same request earlier."""
    gated = _spill_engine(admit_max_inflight_io=0)
    reqs_g = _spill_load()
    m_gated = gated.run(reqs_g)
    assert gated.deferred_admissions > 0
    assert m_gated["deferred_admissions"] > 0
    # the deferral happened at an instant where page/slot gating alone
    # would have admitted: same workload, no gate, admits strictly
    # earlier for at least one request (and never later for any)
    plain = _spill_engine(monitor=True)
    reqs_p = _spill_load()
    m_plain = plain.run(reqs_p)
    assert plain.deferred_admissions == 0
    firsts_g = {r.rid: r.t_first for r in reqs_g}
    firsts_p = {r.rid: r.t_first for r in reqs_p}
    assert any(firsts_g[rid] > firsts_p[rid] for rid in firsts_g)
    # gating must not lose work
    assert all(len(r.out) == r.gen for r in reqs_g)
    assert m_gated["tokens"] == m_plain["tokens"]
    # the deferred count lands in the serve.* namespace too
    assert gated.monitor()["serve.deferred_admissions"] > 0


# ------------------------------------------------- ckpt registry namespace


def test_ckpt_stats_registry_view(tmp_path):
    from repro import ckpt
    state = {"w": np.arange(64, dtype=np.float32).reshape(8, 8)}
    stats = ckpt.save(str(tmp_path), state, step=1)
    assert stats.committed
    assert stats.chunks_written > 0
    # the view writes through to the ckpt.* namespace of the save
    # runtime's registry — one snapshot shows ckpt.* next to io.*
    snap = stats.registry.snapshot()
    assert snap["ckpt.chunks_written"] == stats.chunks_written
    assert snap["ckpt.committed"] is True
    assert "io.write_ops" in snap
    assert snap["io.write_ops"] == stats.io_write_ops
