"""The trip-count-aware HLO cost parser vs known-cost programs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_cost


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_dot_flops_exact():
    m, k, n = 128, 256, 64
    f = lambda a, b: a @ b
    c = _compile(f, jax.ShapeDtypeStruct((m, k), jnp.float32),
                 jax.ShapeDtypeStruct((k, n), jnp.float32))
    cost = hlo_cost.analyze(c.as_text())
    assert cost.flops == 2 * m * k * n


def test_scan_trip_count_multiplies():
    def mk(nlayers):
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y
        return _compile(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                        jax.ShapeDtypeStruct((nlayers, 64, 64), jnp.float32))

    c2 = hlo_cost.analyze(mk(2).as_text())
    c8 = hlo_cost.analyze(mk(8).as_text())
    assert c8.flops == pytest.approx(4 * c2.flops, rel=1e-6)
    # XLA's own cost_analysis counts the body once (the bug we fix).
    # jax < 0.5 returns a one-element list of dicts, newer a dict.
    def raw_flops(compiled):
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return ca["flops"]

    assert raw_flops(mk(2)) == raw_flops(mk(8))


def test_nested_scan_multiplies():
    def f(x, ws):
        def outer(c, wg):
            def inner(c2, w):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, wg)
            return c, None
        y, _ = jax.lax.scan(outer, x, ws)
        return y
    c = _compile(f, jax.ShapeDtypeStruct((32, 32), jnp.float32),
                 jax.ShapeDtypeStruct((3, 5, 32, 32), jnp.float32))
    cost = hlo_cost.analyze(c.as_text())
    assert cost.flops == pytest.approx(15 * 2 * 32 * 32 * 32, rel=1e-6)


def test_bytes_scale_with_trip_count():
    def mk(n):
        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c * w), None
            y, _ = jax.lax.scan(body, x, ws)
            return y
        return _compile(f, jax.ShapeDtypeStruct((256, 256), jnp.float32),
                        jax.ShapeDtypeStruct((n, 256, 256), jnp.float32))
    b2 = hlo_cost.analyze(mk(2).as_text()).bytes
    b8 = hlo_cost.analyze(mk(8).as_text()).bytes
    assert b8 > 3 * b2


def test_collective_parsing_shapes():
    import os
    import subprocess, sys, textwrap
    # needs >1 device: run in a subprocess with forced host devices
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        import sys
        sys.path.insert(0, "src")
        from repro.launch import hlo_cost
        mesh = jax.make_mesh((8,), ("model",))
        def f(x):
            return jnp.sum(x)
        fn = jax.jit(f, in_shardings=NamedSharding(mesh, P("model")),
                     out_shardings=NamedSharding(mesh, P()))
        c = fn.lower(jax.ShapeDtypeStruct((1024,), jnp.float32)).compile()
        cost = hlo_cost.analyze(c.as_text())
        assert cost.coll_counts["all-reduce"] >= 1, cost.coll_counts
        print("OK")
    """)
    env = dict(os.environ)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, cwd=os.path.dirname(
                             os.path.dirname(os.path.abspath(__file__))),
                         env=env)
    assert "OK" in out.stdout, out.stderr[-2000:]
