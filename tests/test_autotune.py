"""Trace-time block autotuner: planner invariants, gradcheck parity at
autotuned (non-default) blocks, and the MLA absorbed-flash training path."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import autotune
from repro.kernels import ops as kops
from repro.models import attention


# --------------------------------------------------------- planner units

@pytest.mark.parametrize("sq,hd", [
    (100, 16),      # ragged, below one default tile
    (68, 64),       # context-parallel stripe size
    (640, 64),      # non-pow2 multiple of MIN_BLOCK
    (1024, 64),
    (1024, 128),
    (4096, 128),
])
def test_plan_bwd_blocks_divide_fwd_padded_seq(sq, hd):
    """Every backward tile must divide the forward-padded sequence, so
    the dq/dkv grids and the fused kernel revisit exactly the rows the
    forward padded — no second padding pass, no overhang."""
    plan = autotune.plan_attention(sq, sq, hd, hd, 2, 2, 1, 32,
                                   True, 0, sq, backend="interpret")
    sq_p = -(-sq // plan.block_q) * plan.block_q
    sk_p = -(-sq // plan.block_k) * plan.block_k
    assert sq_p % plan.dq_block_q == 0
    assert sq_p % plan.dkv_block_q == 0
    assert sk_p % plan.dq_block_k == 0
    assert sk_p % plan.dkv_block_k == 0
    assert plan.g_fold in (1, 2) and 2 % plan.g_fold == 0


@pytest.mark.parametrize("budget_mb", [1, 2, 4])
def test_plan_respects_vmem_budget(budget_mb):
    """Unpinned plans never exceed the per-kernel VMEM budget — the hard
    constraint of the cost model (checked on the tpu backend, whose mega
    gate also runs under the same budget)."""
    budget = budget_mb * 2 ** 20
    plan = autotune.plan_attention(2048, 2048, 128, 128, 4, 2, 1, 32,
                                   True, 0, 2048, backend="tpu",
                                   vmem_budget=budget)
    assert plan.vmem_bytes <= budget
    # a 2048² problem's materialized softmax transients are way past a
    # few MiB of VMEM: the single-step megakernels must be rejected
    assert not plan.mega_fwd and not plan.mega_bwd


def test_plan_budget_monotone_blocks():
    """A larger budget never picks a *more* expensive plan: the best cost
    under budget B is ≥ the best cost under B' > B (superset search)."""
    small = autotune.plan_attention(1024, 1024, 128, 128, 2, 2, 1, 32,
                                    True, 0, 1024, backend="tpu",
                                    vmem_budget=2 * 2 ** 20)
    large = autotune.plan_attention(1024, 1024, 128, 128, 2, 2, 1, 32,
                                    True, 0, 1024, backend="tpu",
                                    vmem_budget=12 * 2 ** 20)
    assert small.vmem_bytes <= 2 * 2 ** 20
    assert large.block_q * large.block_k >= small.block_q * small.block_k


def test_edge_waste_zero_at_multiples_monotone_between():
    block = 128
    for m in (1, 2, 5):
        assert autotune.edge_waste(m * block, block) == 0.0
    # between multiples the dead fraction only shrinks as live rows grow
    prev = float("inf")
    for seq in range(129, 257):
        w = autotune.edge_waste(seq, block)
        assert w <= prev
        assert w >= 0.0
        prev = w
    assert autotune.edge_waste(256, block) == 0.0


def test_plan_override_pins_blocks_verbatim():
    """Config overrides win over the model: odd hand-picked tiles ride
    through to both fwd and bwd, and the structural escapes (mega
    kernels) stay off so the pinned layout is what actually runs."""
    plan = autotune.plan_attention(512, 512, 64, 64, 2, 2, 1, 32,
                                   True, 0, 512, backend="interpret",
                                   block_q=48, block_k=80)
    assert plan.block_q == 48 and plan.block_k == 80
    assert (plan.dq_block_q, plan.dq_block_k) == (48, 80)
    assert (plan.dkv_block_q, plan.dkv_block_k) == (48, 80)
    assert not plan.mega_fwd and not plan.mega_bwd
    # clamped to the sequence, the historical min(block, seq) behavior
    clamped = autotune.plan_attention(100, 100, 64, 64, 2, 2, 1, 32,
                                      True, 0, 100, backend="interpret",
                                      block_q=512, block_k=512)
    assert clamped.block_q == 100 and clamped.block_k == 100


def test_plan_is_deterministic_and_cached():
    args = (768, 768, 64, 64, 2, 2, 1, 32, True, 48, 768)
    assert autotune.plan_attention(*args) is autotune.plan_attention(*args)


def test_flash_min_seq_floor_derives_from_min_block():
    """With no block override the flash threshold floor is 2·min_block()
    — the autotuner's smallest plannable stripe — not a stale tile
    constant; an explicit attn_block_q raises the floor with it."""
    import types
    cfg = types.SimpleNamespace(attn_block_q=None, attn_flash_min_seq=8)
    assert attention.flash_min_seq(cfg) == 2 * autotune.min_block()
    cfg = types.SimpleNamespace(attn_block_q=64, attn_flash_min_seq=8)
    assert attention.flash_min_seq(cfg) == 128
    cfg = types.SimpleNamespace(attn_block_q=None, attn_flash_min_seq=2048)
    assert attention.flash_min_seq(cfg) == 2048


def test_plan_decode_block_divides_cache():
    for seq in (256, 1024, 4096, 32768):
        b = autotune.plan_decode(seq, 2, 64, 64, 32, backend="interpret")
        assert seq % b == 0 and b >= autotune.MIN_BLOCK
    # explicit block_s wins (clamped to the cache length)
    assert autotune.plan_decode(1024, 2, 64, 64, 32, block_s=256) == 256
    assert autotune.plan_decode(128, 2, 64, 64, 32, block_s=512) == 128


def test_plan_decode_serve_page_aligned_shapes():
    """The serve engine's paged KV cache presents lengths that are page
    multiples, not powers of two (3 pages, 5 pages, ...).  Every such
    length must still get a dividing block so the decode grid has no
    overhang row."""
    for page in (64, 128):
        for pages in (1, 2, 3, 5, 6, 7, 12):
            seq = pages * page
            blk = autotune.plan_decode(seq, 2, 32, 32, 32,
                                       backend="interpret")
            assert seq % blk == 0 and blk >= autotune.MIN_BLOCK


def test_plan_serve_batch_picks_batch_tiled_mega():
    """Serving batch sizes: the full-batch softmax transient blows
    MEGA_BUDGET, but one batch row's worth fits — the planner falls back
    to the grid-over-B mega variant instead of abandoning the flat
    single-step chain."""
    plan = autotune.plan_attention(512, 512, 64, 64, 4, 2, 16, 32,
                                   True, 0, 512, backend="interpret")
    assert plan.mega_fwd_bt and not plan.mega_fwd
    assert plan.mega_bwd_bt and not plan.mega_bwd
    # the budget accounting must be per batch row, not the full tensor
    assert plan.vmem_bytes <= autotune.MEGA_BUDGET["interpret"]
    # batch 1 has no separate bt variant — it IS the full mega
    single = autotune.plan_attention(512, 512, 64, 64, 4, 2, 1, 32,
                                     True, 0, 512, backend="interpret")
    assert not single.mega_fwd_bt and not single.mega_bwd_bt


def test_batch_tiled_mega_gradcheck_vs_twin():
    """The batch-tiled mega kernels reuse the full-batch bodies with b=1
    blocks and a (B,) grid; values AND grads must match the jnp twin,
    causal and windowed."""
    from repro.kernels import flash_attention as fa

    base = autotune.plan_attention(128, 128, 32, 32, 2, 2, 4, 32,
                                   True, 0, 128, backend="interpret")
    plan = dataclasses.replace(base, mega_fwd=False, mega_bwd=False,
                               mega_fwd_bt=True, mega_bwd_bt=True)
    q, k, v = _mk(jax.random.PRNGKey(3), 4, 128, 4, 2, 32)

    def tr(x):
        return jnp.transpose(x, (0, 2, 1, 3))   # model -> kernel layout

    for window in (0, 48):
        def loss_bt(q_, k_, v_):
            out = fa.flash_attention(tr(q_), tr(k_), tr(v_), causal=True,
                                     window=window, interpret=True,
                                     plan=plan)
            return jnp.sum(jnp.sin(out))

        def loss_twin(q_, k_, v_):
            out = attention.flash_attention_jnp(
                q_, k_, v_, jnp.zeros((), jnp.float32), True, window)
            return jnp.sum(jnp.sin(tr(out)))

        vp, gp = jax.value_and_grad(loss_bt, argnums=(0, 1, 2))(q, k, v)
        vt, gt = jax.value_and_grad(loss_twin, argnums=(0, 1, 2))(q, k, v)
        np.testing.assert_allclose(float(vp), float(vt),
                                   atol=3e-4, rtol=1e-5)
        for a, b_ in zip(gp, gt):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=3e-4, rtol=1e-4)


def test_plan_copy_chunk_fits_budget():
    for rows in (256, 4096, 131072, 1 << 20):
        chunk = autotune.plan_copy_chunk(rows, 12 * 2 ** 20)
        assert chunk >= autotune.MIN_BLOCK
        assert 3 * chunk * autotune.LANES <= 12 * 2 ** 20 + 3 * autotune.LANES


# --------------------- gradcheck parity at autotuned (None) block sizes

def _mk(key, b, s, h, kh, hd):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kh, hd))
    v = jax.random.normal(ks[2], (b, s, kh, hd))
    return q, k, v


@pytest.mark.parametrize("s,h,kh,window", [
    (100, 4, 2, 0),     # ragged + GQA
    (65, 4, 2, 24),     # ragged + sliding window
    (96, 3, 1, 0),      # MQA, odd head count
    (384, 4, 2, 48),    # multi-tile + window
])
def test_autotuned_blocks_gradcheck_vs_twin(s, h, kh, window):
    """block_q=block_k=None routes through the planner; values AND grads
    must match the jnp twin at whatever layout it picked — including the
    single-step megakernels the fixed-constant path never had."""
    plan = autotune.plan_attention(s, s, 16, 16, h // kh, kh, 1, 32,
                                   True, window, s, backend="interpret")
    # the point of the test: the planner chose something other than the
    # old fixed default layout
    assert (plan.mega_fwd or plan.mega_bwd or plan.block_q != 128
            or plan.g_fold > 1)

    q, k, v = _mk(jax.random.PRNGKey(s + h + window), 1, s, h, kh, 16)

    def loss_pallas(q_, k_, v_):
        out = kops.flash_attention(q_, k_, v_, causal=True, window=window,
                                   interpret=True)
        return jnp.sum(jnp.sin(out))

    def loss_twin(q_, k_, v_):
        out = attention.flash_attention_jnp(
            q_, k_, v_, jnp.zeros((), jnp.float32), True, window)
        return jnp.sum(jnp.sin(out))

    vp, gp = jax.value_and_grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    vt, gt = jax.value_and_grad(loss_twin, argnums=(0, 1, 2))(q, k, v)
    np.testing.assert_allclose(float(vp), float(vt), atol=3e-4, rtol=1e-5)
    for a, b_ in zip(gp, gt):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=3e-4, rtol=1e-4)


# ------------------------------- MLA trains on the flash VJP (absorbed)

def _mla_setup(seq=48, batch=2):
    cfg = get_config("deepseek-v2-236b").reduced()
    # drop the flash threshold so a smoke-sized sequence takes the
    # absorbed-MQA Pallas path (floor becomes 2·min_block() = 32 < 48)
    flash_cfg = dataclasses.replace(cfg, attn_flash_min_seq=16)
    dense_cfg = dataclasses.replace(cfg, attn_flash_min_seq=1 << 20)
    assert seq > attention.flash_min_seq(flash_cfg)
    assert seq <= attention.flash_min_seq(dense_cfg)
    params = attention.mla_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, seq, cfg.d_model))
    positions = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
    return flash_cfg, dense_cfg, params, x, positions


def test_mla_train_flash_path_runs_pallas_not_dense(monkeypatch):
    """Above the threshold mla_train must go through the absorbed flash
    kernel and never touch the dense reference."""
    flash_cfg, dense_cfg, params, x, positions = _mla_setup()

    def boom(*a, **kw):
        raise AssertionError("dense full_attention reached on flash path")
    monkeypatch.setattr(attention, "full_attention", boom)

    out = attention.mla_train(params, x, flash_cfg, positions)
    assert out.shape == x.shape
    with pytest.raises(AssertionError, match="dense full_attention"):
        attention.mla_train(params, x, dense_cfg, positions)


def test_mla_flash_bwd_matches_dense():
    """Loss AND grads (params and activations) of the absorbed-MQA flash
    path match the dense full-attention reference — the W_UK/W_UV
    absorption is exact up to f32 reassociation."""
    flash_cfg, dense_cfg, params, x, positions = _mla_setup()

    def loss(cfg):
        def f(p, x_):
            return jnp.sum(jnp.sin(
                attention.mla_train(p, x_, cfg, positions)))
        return f

    vf, gf = jax.value_and_grad(loss(flash_cfg), argnums=(0, 1))(params, x)
    vd, gd = jax.value_and_grad(loss(dense_cfg), argnums=(0, 1))(params, x)
    np.testing.assert_allclose(float(vf), float(vd), atol=1e-3, rtol=1e-5)
    flat_f, _ = jax.tree_util.tree_flatten_with_path(gf)
    flat_d, _ = jax.tree_util.tree_flatten_with_path(gd)
    for (path, a), (_, b) in zip(flat_f, flat_d):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=1e-3, rtol=1e-3,
            err_msg=f"grad mismatch at {jax.tree_util.keystr(path)}")


def test_mla_prefill_flash_matches_dense_and_caches_latents():
    flash_cfg, dense_cfg, params, x, positions = _mla_setup()
    out_f, cache_f = attention.mla_prefill(params, x, flash_cfg, positions)
    out_d, cache_d = attention.mla_prefill(params, x, dense_cfg, positions)
    np.testing.assert_allclose(np.asarray(out_f), np.asarray(out_d),
                               atol=1e-4, rtol=1e-4)
    assert set(cache_f) == set(cache_d) == {"c_kv", "k_rope"}
    for k in cache_f:
        np.testing.assert_allclose(np.asarray(cache_f[k]),
                                   np.asarray(cache_d[k]),
                                   atol=1e-5, rtol=1e-5)
