"""The §6 bridge: every sharding this framework emits is a valid paper-§6
partitioning — its per-device (offset, size) ranges are accepted by the
core runtime's ``db_partition`` (which enforces the §6.2 invariants).
"""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    full = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys\nsys.path.insert(0, 'src')\n" + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", full], capture_output=True,
                         text=True, cwd=ROOT, timeout=560)
    assert out.returncode == 0 and "PASS" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])


def test_param_shardings_are_valid_section6_partitions():
    _run("""
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.dist.sharding import ShardCtx, param_shardings, partition_tree_of
    from repro.launch.specs import params_only_specs
    from repro.core import NULL_GUID, Runtime, spawn_main

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ShardCtx(mesh)

    checked = [0]
    for arch in ("llama3.2-3b", "deepseek-v2-236b", "mamba2-1.3b"):
        cfg = get_config(arch).reduced()
        shapes = params_only_specs(cfg)
        shardings = param_shardings(shapes, ctx)

        leaves = list(zip(jax.tree_util.tree_leaves(shapes),
                          jax.tree_util.tree_leaves(shardings)))
        for leaf, sh in leaves:
            parts = partition_tree_of(tuple(leaf.shape),
                                      np.dtype(leaf.dtype).itemsize, sh)
            uniq = sorted(set(parts))
            total = int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
            # replicated dims repeat ranges; distinct ranges must tile the
            # buffer disjointly — proven by handing them to db_partition
            if len(uniq) == 1:
                continue
            rt = Runtime()
            res = {}

            def main(paramv, depv, api):
                db, _ = api.db_create(total)
                api.db_release(db)
                api.db_partition(db, uniq)      # §6.2 invariants enforced
                res["ok"] = True
                return NULL_GUID

            spawn_main(rt, main)
            rt.run()
            assert res.get("ok"), (arch, leaf.shape, sh.spec, uniq[:4])
            # and they cover the buffer exactly when the leading dim shards
            assert sum(s for _, s in uniq) == total
            checked[0] += 1
    assert checked[0] >= 3, checked
    print("PASS")
    """)


def test_partition_tree_of_properties_hypothesis():
    """Property test: for random shapes × meshes × specs, the emitted
    ranges are mutually disjoint, tile the buffer exactly, pass the §6.2
    invariant checks of ``db_partition``, and are lane-aligned (128 B)
    whenever the sharded dim's contiguous run allows it."""
    import pytest
    pytest.importorskip("hypothesis")
    _run("""
    import numpy as np
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from hypothesis import given, settings, strategies as st
    from repro.core import NULL_GUID, Runtime, spawn_main
    from repro.dist.sharding import partition_tree_of

    MESHES = (((8,), ("model",)),
              ((2, 4), ("data", "model")),
              ((4, 2), ("data", "model")),
              ((2, 2, 2), ("pod", "data", "model")))

    @st.composite
    def cases(draw):
        mi = draw(st.integers(0, len(MESHES) - 1))
        mesh_shape, axes = MESHES[mi]
        ndim = draw(st.integers(1, 3))
        dims = tuple(draw(st.sampled_from((1, 2, 3, 4, 6, 8, 16, 32, 48)))
                     for _ in range(ndim))
        spec = [None] * ndim
        used = set()
        for ax, size in zip(axes, mesh_shape):
            d = draw(st.integers(-1, ndim - 1))
            if d >= 0 and d not in used and dims[d] % size == 0:
                spec[d] = ax
                used.add(d)
        itemsize = draw(st.sampled_from((1, 2, 4)))
        return mi, dims, tuple(spec), itemsize

    @settings(max_examples=80, deadline=None)
    @given(cases())
    def prop(case):
        mi, dims, spec, itemsize = case
        mesh_shape, axes = MESHES[mi]
        mesh = jax.make_mesh(mesh_shape, axes)
        sizes = dict(zip(axes, mesh_shape))
        sh = NamedSharding(mesh, P(*spec))
        parts = partition_tree_of(dims, itemsize, sh)
        assert len(parts) >= mesh.size      # >= one range per device
        total = int(np.prod(dims)) * itemsize
        uniq = sorted(set(parts))
        # disjoint + exact tiling: sorted distinct ranges chain perfectly
        off = 0
        for o, s in uniq:
            assert o == off and s > 0, (uniq, dims, spec)
            off += s
        assert off == total, (uniq, dims, spec)
        # accepted by the core runtime's db_partition (§6.2 invariants)
        if len(uniq) > 1:
            rt = Runtime()
            res = {}

            def main(paramv, depv, api):
                db, _ = api.db_create(total)
                api.db_release(db)
                api.db_partition(db, uniq)
                res["ok"] = True
                return NULL_GUID

            spawn_main(rt, main)
            rt.run()
            assert res.get("ok"), (dims, spec, uniq[:4])
        # lane alignment where the sharded dim allows: every range is a
        # multiple of the innermost contiguous run, so when that run is a
        # multiple of 128 B all offsets/sizes are lane-aligned
        sharded = [i for i, a in enumerate(spec) if a is not None]
        if sharded:
            k = sharded[-1]
            run = (dims[k] // sizes[spec[k]]) * itemsize
            run *= int(np.prod(dims[k + 1:], dtype=np.int64))
            if run % 128 == 0:
                assert all(o % 128 == 0 and s % 128 == 0 for o, s in uniq)

    prop()
    print("PASS")
    """)


def test_pure_dp_train_parity():
    """pure_dp mode must produce the same step as single-device."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.model import LanguageModel
    from repro.optim import OptimizerConfig
    from repro.train.steps import init_train_state, make_train_step
    from repro.dist.sharding import use_mesh
    from repro.data import SyntheticTokens

    cfg = get_config("smollm-360m").reduced()
    model = LanguageModel(cfg)
    oc = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=50)
    data = SyntheticTokens(cfg.vocab_size, batch=8, seq=32, seed=5)
    step = make_train_step(model, oc)
    b = {k: jnp.asarray(v) for k, v in data.get(0).items()}

    s1 = init_train_state(model, jax.random.PRNGKey(0), oc)
    s1b, m1 = jax.jit(step)(s1, b)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    s2 = init_train_state(model, jax.random.PRNGKey(0), oc)
    with use_mesh(mesh, pure_dp=True):
        s2b, m2 = jax.jit(step)(s2, b)

    assert abs(float(m1["ce_loss"]) - float(m2["ce_loss"])) < 1e-3
    for a, c in zip(jax.tree_util.tree_leaves(s1b["params"]),
                    jax.tree_util.tree_leaves(s2b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=3e-4, rtol=3e-4)
    print("PASS")
    """)
