"""Data pipelines: stateless determinism + §5 file-backed source."""
import numpy as np

from repro.data import FileTokens, SyntheticTokens
from repro.data.pipeline import write_token_file


def test_synthetic_deterministic():
    a = SyntheticTokens(100, 4, 16, seed=3)
    b = SyntheticTokens(100, 4, 16, seed=3)
    for step in (0, 5, 1000):
        x, y = a.get(step), b.get(step)
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        np.testing.assert_array_equal(x["targets"], y["targets"])
    assert not np.array_equal(a.get(1)["tokens"], a.get(2)["tokens"])


def test_targets_are_shifted():
    d = SyntheticTokens(50, 2, 8, seed=0)
    b = d.get(0)
    # targets[t] is the next token after tokens[t]
    assert b["tokens"].shape == b["targets"].shape == (2, 8)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_markov_mode_learnable():
    d = SyntheticTokens(64, 8, 64, seed=1, mode="markov")
    b = d.get(0)
    # ≥ 80% of transitions follow the affine chain
    pred = (b["tokens"] * 31 + 7) % 64
    agree = np.mean(pred == b["targets"])
    assert agree > 0.8


def test_file_tokens_roundtrip(tmp_path):
    path = str(tmp_path / "tokens.bin")
    rng = np.random.default_rng(0)
    batch, seq = 2, 16
    n_batches = 3
    raw = rng.integers(0, 1000, size=(n_batches * batch * (seq + 1),),
                       dtype=np.int32)
    write_token_file(path, raw)
    ft = FileTokens(path, vocab_size=1000, batch=batch, seq=seq)
    assert ft.num_batches() == n_batches
    for step in range(n_batches):
        got = ft.get(step)
        want = raw.reshape(-1)[step * batch * (seq + 1):
                               (step + 1) * batch * (seq + 1)]
        want = want.reshape(batch, seq + 1) % 1000
        np.testing.assert_array_equal(got["tokens"], want[:, :-1])
        np.testing.assert_array_equal(got["targets"], want[:, 1:])
    # wraps around
    np.testing.assert_array_equal(ft.get(n_batches)["tokens"],
                                  ft.get(0)["tokens"])
