"""Capacity-bucketed all-to-all MoE dispatch (repro.models.moe).

Four angles: (1) the a2a path, the legacy psum path and the single-device
oracle agree — outputs, aux loss AND gradients — on 8 forced host
devices; (2) the bucket pack/unpack custom VJPs are the true transposes
(checked against plain-autodiff references and numerically); (3) bucket
slots are disjoint and capacity-bounded for arbitrary routings
(hypothesis), and ``moe_bucket_ranges`` emits §6 partitions that
``db_partition`` accepts; (4) overflow drops are deterministic and keep
the earliest tokens (stable sort).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    full = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys\nsys.path.insert(0, 'src')\n" + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", full], capture_output=True,
                         text=True, cwd=ROOT, timeout=560)
    assert out.returncode == 0 and "PASS" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])


def test_a2a_psum_oracle_parity():
    """a2a == psum == single-device oracle: y, balance loss, grads."""
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.dist.sharding import use_mesh
    from repro.models import moe as M

    cfg = get_config("deepseek-v2-236b").reduced()   # cf=8.0: no drops
    cfg = dataclasses.replace(cfg, num_experts=8, experts_per_token=2)
    params = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

    def loss(cfg_):
        def f(p, xx):
            y, a = M.moe_ffn(p, xx, cfg_)
            return jnp.sum(y ** 2) + 0.01 * a["loss"], (y, a)
        return f

    (l_ref, (y_ref, a_ref)), g_ref = jax.value_and_grad(
        loss(cfg), has_aux=True)(params, x)          # no mesh: oracle

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    outs = {}
    for dispatch in ("a2a", "psum"):
        c = dataclasses.replace(cfg, moe_dispatch=dispatch)
        with use_mesh(mesh):
            outs[dispatch] = jax.jit(jax.value_and_grad(
                loss(c), has_aux=True))(params, x)

    for dispatch, ((l, (y, a)), g) in outs.items():
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y),
                                   atol=2e-4, rtol=2e-4, err_msg=dispatch)
        np.testing.assert_allclose(float(l_ref), float(l), rtol=1e-5,
                                   err_msg=dispatch)
        assert float(a["dropped"]) == 0.0, dispatch
        for pa, pb in zip(jax.tree_util.tree_leaves(g_ref),
                          jax.tree_util.tree_leaves(g)):
            np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                       atol=5e-3, rtol=5e-3,
                                       err_msg=dispatch)
    # the a2a gauge is live only on the a2a path
    assert float(outs["a2a"][0][1][1]["a2a_bytes"]) > 0
    assert float(outs["psum"][0][1][1]["a2a_bytes"]) == 0
    print("PASS")
    """)


def _routing_tables(key, t, e, k, capacity):
    from repro.models import moe as M
    kg, ki = jax.random.split(key)
    logits = jax.random.normal(kg, (t, e))
    gates, idx = M._route(logits, k)
    n = t * k
    flat_e = idx.reshape(n).astype(jnp.int32)
    flat_g = gates.reshape(n)
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    pos = M._expert_positions(flat_e, n)
    valid = (pos < capacity) & (flat_g > 0)
    safe_pos = jnp.where(valid, pos, capacity).astype(jnp.int32)
    w = (flat_g * valid).astype(jnp.float32)
    return flat_e, safe_pos, tok, w, valid


def test_dispatch_combine_custom_vjp_gradcheck():
    """The chunked-scan custom VJPs equal plain autodiff of the direct
    scatter/gather formulation, and pass numerical gradcheck."""
    from repro.models import moe as M
    t, e, k, cap, d = 12, 4, 2, 3, 8
    key = jax.random.PRNGKey(7)
    fe, sp, tok, w, _ = _routing_tables(key, t, e, k, cap)
    x = jax.random.normal(jax.random.PRNGKey(8), (t, d))
    yg = jax.random.normal(jax.random.PRNGKey(9), (e, cap, d))

    def ref_dispatch(xx, ww):
        acc = jnp.zeros((e, cap + 1, d))
        acc = acc.at[fe, sp].add(xx[tok] * (ww > 0)[:, None], mode="drop")
        return acc[:, :cap]

    def ref_combine(yy, ww):
        y_ext = jnp.concatenate([yy, jnp.zeros((e, 1, d))], axis=1)
        out = jnp.zeros((t, d))
        return out.at[tok].add(y_ext[fe, sp] * ww[:, None], mode="drop")

    co = jax.random.normal(jax.random.PRNGKey(10), (e, cap, d))

    def f_cust(xx):
        return jnp.sum(M._dispatch(xx, fe, sp, tok, w, e, cap,
                                   str(x.dtype), t) * co)

    def f_ref(xx):
        return jnp.sum(ref_dispatch(xx, w) * co)

    np.testing.assert_allclose(f_cust(x), f_ref(x), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(jax.grad(f_cust)(x)),
                               np.asarray(jax.grad(f_ref)(x)), rtol=1e-5)

    ct = jax.random.normal(jax.random.PRNGKey(11), (t, d))

    def g_cust(yy, ww):
        return jnp.sum(M._combine(yy, fe, sp, tok, ww, t) * ct)

    def g_ref(yy, ww):
        return jnp.sum(ref_combine(yy, ww) * ct)

    np.testing.assert_allclose(g_cust(yg, w), g_ref(yg, w), rtol=1e-5)
    for a, b in zip(jax.grad(g_cust, argnums=(0, 1))(yg, w),
                    jax.grad(g_ref, argnums=(0, 1))(yg, w)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-5)

    # numerical check through the full pack → unpack round trip
    from jax.test_util import check_grads

    def roundtrip(xx):
        xx = jnp.asarray(xx)     # check_grads perturbs with numpy arrays
        buckets = M._dispatch(xx, fe, sp, tok, w, e, cap, str(x.dtype), t)
        return jnp.sum(M._combine(buckets, fe, sp, tok, w, t) ** 2)

    check_grads(roundtrip, (x,), order=1, modes=("rev",),
                atol=1e-3, rtol=1e-3)


def test_bucket_slots_disjoint_and_capacity_bounded():
    """Hypothesis: for arbitrary routings, every kept (token, choice) pair
    gets a unique (expert, slot) with slot < capacity; per-expert kept
    counts saturate at capacity; dropped pairs are exactly the overflow."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.models import moe as M

    @st.composite
    def cases(draw):
        t = draw(st.integers(2, 24))
        e = draw(st.sampled_from((2, 4, 8, 16)))
        k = draw(st.integers(1, min(4, e)))
        cap = draw(st.integers(1, 8))
        seed = draw(st.integers(0, 2 ** 16))
        return t, e, k, cap, seed

    @settings(max_examples=60, deadline=None)
    @given(cases())
    def prop(case):
        t, e, k, cap, seed = case
        fe, sp, tok, w, valid = _routing_tables(
            jax.random.PRNGKey(seed), t, e, k, cap)
        fe_, sp_, valid_ = (np.asarray(fe), np.asarray(sp),
                            np.asarray(valid))
        kept = [(int(a), int(b)) for a, b, v in zip(fe_, sp_, valid_) if v]
        # disjoint: each (expert, slot) used at most once
        assert len(kept) == len(set(kept))
        # capacity-bounded
        assert all(0 <= s < cap for _, s in kept)
        # per-expert saturation: kept == min(assigned, capacity)
        for ex in range(e):
            assigned = int((fe_ == ex).sum())
            got = sum(1 for a, _ in kept if a == ex)
            assert got == min(assigned, cap), (ex, assigned, got, cap)

    prop()


def test_bucket_ranges_are_section6_partitions():
    """``moe_bucket_ranges`` under an EP mesh: disjoint ranges tiling the
    (E, C, D) bucket block, accepted by the core ``db_partition``."""
    _run("""
    import jax
    import numpy as np
    from repro.core import NULL_GUID, Runtime, spawn_main
    from repro.dist.sharding import ShardCtx, moe_bucket_ranges

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ShardCtx(mesh)
    checked = 0
    for e, cap, d, item in ((8, 3, 16, 4), (64, 5, 128, 4),
                            (128, 1, 32, 2), (160, 7, 8, 4)):
        ranges = moe_bucket_ranges(e, cap, d, item, ctx)
        total = e * cap * d * item
        assert len(ranges) == 4, ranges       # one per "model" shard
        off = 0
        for o, s in ranges:                   # disjoint + exact tiling
            assert o == off and s == total // 4, ranges
            off += s
        assert off == total
        rt = Runtime()
        res = {}

        def main(paramv, depv, api, _total=total, _ranges=ranges):
            db, _ = api.db_create(_total)
            api.db_release(db)
            api.db_partition(db, _ranges)     # §6.2 invariants enforced
            res["ok"] = True
            return NULL_GUID

        spawn_main(rt, main)
        rt.run()
        assert res.get("ok"), (e, cap, ranges)
        checked += 1
    assert checked == 4

    # no active EP axis: the whole block is one local range
    assert moe_bucket_ranges(8, 3, 16, 4, ShardCtx(None)) == [(0, 8*3*16*4)]
    print("PASS")
    """)


def test_overflow_drops_deterministic_and_earliest_win():
    """With a starved capacity factor, repeated runs are bitwise identical
    and the stable sort keeps the earliest tokens' slots."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import moe as M

    cfg = get_config("deepseek-v2-236b").reduced()
    cfg = dataclasses.replace(cfg, num_experts=4, experts_per_token=2,
                              capacity_factor=0.25, num_shared_experts=0)
    params = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))

    fn = jax.jit(lambda p, xx: M.moe_ffn(p, xx, cfg))
    y1, a1 = fn(params, x)
    y2, a2 = fn(params, x)
    assert float(a1["dropped"]) > 0           # starved: drops must occur
    assert np.array_equal(np.asarray(y1), np.asarray(y2))
    assert float(a1["dropped"]) == float(a2["dropped"])

    # earliest-token-wins: slots go to the first `capacity` pairs of each
    # expert in token order (stable argsort)
    t, e, k, cap = 16, 4, 2, 2
    fe, sp, tok, w, valid = _routing_tables(
        jax.random.PRNGKey(3), t, e, k, cap)
    fe_, valid_, tok_ = np.asarray(fe), np.asarray(valid), np.asarray(tok)
    for ex in range(e):
        rows = np.where(fe_ == ex)[0]         # already in token order
        expect = set(rows[:cap].tolist())
        got = set(rows[valid_[rows]].tolist())
        assert got == expect, (ex, expect, got)


def test_a2a_sharded_drop_determinism():
    """The sharded a2a path with drops: two executions bitwise agree."""
    _run("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.dist.sharding import use_mesh
    from repro.models import moe as M

    cfg = get_config("deepseek-v2-236b").reduced()
    cfg = dataclasses.replace(cfg, num_experts=8, experts_per_token=2,
                              capacity_factor=0.5, num_shared_experts=0)
    params = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        fn = jax.jit(lambda p, xx: M.moe_ffn(p, xx, cfg))
        y1, a1 = fn(params, x)
        y2, a2 = fn(params, x)
    assert float(a1["dropped"]) > 0
    assert np.array_equal(np.asarray(y1), np.asarray(y2))
    assert float(a1["dropped"]) == float(a2["dropped"])
    print("PASS")
    """)
