"""Per-architecture smoke + serving-path parity tests on reduced configs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.models.model import LanguageModel

B, S = 2, 64


def _batch(cfg, key, seq=S):
    batch = {
        "tokens": jax.random.randint(key, (B, seq), 0, cfg.vocab_size),
        "targets": jax.random.randint(jax.random.fold_in(key, 1), (B, seq),
                                      0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        p = cfg.num_patches
        batch["tokens"] = batch["tokens"][:, : seq - p]
        batch["targets"] = batch["targets"][:, : seq - p]
        batch["patches"] = jax.random.normal(
            key, (B, p, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", all_arch_names())
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/backward, shapes + finiteness."""
    cfg = get_config(arch).reduced()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(2))
    (loss, metrics), grads = jax.jit(jax.value_and_grad(
        model.train_loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    assert metrics["tokens"] > 0
    for leaf in jax.tree_util.tree_leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))


@pytest.mark.parametrize("arch", ["smollm-360m", "qwen2-7b", "mamba2-1.3b",
                                  "zamba2-1.2b", "deepseek-v2-236b",
                                  "whisper-small", "llava-next-mistral-7b"])
def test_prefill_decode_matches_full_forward(arch):
    """Serving-path correctness: prefill(S) then decode(token S) must equal
    the full forward on S+1 tokens at the last position."""
    cfg = get_config(arch).reduced()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)
    full = _batch(cfg, key, seq=S + 1)

    pre = dict(full)
    pre["tokens"] = full["tokens"][:, :-1]
    pre.pop("targets", None)

    # ground truth: prefill over all S+1 tokens, last logits
    truth, _ = jax.jit(model.prefill)(params, full)

    # prefill S tokens -> decode the final token at cur_len = len(prefill)
    _, cache = jax.jit(model.prefill)(params, pre)
    # decode needs cache rows for the new position: pad caches along seq
    def pad_seq(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v"):          # head-major (…, K, S, hd): seq = -2
            pad = [(0, 0)] * leaf.ndim
            pad[-2] = (0, 1)
            return jnp.pad(leaf, pad)
        if name in ("c_kv", "k_rope"):  # (…, S, r): seq = -2
            pad = [(0, 0)] * leaf.ndim
            pad[-2] = (0, 1)
            return jnp.pad(leaf, pad)
        return leaf
    cache = jax.tree_util.tree_map_with_path(pad_seq, cache)

    tok = full["tokens"][:, -1:]
    prefix = cfg.num_patches if cfg.family == "vlm" else 0
    cur = jnp.asarray(prefix + pre["tokens"].shape[1], dtype=jnp.int32)
    got, _ = jax.jit(model.decode_step)(params, cache, tok, cur)

    np.testing.assert_allclose(np.asarray(got), np.asarray(truth),
                               atol=2e-2, rtol=2e-2)
    # argmax agreement is the serving-level contract
    assert np.mean(np.argmax(got, -1) == np.argmax(truth, -1)) >= 0.95


def test_vlm_masks_patch_positions():
    cfg = get_config("llava-next-mistral-7b").reduced()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(2))
    _, metrics = jax.jit(model.train_loss)(params, batch)
    # loss tokens exclude the patch prefix
    assert int(metrics["tokens"]) == B * (S - cfg.num_patches)


def test_hybrid_shared_attention_is_shared():
    """zamba2: one attention block's weights serve all applications (§4
    labeled-map dedup) — the param tree must contain exactly one copy."""
    cfg = get_config("zamba2-1.2b").reduced()
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    assert "shared_attn" in params
    wq = params["shared_attn"]["attn"]["w_q"]
    assert wq.ndim == 3                      # no leading per-application dim
    g, rem = model._hybrid_segments()
    assert g == cfg.num_layers // cfg.attn_every
