"""Checkpoint layer (§5): roundtrip, dirty-skip, commit, elasticity, async."""
import json
import os

import numpy as np
import pytest

from repro import ckpt


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "embedding": rng.normal(size=(32, 8)).astype(np.float32),
            "layers": {"w": rng.normal(size=(4, 8, 8)).astype(np.float32),
                       "b": np.zeros((4, 8), np.float32)},
        },
        "opt": {"m": {"w": np.zeros((4, 8, 8), np.float32)},
                "step": np.asarray(7, np.int32)},
    }


def _assert_tree_equal(a, b):
    if isinstance(a, dict):
        assert sorted(a) == sorted(b)
        for k in a:
            _assert_tree_equal(a[k], b[k])
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip(tmp_path):
    t = _tree()
    stats = ckpt.save(str(tmp_path), t, 3, chunk_bytes=256)
    assert stats.chunks_written == stats.chunks_total
    got, step = ckpt.restore(str(tmp_path))
    assert step == 3
    _assert_tree_equal(t, got)


def test_dirty_skip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), t, 1, chunk_bytes=128)
    s2 = ckpt.save(str(tmp_path), t, 2, chunk_bytes=128)
    assert s2.chunks_written == 0
    assert s2.chunks_skipped == s2.chunks_total
    # change ONE leaf: only its chunks rewrite
    t["params"]["layers"]["w"][2, 3, 4] = 99.0
    s3 = ckpt.save(str(tmp_path), t, 3, chunk_bytes=128)
    assert 0 < s3.chunks_written < s3.chunks_total
    got, step = ckpt.restore(str(tmp_path))
    assert step == 3
    _assert_tree_equal(t, got)


def test_manifest_commit_protects_partial_saves(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), t, 5)
    # a crashed save leaves a .tmp dir without manifest — must be ignored
    os.makedirs(tmp_path / "step_9.tmp")
    with open(tmp_path / "step_9.tmp" / "leaf_0.bin", "wb") as f:
        f.write(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 5
    got, step = ckpt.restore(str(tmp_path))
    assert step == 5


def test_elastic_reader_count(tmp_path):
    """Restore must reassemble identically for any reader parallelism."""
    t = _tree(seed=4)
    ckpt.save(str(tmp_path), t, 1, chunk_bytes=64, num_writers=3)
    for readers in (1, 2, 7):
        got, _ = ckpt.restore(str(tmp_path), num_readers=readers)
        _assert_tree_equal(t, got)


def test_async_save(tmp_path):
    t = _tree(seed=9)
    th = ckpt.async_save(str(tmp_path), t, 11)
    # mutate after issue: snapshot semantics (§3 issue-now/resolve-later)
    t["params"]["embedding"][:] = -1
    th.join()
    got, step = ckpt.restore(str(tmp_path))
    assert step == 11
    assert not np.allclose(got["params"]["embedding"], -1)


def test_restore_specific_step(tmp_path):
    a, b = _tree(1), _tree(2)
    ckpt.save(str(tmp_path), a, 1)
    ckpt.save(str(tmp_path), b, 2)
    got, step = ckpt.restore(str(tmp_path), step=1)
    assert step == 1
    _assert_tree_equal(a, got)
