"""Checkpoint layer (§5/§6): roundtrip, dirty-skip, commit, elasticity,
async, crash consistency, corrupt-manifest resilience, sharded ranges."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro import ckpt

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {
            "embedding": rng.normal(size=(32, 8)).astype(np.float32),
            "layers": {"w": rng.normal(size=(4, 8, 8)).astype(np.float32),
                       "b": np.zeros((4, 8), np.float32)},
        },
        "opt": {"m": {"w": np.zeros((4, 8, 8), np.float32)},
                "step": np.asarray(7, np.int32)},
    }


def _assert_tree_equal(a, b):
    if isinstance(a, dict):
        assert sorted(a) == sorted(b)
        for k in a:
            _assert_tree_equal(a[k], b[k])
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip(tmp_path):
    t = _tree()
    stats = ckpt.save(str(tmp_path), t, 3, chunk_bytes=256)
    assert stats.chunks_written == stats.chunks_total
    got, step = ckpt.restore(str(tmp_path))
    assert step == 3
    _assert_tree_equal(t, got)


def test_dirty_skip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), t, 1, chunk_bytes=128)
    s2 = ckpt.save(str(tmp_path), t, 2, chunk_bytes=128)
    assert s2.chunks_written == 0
    assert s2.chunks_skipped == s2.chunks_total
    # change ONE leaf: only its chunks rewrite
    t["params"]["layers"]["w"][2, 3, 4] = 99.0
    s3 = ckpt.save(str(tmp_path), t, 3, chunk_bytes=128)
    assert 0 < s3.chunks_written < s3.chunks_total
    got, step = ckpt.restore(str(tmp_path))
    assert step == 3
    _assert_tree_equal(t, got)


def test_manifest_commit_protects_partial_saves(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), t, 5)
    # a crashed save leaves a .tmp dir without manifest — must be ignored
    os.makedirs(tmp_path / "step_9.tmp")
    with open(tmp_path / "step_9.tmp" / "leaf_0.bin", "wb") as f:
        f.write(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 5
    got, step = ckpt.restore(str(tmp_path))
    assert step == 5


def test_elastic_reader_count(tmp_path):
    """Restore must reassemble identically for any reader parallelism."""
    t = _tree(seed=4)
    ckpt.save(str(tmp_path), t, 1, chunk_bytes=64, num_writers=3)
    for readers in (1, 2, 7):
        got, _ = ckpt.restore(str(tmp_path), num_readers=readers)
        _assert_tree_equal(t, got)


def test_async_save(tmp_path):
    t = _tree(seed=9)
    th = ckpt.async_save(str(tmp_path), t, 11)
    # mutate after issue: snapshot semantics (§3 issue-now/resolve-later)
    t["params"]["embedding"][:] = -1
    th.join()
    got, step = ckpt.restore(str(tmp_path))
    assert step == 11
    assert not np.allclose(got["params"]["embedding"], -1)


def test_restore_specific_step(tmp_path):
    a, b = _tree(1), _tree(2)
    ckpt.save(str(tmp_path), a, 1)
    ckpt.save(str(tmp_path), b, 2)
    got, step = ckpt.restore(str(tmp_path), step=1)
    assert step == 1
    _assert_tree_equal(a, got)


def test_crash_mid_flush_preserves_previous(tmp_path):
    """A save killed with coalesced writes pending must not commit, and
    the previous step must still round-trip; the .tmp dir is ignored."""
    a = _tree(3)
    ckpt.save(str(tmp_path), a, 1)
    b = _tree(4)
    stats = ckpt.save(str(tmp_path), b, 2, crash_at=0.5)
    assert not stats.committed
    assert os.path.isdir(tmp_path / "step_2.tmp")      # dead weight, ignored
    assert ckpt.latest_step(str(tmp_path)) == 1
    got, step = ckpt.restore(str(tmp_path))
    assert step == 1
    _assert_tree_equal(a, got)
    # a later save is unaffected by the wreckage
    s3 = ckpt.save(str(tmp_path), b, 3)
    assert s3.committed
    got, step = ckpt.restore(str(tmp_path))
    assert step == 3
    _assert_tree_equal(b, got)


def test_corrupt_prev_manifest_skips_dirty_tracking(tmp_path):
    """A corrupt previous manifest only disables the dirty skip (warn)."""
    t = _tree(5)
    ckpt.save(str(tmp_path), t, 1, chunk_bytes=128)
    with open(tmp_path / "step_1" / "manifest.json", "w") as f:
        f.write("{definitely not json")
    with pytest.warns(UserWarning, match="dirty-range skipping disabled"):
        s2 = ckpt.save(str(tmp_path), t, 2, chunk_bytes=128)
    assert s2.committed
    assert s2.chunks_written == s2.chunks_total       # full write, no skip
    got, step = ckpt.restore(str(tmp_path), step=2)
    _assert_tree_equal(t, got)


def test_host_tree_reports_no_gathers(tmp_path):
    stats = ckpt.save(str(tmp_path), _tree(), 1)
    assert stats.host_gathers == 0


def _run_devices(code: str):
    full = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys\nsys.path.insert(0, 'src')\n" + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", full], capture_output=True,
                         text=True, cwd=ROOT, timeout=560)
    assert out.returncode == 0 and "PASS" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])


def test_sharded_save_reshard_on_restore():
    """Save under an 8-device mesh; restore under 2- and 1-device meshes
    and pure_dp — bit-exact via the §6 range manifest, zero gathers."""
    _run_devices("""
    import json, os, tempfile, shutil
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro import ckpt
    from repro.dist.sharding import ShardCtx, param_shardings

    rng = np.random.default_rng(0)
    tree = {"params": {
        "w_q": rng.normal(size=(32, 8, 16)).astype(np.float32),
        "w_down": rng.normal(size=(64, 32)).astype(np.float32),
        "norm": rng.normal(size=(32,)).astype(np.float32)},
        "opt": {"step": np.asarray(11, np.int32)}}
    shapes = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)

    mesh8 = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    sh8 = param_shardings(shapes, ShardCtx(mesh=mesh8))
    dev = jax.tree_util.tree_map(jax.device_put, tree, sh8)
    tmp = tempfile.mkdtemp()
    st = ckpt.save(tmp, dev, 1, num_writers=8)
    assert st.host_gathers == 0, st
    assert st.committed

    # the manifest carries per-range (node, offset, size) entries
    with open(os.path.join(tmp, "step_1", "manifest.json")) as f:
        man = json.load(f)
    sharded_leaves = [l for l in man["leaves"] if "ranges" in l]
    assert sharded_leaves, man["leaves"]
    for l in sharded_leaves:
        assert all(len(r) == 3 for r in l["ranges"])
        spans = sorted((off, off + size) for _n, off, size in l["ranges"])
        assert spans[0][0] == 0 and spans[-1][1] == l["nbytes"]
        assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))

    def check(shardings):
        got, step = ckpt.restore(tmp, shardings=shardings)
        assert step == 1
        for k in tree["params"]:
            np.testing.assert_array_equal(
                tree["params"][k], np.asarray(got["params"][k]))
        np.testing.assert_array_equal(
            tree["opt"]["step"], np.asarray(got["opt"]["step"]))

    check(None)                                        # plain host restore
    mesh2 = Mesh(np.array(jax.devices()[:2]).reshape(1, 2),
                 ("data", "model"))
    check(param_shardings(shapes, ShardCtx(mesh=mesh2)))
    mesh1 = Mesh(np.array(jax.devices()[:1]).reshape(1, 1),
                 ("data", "model"))
    check(param_shardings(shapes, ShardCtx(mesh=mesh1)))
    check(param_shardings(shapes, ShardCtx(mesh=mesh2, pure_dp=True)))

    # dirty-skip across identical sharded saves
    st2 = ckpt.save(tmp, dev, 2, num_writers=8)
    assert st2.chunks_written == 0 and st2.chunks_skipped == st2.chunks_total
    shutil.rmtree(tmp)
    print("PASS")
    """)


def test_sharded_save_restores_on_other_writer_count():
    """§6 range manifest is elastic in the writer/reader dimension too."""
    _run_devices("""
    import tempfile, shutil
    import numpy as np
    import jax
    from jax.sharding import Mesh
    from repro import ckpt
    from repro.dist.sharding import ShardCtx, param_shardings

    rng = np.random.default_rng(2)
    tree = {"w_up": rng.normal(size=(16, 64)).astype(np.float32)}
    shapes = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    dev = jax.tree_util.tree_map(
        jax.device_put, tree, param_shardings(shapes, ShardCtx(mesh=mesh)))
    tmp = tempfile.mkdtemp()
    ckpt.save(tmp, dev, 1, num_writers=3)       # writers != devices
    for readers in (1, 2, 7):
        got, _ = ckpt.restore(tmp, num_readers=readers)
        np.testing.assert_array_equal(tree["w_up"], np.asarray(got["w_up"]))
    shutil.rmtree(tmp)
    print("PASS")
    """)
