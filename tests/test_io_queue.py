"""§5 async IO queue: grant deferral, read-ahead overlap, write-back
coalescing, write-only chunks, crash semantics, §6-partition write-back.

``REPRO_IO_LATENCY`` sweeps the per-chunk latency (CI runs 0 and 1.0);
tests whose assertion *requires* a nonzero latency pin their own.
"""
import os

import numpy as np
import pytest

from repro.core import DbMode, NULL_GUID, Runtime, spawn_main

L = float(os.environ.get("REPRO_IO_LATENCY", "1.0"))


def _write_file(path, n=64):
    data = np.arange(n, dtype=np.uint8)
    data.tofile(path)
    return data


def test_grant_defers_until_read_lands(tmp_path):
    """A task acquiring a lazy chunk runs only after open + read."""
    path = str(tmp_path / "f.bin")
    data = _write_file(path)
    rt = Runtime(io_latency=L)
    seen = {}

    def reader(paramv, depv, api):
        seen["t"] = api.rt.clock
        seen["data"] = bytes(depv[0].ptr)
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            c = api2.file_get_chunk(fg, 0, 64)
            api2.file_release(fg)
            tmpl2 = api2.edt_template_create(reader, 0, 1)
            api2.edt_create(tmpl2, depv=[c], dep_modes=[DbMode.RO])
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert seen["data"] == data.tobytes()
    assert seen["t"] >= 2 * L          # open latency + queued chunk read
    assert stats.io_read_ops == 1
    assert stats.file_bytes_read == 64


def _scan(io_mode, io_latency, chunks=16, duration=3.0, tmp_path=None):
    """Chained scan: task i consumes chunk i and feeds task i+1."""
    path = str(tmp_path / f"scan_{io_mode}.bin")
    nbytes = 1 << 12
    np.arange(nbytes // 4, dtype=np.uint32).tofile(path)
    rt = Runtime(num_nodes=2, io_latency=io_latency, io_mode=io_mode)
    per = nbytes // chunks
    acc = {"v": 0}

    def work(paramv, depv, api):
        acc["v"] += int(depv[0].ptr.view(np.uint32).sum())
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            tmpl2 = api2.edt_template_create(work, 0, 2)
            prev = None
            for c in range(chunks):
                ch = api2.file_get_chunk(fg, c * per, per)
                depv2 = [ch, prev if prev is not None else NULL_GUID]
                _, ev = api2.edt_create(
                    tmpl2, depv=depv2, dep_modes=[DbMode.RO, DbMode.NULL],
                    duration=duration, output_event=True)
                prev = ev
            api2.file_release(fg)
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    expect = int(np.arange(nbytes // 4, dtype=np.uint64).sum())
    return stats, acc["v"] == expect


def test_async_overlap_beats_sync_baseline(tmp_path):
    """Read-ahead must strictly beat the blocking per-chunk baseline."""
    sync_stats, ok_s = _scan("sync", 2.0, tmp_path=tmp_path)
    async_stats, ok_a = _scan("async", 2.0, tmp_path=tmp_path)
    assert ok_s and ok_a
    assert async_stats.makespan < sync_stats.makespan
    assert async_stats.io_overlap_ticks > 0
    # read-ahead streams every chunk before the chain consumes them
    assert async_stats.io_reads_inflight_max > 1


def test_env_latency_scan_consistency(tmp_path):
    """At the swept latency both modes stay correct; async never loses."""
    sync_stats, ok_s = _scan("sync", L, tmp_path=tmp_path)
    async_stats, ok_a = _scan("async", L, tmp_path=tmp_path)
    assert ok_s and ok_a
    assert async_stats.makespan <= sync_stats.makespan
    if L == 0:
        assert async_stats.makespan == sync_stats.makespan


def test_adjacent_writebacks_coalesce(tmp_path):
    """Same-timestamp destroys of adjacent dirty chunks merge to one op."""
    path = str(tmp_path / "f.bin")
    rt = Runtime(io_latency=L)
    n, per = 4, 16

    def w(paramv, depv, api):
        depv[0].ptr[:] = paramv[0]
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "wb+")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            tmpl2 = api2.edt_template_create(w, 1, 1)
            for c in range(n):
                ch = api2.file_get_chunk(fg, c * per, per, write_only=True)
                api2.edt_create(tmpl2, paramv=[c + 1], depv=[ch],
                                dep_modes=[DbMode.EW])
            api2.file_release(fg)
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.io_write_ops == 1
    assert stats.io_coalesced_writes == n - 1
    assert stats.file_bytes_written == n * per
    got = np.fromfile(path, np.uint8)
    expect = np.repeat(np.arange(1, n + 1, dtype=np.uint8), per)
    assert np.array_equal(got, expect)


def test_write_only_chunk_skips_read(tmp_path):
    """A write-only chunk of a non-empty file charges no read op."""
    path = str(tmp_path / "f.bin")
    _write_file(path)
    rt = Runtime(io_latency=L)

    def w(paramv, depv, api):
        depv[0].ptr[:] = 9
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb+")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            ch = api2.file_get_chunk(fg, 0, 32, write_only=True)
            api2.file_release(fg)
            tmpl2 = api2.edt_template_create(w, 0, 1)
            api2.edt_create(tmpl2, depv=[ch], dep_modes=[DbMode.EW])
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.io_read_ops == 0
    assert stats.file_bytes_read == 0
    got = np.fromfile(path, np.uint8)
    assert np.all(got[:32] == 9) and np.all(got[32:] == np.arange(32, 64))


def test_killed_node_loses_inflight_writes(tmp_path):
    """Write-backs in flight on a fail-stopped node never reach disk."""
    path = str(tmp_path / "f.bin")
    _write_file(path)
    rt = Runtime(num_nodes=2, io_latency=4.0)

    def w(paramv, depv, api):
        # the writer node creates + writes + destroys its own chunk, so
        # the write-back rides node 1's IO queue
        fg = api.rt.file_registry[0]
        ch = api.file_get_chunk(fg, 0, 32, write_only=True)
        db = api.rt.lookup(ch)
        api.rt._materialize(db)[:] = 7
        db.dirty = True
        api.db_destroy(ch)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb+")

        def after(pv, dv, api2):
            tmpl2 = api2.edt_template_create(w, 0, 0)
            api2.edt_create(tmpl2, depv=[], placement=1)
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    # run past the destroy (write enqueued) but not to its completion,
    # then fail-stop the writer's node: the MIoDone is dropped
    rt.run(until=rt.io_latency + 2.5)
    rt.kill_node(1)
    stats = rt.run()
    assert stats.file_bytes_written == 0
    assert np.array_equal(np.fromfile(path, np.uint8),
                          np.arange(64, dtype=np.uint8))


def test_partition_children_write_back_own_ranges(tmp_path):
    """§6 partitions of a file-mapped chunk write exactly their ranges."""
    path = str(tmp_path / "f.bin")
    rt = Runtime(io_latency=L)
    parts = [(0, 16), (16, 16), (32, 32)]

    def w(paramv, depv, api):
        depv[0].ptr[:] = paramv[0]
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "wb+")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            chunk = api2.file_get_chunk(fg, 0, 64, write_only=True)
            children = api2.db_partition(chunk, parts)
            tmpl2 = api2.edt_template_create(w, 1, 1)
            for i, child in enumerate(children):
                api2.edt_create(tmpl2, paramv=[i + 1], depv=[child],
                                dep_modes=[DbMode.EW])
            api2.db_destroy(chunk)      # deferred until children retire
            api2.file_release(fg)
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    # the three children retire together: adjacent ranges coalesce
    assert stats.file_bytes_written == 64
    assert stats.io_write_ops == 1
    assert stats.io_coalesced_writes == 2
    got = np.fromfile(path, np.uint8)
    expect = np.concatenate([np.full(s, i + 1, np.uint8)
                             for i, (_o, s) in enumerate(parts)])
    assert np.array_equal(got, expect)


def test_elevator_merges_into_queued_unstarted_write(tmp_path):
    """Cross-timestamp coalescing: a write-back flushed while an adjacent
    write op is still queued (disk backlogged, op unstarted) merges into
    that op instead of paying its own ``io_latency`` — the elevator pass.

    Timeline (io_latency 8): chunks 0 and 2 retire at t≈1 → two ops (not
    adjacent); chunk 0's op starts immediately, chunk 2's queues behind
    it.  Chunk 3 retires at t≈3, adjacent to the *queued* chunk-2 op →
    absorbed.  Requires a nonzero latency, so the test pins its own.
    """
    path = str(tmp_path / "f.bin")
    rt = Runtime(io_latency=8.0)
    per = 16

    def w(paramv, depv, api):
        depv[0].ptr[:] = paramv[0]
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "wb+")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            tmpl2 = api2.edt_template_create(w, 1, 1)
            for c, dur in ((0, 1.0), (2, 1.0), (3, 3.0)):
                ch = api2.file_get_chunk(fg, c * per, per, write_only=True)
                api2.edt_create(tmpl2, paramv=[c + 1], depv=[ch],
                                dep_modes=[DbMode.EW], duration=dur)
            api2.file_release(fg)
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    # chunks 0 and 2 each pay a disk slot; chunk 3 rides chunk 2's
    assert stats.io_write_ops == 2
    assert stats.io_coalesced_writes == 1
    assert stats.file_bytes_written == 3 * per
    got = np.fromfile(path, np.uint8)
    expect = np.zeros(4 * per, np.uint8)
    for c in (0, 2, 3):
        expect[c * per:(c + 1) * per] = c + 1
    assert np.array_equal(got, expect)


def test_elevator_never_reorders_rewrite_past_stale_queued_op(tmp_path):
    """A re-written chunk must not ride the elevator past its own stale
    queued write-back: the new payload's op overlaps a pending op, so it
    takes a fresh (later) disk slot and the newest bytes land last.

    Timeline (io_latency 10): Z [96,112) occupies the disk; chunk2
    [32,48) and chunk4 [64,80) (payload OLD) queue behind it at t≈1 as
    two non-adjacent ops; chunk3 [48,64) retires at t≈2 and
    elevator-merges into chunk2's op, growing it to [32,64) — adjacent to
    chunk4.  At t≈3 chunk4 is re-acquired and destroyed with payload NEW:
    without the overlap guard it would merge into the *earlier* grown op
    and the stale [64,80) op would overwrite it at its later completion.
    """
    path = str(tmp_path / "f.bin")
    rt = Runtime(io_latency=10.0)
    per = 16
    OLD, NEW = 7, 9

    def w(paramv, depv, api):
        depv[0].ptr[:] = paramv[0]
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def delay(paramv, depv, api):
        return NULL_GUID

    def rewrite(paramv, depv, api):
        # chunk4's first db was destroyed two event-hops ago
        fg = api.rt.file_registry[0]
        ch = api.file_get_chunk(fg, 4 * per, per, write_only=True)
        db = api.rt.lookup(ch)
        api.rt._materialize(db)[:] = NEW
        db.dirty = True
        api.db_destroy(ch)
        api.file_release(fg)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "wb+")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            tmpl2 = api2.edt_template_create(w, 1, 1)
            ev4 = None
            for c, val, dur in ((6, 1, 0.5),       # Z: occupies the disk
                                (2, 2, 1.0),
                                (4, OLD, 1.0),
                                (3, 3, 2.0)):      # merges into chunk2's op
                ch = api2.file_get_chunk(fg, c * per, per, write_only=True)
                _, ev = api2.edt_create(tmpl2, paramv=[val], depv=[ch],
                                        dep_modes=[DbMode.EW], duration=dur,
                                        output_event=True)
                if c == 4:
                    ev4 = ev
            # rewrite runs one event-hop after chunk4's OLD write-back is
            # enqueued (and after chunk3's elevator merge), while the
            # stale op is still queued behind Z on the disk
            tmpl_d = api2.edt_template_create(delay, 0, 1)
            _, ev_d = api2.edt_create(tmpl_d, depv=[ev4],
                                      dep_modes=[DbMode.NULL],
                                      duration=1.5, output_event=True)
            tmpl3 = api2.edt_template_create(rewrite, 0, 1)
            api2.edt_create(tmpl3, depv=[ev_d], dep_modes=[DbMode.NULL])
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    got = np.fromfile(path, np.uint8)
    # the rewrite's payload must win over the stale queued write-back
    assert np.all(got[4 * per:5 * per] == NEW)
    # chunk3 still coalesced into chunk2's queued op
    assert stats.io_coalesced_writes >= 1


def test_sync_mode_rejects_unknown(tmp_path):
    with pytest.raises(ValueError):
        Runtime(io_mode="turbo")
