"""Cold-object spill: idle unlocked data blocks past ``spill_threshold``
write back through the §5 IO queue (least-recently-granted first, one op
per contiguous spill-file run) and re-materialize through the same
grant-deferral path as IO-pending file chunks.

Contracts under test: spill → re-acquire round-trips bit-exact payloads;
``run(until)`` / fail-stop lose exactly the in-flight spill ops (PR 3's IO
crash semantics — never object payloads); ``Stats.spilled_objects`` counts
match the per-node and per-shard accounting; a racing write aborts a stale
spill snapshot.

``REPRO_IO_LATENCY`` sweeps the disk latency (CI runs 0 and 1.0); tests
whose assertions need a wide in-flight window pin their own.
"""
import os

import pytest

from repro.core import DbMode, NULL_GUID, OcrError, Runtime, spawn_main

L = float(os.environ.get("REPRO_IO_LATENCY", "1.0"))


def _mk_runtime(**kw):
    kw.setdefault("io_latency", L)
    kw.setdefault("shard_bits", 2)
    return Runtime(**kw)


def _make_dbs(api, n, size=16, payload_of=lambda i: i + 1):
    out = []
    for i in range(n):
        g, buf = api.db_create(size)
        buf[:] = payload_of(i)
        out.append((g, bytes(buf)))
    return out


def _assert_resident_counter_consistent(rt):
    """The incremental per-node resident counter must match a full scan."""
    from repro.core import ObjectKind
    for node in rt.nodes:
        scan = sum(1 for _i, sh in node.objects.shards(ObjectKind.DATABLOCK)
                   for o in sh.objs.values()
                   if o.buffer is not None and not o.is_view)
        assert node.resident_dbs == scan, (node.idx, node.resident_dbs, scan)


def test_spill_roundtrip_bit_exact():
    """Spill then re-acquire: payloads survive the disk round trip."""
    rt = _mk_runtime(spill_threshold=2)
    made = []

    def maker(paramv, depv, api):
        made.extend(_make_dbs(api, 8))
        return NULL_GUID

    spawn_main(rt, maker)
    stats = rt.run()
    # resident was 8 > 2: exactly 6 spill, never below the threshold
    assert stats.spilled_objects == 6
    assert rt.nodes[0].spilled == 6
    spilled = [g for g, _ in made if rt.lookup(g).spilled]
    assert len(spilled) == 6
    for g in spilled:
        assert rt.lookup(g).buffer is None
    # contiguously-placed victims coalesce into one write-back op
    assert stats.io_write_ops == 1

    # re-acquire every block (spilled ones defer the grant, unspill through
    # the IO queue, and wake exactly like IO-pending §5 chunks)
    rt.spill_threshold = None
    seen = {}

    def reader(paramv, depv, api):
        seen[depv[0].guid] = bytes(depv[0].ptr)
        return NULL_GUID

    def phase2(paramv, depv, api):
        tmpl = api.edt_template_create(reader, 0, 1)
        for g, _ in made:
            api.edt_create(tmpl, depv=[g], dep_modes=[DbMode.RO])
        return NULL_GUID

    spawn_main(rt, phase2)
    stats = rt.run()
    assert stats.spilled_objects == 0
    for g, payload in made:
        assert seen[g] == payload
        assert rt.lookup(g).buffer is not None
    _assert_resident_counter_consistent(rt)


def test_same_timestamp_release_rescans_past_fruitless_guard():
    """A fruitless scan at clock T must not suppress the scan of a later
    same-timestamp retirement that *released* blocks (the release clears
    the guard)."""
    rt = Runtime(io_latency=1.0, spill_threshold=0, shard_bits=2)
    made = {}

    def idle(paramv, depv, api):
        return NULL_GUID

    def holder(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        g, buf = api.db_create(16)
        buf[:] = 3
        made["db"] = g
        # holder keeps the only block locked while main and idle retire
        # (their scans are fruitless and arm the guard at t=1); holder's
        # own retirement at the same t=1 releases it and must still spill
        it = api.edt_template_create(idle, 0, 1)
        api.edt_create(it, depv=[NULL_GUID], dep_modes=[DbMode.NULL])
        ht = api.edt_template_create(holder, 0, 1)
        api.edt_create(ht, depv=[g], dep_modes=[DbMode.EW])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.spilled_objects == 1
    assert rt.lookup(made["db"]).spilled
    _assert_resident_counter_consistent(rt)


def test_partition_view_write_aborts_stale_spill_snapshot():
    """A §6 partition child writing through the parent's buffer inside the
    spill-op window must abort the parent's stale snapshot (view writes
    bypass the parent's lock state — the PR 3 checkpoint pattern)."""
    rt = Runtime(io_latency=10.0, spill_threshold=0, shard_bits=2)
    made = {}
    seen = {}
    OLD, NEW = 4, 6

    def delay(paramv, depv, api):
        return NULL_GUID

    def carve(paramv, depv, api):
        # partition -> EW write -> destroy, all inside the parent's
        # in-flight spill window
        parent = made["db"]
        child = api.db_partition(parent, [(0, 16)])[0]

        def w(pv, dv, a):
            dv[0].ptr[:] = NEW
            a.db_destroy(dv[0].guid)
            return NULL_GUID

        wt = api.edt_template_create(w, 0, 1)
        api.edt_create(wt, depv=[child], dep_modes=[DbMode.EW])
        return NULL_GUID

    def reader(paramv, depv, api):
        seen["late"] = bytes(depv[0].ptr[:16])
        return NULL_GUID

    def main(paramv, depv, api):
        g, buf = api.db_create(32)
        buf[:] = OLD
        made["db"] = g
        # main retires at t=1 -> spill submitted, completes t=11;
        # carve runs at t=3, its writer finishes t~4, all inside the window
        dt = api.edt_template_create(delay, 0, 1)
        _, ev = api.edt_create(dt, depv=[NULL_GUID], dep_modes=[DbMode.NULL],
                               duration=2.0, output_event=True)
        ct = api.edt_template_create(carve, 0, 1)
        _, ev2 = api.edt_create(ct, depv=[ev], dep_modes=[DbMode.NULL],
                                output_event=True)
        # read well past the spill completion
        _, ev3 = api.edt_create(dt, depv=[ev2], dep_modes=[DbMode.NULL],
                                duration=15.0, output_event=True)
        rtm = api.edt_template_create(reader, 0, 2)
        api.edt_create(rtm, depv=[made["db"], ev3],
                       dep_modes=[DbMode.RO, DbMode.NULL])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    # without the db_partition version bump the stale spill would win and
    # the late read would re-materialize the OLD bytes
    assert seen["late"] == bytes([NEW]) * 16
    _assert_resident_counter_consistent(rt)


def test_remote_release_spills_pure_data_holder_node():
    """A node whose blocks are only ever locked by remote tasks has no
    retirements of its own: the remote task's retirement must run the
    spill check on the data-holder node too."""
    rt = Runtime(num_nodes=2, io_latency=1.0, spill_threshold=0,
                 shard_bits=2)
    made = {}

    def writer(paramv, depv, api):
        depv[0].ptr[:] = 7
        return NULL_GUID

    def main(paramv, depv, api):
        db, _ = api.db_create(32, placement=1)    # lives on node 1
        made["db"] = db
        wt = api.edt_template_create(writer, 0, 1)
        api.edt_create(wt, depv=[db], dep_modes=[DbMode.EW], placement=0)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.spilled_objects == 1
    db = rt.lookup(made["db"])
    assert db.spilled and db.buffer is None
    assert rt.nodes[1].spill_path is not None
    _assert_resident_counter_consistent(rt)


def test_spill_counts_match_table_marks():
    rt = _mk_runtime(spill_threshold=3)

    def maker(paramv, depv, api):
        _make_dbs(api, 10)
        return NULL_GUID

    spawn_main(rt, maker)
    stats = rt.run()
    assert stats.spilled_objects == 7
    assert sum(n.spilled for n in rt.nodes) == stats.spilled_objects
    from repro.core import ObjectKind
    marks = sum(sh.spilled for _i, sh in
                rt.nodes[0].objects.shards(ObjectKind.DATABLOCK))
    assert marks == stats.spilled_objects
    # a fully-spilled shard is no longer hot
    assert stats.table_hot_shards < stats.table_shards


def test_run_until_loses_exactly_inflight_spill_ops():
    """Halting mid-spill loses the ops, not the payloads: buffers stay
    resident and nothing is marked spilled (PR 3's fail-stop IO contract)."""
    rt = Runtime(io_latency=5.0, spill_threshold=0, shard_bits=2)
    made = []

    def maker(paramv, depv, api):
        made.extend(_make_dbs(api, 3))
        return NULL_GUID

    spawn_main(rt, maker)
    # maker retires at t=1 (spill submitted); ops complete at t=6
    rt.run(until=2.0)
    assert rt.stats.spilled_objects == 0
    for g, _ in made:
        db = rt.lookup(g)
        assert db.buffer is not None and db.spilling and not db.spilled
    # resuming completes the spill
    stats = rt.run()
    assert stats.spilled_objects == 3
    for g, _ in made:
        assert rt.lookup(g).spilled


def test_failstop_mid_spill_drops_ops_and_reclaims_file():
    rt = Runtime(num_nodes=2, io_latency=5.0, spill_threshold=0, shard_bits=2)
    made = []

    def maker(paramv, depv, api):
        made.extend(_make_dbs(api, 3))
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(maker, 0, 0)
        api.edt_create(tmpl, depv=[], placement=1)
        return NULL_GUID

    spawn_main(rt, main)
    rt.run(until=3.0)           # spill submitted on node 1, not yet done
    spill_path = rt.nodes[1].spill_path
    assert spill_path is not None and os.path.exists(spill_path)
    rt.kill_node(1)
    stats = rt.run()            # the in-flight MIoDone is dropped
    assert stats.spilled_objects == 0
    assert not os.path.exists(spill_path)
    with pytest.raises(OcrError, match="fail-stopped"):
        rt.lookup(made[0][0])


def test_dirty_spilled_chunk_writes_back_real_bytes(tmp_path):
    """Destroying a dirty spilled §5 chunk re-materializes from the spill
    file and writes the *real* bytes back to the user file."""
    path = str(tmp_path / "f.bin")
    rt = Runtime(io_latency=1.0, spill_threshold=0, shard_bits=2)
    keep = {}

    def w(paramv, depv, api):
        depv[0].ptr[:] = 9
        return NULL_GUID

    def delay(paramv, depv, api):
        return NULL_GUID

    def destroyer(paramv, depv, api):
        db = api.rt.lookup(keep["chunk"])
        assert db.spilled and db.buffer is None     # cold by now
        api.db_destroy(keep["chunk"])
        api.file_release(keep["fg"])
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "wb+")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            keep["fg"] = fg
            ch = api2.file_get_chunk(fg, 0, 32, write_only=True)
            keep["chunk"] = ch
            wt = api2.edt_template_create(w, 0, 1)
            _, ev = api2.edt_create(wt, depv=[ch], dep_modes=[DbMode.EW],
                                    output_event=True)
            # wait out the spill (submitted when w retires) before destroy
            dt = api2.edt_template_create(delay, 0, 1)
            _, ev2 = api2.edt_create(dt, depv=[ev], dep_modes=[DbMode.NULL],
                                     duration=5.0, output_event=True)
            kt = api2.edt_template_create(destroyer, 0, 1)
            api2.edt_create(kt, depv=[ev2], dep_modes=[DbMode.NULL])
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    # the destroyed chunk left the spill accounting (the file descriptor
    # DB is still live and may legitimately stay spilled)
    assert rt.try_lookup(keep["chunk"]) is None
    assert stats.spilled_objects <= 1
    _assert_resident_counter_consistent(rt)
    import numpy as np
    got = np.fromfile(path, np.uint8)
    assert len(got) == 32 and (got == 9).all()


def test_racing_write_aborts_stale_spill_snapshot():
    """A block re-acquired RW while its spill op is in flight must keep its
    live buffer: the spill snapshot is stale (version guard), and a later
    re-spill writes the fresh bytes."""
    rt = Runtime(io_latency=10.0, spill_threshold=0, shard_bits=2)
    made = {}
    seen = {}
    OLD, NEW = 5, 8

    def writer(paramv, depv, api):
        depv[0].ptr[:] = NEW
        return NULL_GUID

    def delay(paramv, depv, api):
        return NULL_GUID

    def reader(paramv, depv, api):
        seen["late"] = bytes(depv[0].ptr)
        return NULL_GUID

    def main(paramv, depv, api):
        g, buf = api.db_create(16)
        buf[:] = OLD
        made["db"] = g
        # delay the writer so it grants inside the spill window
        # (main retires at t=1 -> spill submitted, completes t=11)
        dt = api.edt_template_create(delay, 0, 1)
        _, ev = api.edt_create(dt, depv=[NULL_GUID], dep_modes=[DbMode.NULL],
                               duration=2.0, output_event=True)
        wt = api.edt_template_create(writer, 0, 2)
        _, ev2 = api.edt_create(wt, depv=[g, ev],
                                dep_modes=[DbMode.EW, DbMode.NULL],
                                output_event=True)
        # read well after the spill op completed (t=11)
        _, ev3 = api.edt_create(dt, depv=[ev2], dep_modes=[DbMode.NULL],
                                duration=12.0, output_event=True)
        rtm = api.edt_template_create(reader, 0, 2)
        api.edt_create(rtm, depv=[g, ev3], dep_modes=[DbMode.RO, DbMode.NULL])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert seen["late"] == bytes([NEW]) * 16
    # after the reader retires the block goes cold again and re-spills —
    # with the fresh bytes, which a final re-acquire proves
    db = rt.lookup(made["db"])
    assert db.spilled and db.buffer is None
    rt.spill_threshold = None

    def phase2(paramv, depv, api):
        rtm = api.edt_template_create(reader, 0, 1)
        api.edt_create(rtm, depv=[made["db"]], dep_modes=[DbMode.RO])
        return NULL_GUID

    spawn_main(rt, phase2)
    rt.run()
    assert seen["late"] == bytes([NEW]) * 16


def test_bufferless_blocks_do_not_count_as_resident():
    """no_acquire / unread blocks hold no buffer: they must not push the
    node over the threshold and trigger spurious spills."""
    from repro.core import DB_PROP_NO_ACQUIRE
    rt = _mk_runtime(spill_threshold=3)

    def maker(paramv, depv, api):
        for _ in range(5):
            api.db_create(16, props=DB_PROP_NO_ACQUIRE)   # buffer None
        _make_dbs(api, 2)                                 # resident
        return NULL_GUID

    spawn_main(rt, maker)
    stats = rt.run()
    assert stats.spilled_objects == 0      # 2 resident <= threshold 3


def test_sync_mode_charges_unspill_read():
    """Re-acquiring a spilled block under io_mode="sync" charges the
    spill-file read to the task's blocking time (same disk model as the
    async path — the sync baseline must not get free unspills)."""
    rt = Runtime(io_latency=4.0, spill_threshold=0, shard_bits=2,
                 io_mode="sync")
    made = []
    seen = {}

    def maker(paramv, depv, api):
        made.extend(_make_dbs(api, 1))
        return NULL_GUID

    spawn_main(rt, maker)
    stats = rt.run()
    assert stats.spilled_objects == 1
    reads_before = stats.io_read_ops
    rt.spill_threshold = None

    def reader(paramv, depv, api):
        seen["t"] = api.rt.clock
        seen["bytes"] = bytes(depv[0].ptr)
        return NULL_GUID

    def phase2(paramv, depv, api):
        tmpl = api.edt_template_create(reader, 0, 1)
        api.edt_create(tmpl, depv=[made[0][0]], dep_modes=[DbMode.RO])
        return NULL_GUID

    t0 = rt.clock
    spawn_main(rt, phase2)
    stats = rt.run()
    assert seen["bytes"] == made[0][1]
    assert stats.io_read_ops == reads_before + 1    # the unspill is charged
    # the reader's window covers the charged read: one io_latency past the
    # phase-2 start plus the phase-2 main's own duration
    assert stats.makespan >= t0 + 4.0


def test_spill_roundtrip_sync_io_mode():
    """Sync IO mode re-materializes spilled blocks synchronously at
    execution (no grant deferral) with the same bit-exact contract."""
    rt = _mk_runtime(spill_threshold=0, io_mode="sync")
    made = []
    seen = {}

    def maker(paramv, depv, api):
        made.extend(_make_dbs(api, 4))
        return NULL_GUID

    spawn_main(rt, maker)
    stats = rt.run()
    assert stats.spilled_objects == 4

    rt.spill_threshold = None

    def reader(paramv, depv, api):
        seen[depv[0].guid] = bytes(depv[0].ptr)
        return NULL_GUID

    def phase2(paramv, depv, api):
        tmpl = api.edt_template_create(reader, 0, 1)
        for g, _ in made:
            api.edt_create(tmpl, depv=[g], dep_modes=[DbMode.RO])
        return NULL_GUID

    spawn_main(rt, phase2)
    stats = rt.run()
    assert stats.spilled_objects == 0
    for g, payload in made:
        assert seen[g] == payload


def test_spill_compaction_packs_file_and_unspills_bit_exact():
    """On-line compaction (spill_compact_threshold): destroying spilled
    blocks punches holes; past the frag fraction one IO-queue sweep
    rewrites the live slots packed from 0, shrinks the bump pointer, and
    the survivors still unspill bit-exact."""
    rt = _mk_runtime(spill_threshold=2, spill_compact_threshold=0.3)
    made = []

    def maker(paramv, depv, api):
        made.extend(_make_dbs(api, 8))
        return NULL_GUID

    spawn_main(rt, maker)
    stats = rt.run()
    assert stats.spilled_objects == 6
    node = rt.nodes[0]
    tail_before = node.spill_tail
    assert tail_before == 6 * 16
    # destroy three spilled victims: holes accumulate until the 0.3
    # fraction trips and a compaction sweep is submitted
    spilled = [g for g, _ in made if rt.lookup(g).spilled]
    for g in spilled[:3]:
        rt.destroy(g)
    rt.run()       # drain the sweep's MIoDone
    assert stats.spill_compactions >= 1
    assert rt.registry.value("spill.compactions") == stats.spill_compactions
    # live slots are packed from 0, free list empty, tail shrunk, and the
    # frag gauge dropped to zero
    live = [rt.lookup(g) for g in spilled[3:]]
    assert sorted(db.spill_offset for db in live) == [0, 16, 32]
    assert node.spill_free == []
    assert node.spill_tail == 3 * 16
    assert stats.spill_frag_bytes == 0
    assert os.path.getsize(node.spill_path) == 3 * 16

    # bit-exact unspill of every survivor through the ordinary grant path
    rt.spill_threshold = None
    seen = {}

    def reader(paramv, depv, api):
        seen[depv[0].guid] = bytes(depv[0].ptr)
        return NULL_GUID

    def phase2(paramv, depv, api):
        tmpl = api.edt_template_create(reader, 0, 1)
        for g, _ in made:
            if rt.try_lookup(g) is not None:
                api.edt_create(tmpl, depv=[g], dep_modes=[DbMode.RO])
        return NULL_GUID

    spawn_main(rt, phase2)
    rt.run()
    survivors = {g for g, _ in made} - set(spilled[:3])
    assert set(seen) == survivors
    for g, payload in made:
        if g in seen:
            assert seen[g] == payload
    _assert_resident_counter_consistent(rt)


def test_spill_compaction_aborts_when_victim_read_inflight():
    """A compaction sweep completing while an unspill read is in flight
    for one of its victims must abort wholesale (the reader consumes the
    old layout); the retrigger on a later release compacts cleanly."""
    if L == 0.0:
        pytest.skip("needs a nonzero IO window to race the sweep")
    rt = _mk_runtime(spill_threshold=2, spill_compact_threshold=0.1)
    made = []

    def maker(paramv, depv, api):
        made.extend(_make_dbs(api, 8))
        return NULL_GUID

    spawn_main(rt, maker)
    rt.run()
    spilled = [g for g, _ in made if rt.lookup(g).spilled]
    # punch a hole (submits the sweep) and, inside the sweep's disk
    # window, acquire a spilled victim so its unspill read is in flight
    # when the sweep completes
    rt.destroy(spilled[0])
    assert rt.nodes[0].compact_inflight
    seen = {}

    def reader(paramv, depv, api):
        seen[depv[0].guid] = bytes(depv[0].ptr)
        return NULL_GUID

    def phase2(paramv, depv, api):
        tmpl = api.edt_template_create(reader, 0, 1)
        api.edt_create(tmpl, depv=[spilled[1]], dep_modes=[DbMode.RO])
        return NULL_GUID

    spawn_main(rt, phase2)
    stats = rt.run()
    # the racing sweep aborted; the unspill (and its release of the
    # victim's slot) retriggered a clean one — payloads stay bit-exact
    assert seen[spilled[1]] == dict(made)[spilled[1]]
    assert stats.spill_compactions >= 1
    assert rt.nodes[0].spill_free == []
    for g in spilled[2:]:
        db = rt.lookup(g)
        assert db.spilled and 0 <= db.spill_offset < rt.nodes[0].spill_tail
    _assert_resident_counter_consistent(rt)
