"""Trainer on the core runtime: descent, fault tolerance, stragglers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticTokens
from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("smollm-360m").reduced()
    model = LanguageModel(cfg)
    oc = OptimizerConfig(peak_lr=1e-3, warmup_steps=5, total_steps=60)
    data = SyntheticTokens(cfg.vocab_size, batch=4, seq=32, seed=7,
                           mode="markov")
    return cfg, model, oc, data


def test_descent(setup):
    cfg, model, oc, data = setup
    tr = Trainer(model, oc, data, TrainerConfig())
    state = tr.init_or_restore(jax.random.PRNGKey(0))
    tr.run(state, 10)
    losses = [h["ce_loss"] for h in tr.history]
    assert np.mean(losses[-3:]) < np.mean(losses[:3])
    # steps ran in order through the §4 labeled step map
    assert [h["step"] for h in tr.history] == list(range(10))


def test_failure_restart_bit_exact(setup, tmp_path):
    """Fail-stop at step 8, restart from the step-5 manifest, finish — final
    params must equal an uninterrupted run bit-for-bit."""
    cfg, model, oc, data = setup

    tr_a = Trainer(model, oc, data, TrainerConfig())
    state_a = tr_a.init_or_restore(jax.random.PRNGKey(0))
    state_a = tr_a.run(state_a, 12)

    tc = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                       async_ckpt=False, fail_at_step=8)
    tr_b = Trainer(model, oc, data, tc)
    state_b = tr_b.init_or_restore(jax.random.PRNGKey(0))
    tr_b.run(state_b, 12)
    assert max(h["step"] for h in tr_b.history) == 7   # died at 8

    tc2 = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=5,
                        async_ckpt=False)
    tr_c = Trainer(model, oc, data, tc2)
    state_c = tr_c.init_or_restore(jax.random.PRNGKey(99))  # key unused
    assert tr_c.start_step == 5
    state_c = tr_c.run(state_c, 12 - tr_c.start_step)

    for a, b in zip(jax.tree_util.tree_leaves(state_a["params"]),
                    jax.tree_util.tree_leaves(state_c["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_watchdog(setup, monkeypatch):
    cfg, model, oc, data = setup
    tr = Trainer(model, oc, data, TrainerConfig(straggler_factor=1.8))
    state = tr.init_or_restore(jax.random.PRNGKey(0))

    orig_get = data.get
    import time as _t

    def slow_get(step):
        if step == 9:
            _t.sleep(1.0)       # inject a straggler
        return orig_get(step)

    monkeypatch.setattr(data, "get", slow_get)
    tr.run(state, 11)
    assert 9 in tr.straggler_steps


def test_trainer_with_file_tokens(setup, tmp_path):
    """§5 file-backed data source feeding the trainer end-to-end."""
    import numpy as np
    from repro.data import FileTokens
    from repro.data.pipeline import write_token_file

    cfg, model, oc, _ = setup
    rng = np.random.default_rng(0)
    batch, seq, nb = 4, 32, 6
    raw = rng.integers(0, cfg.vocab_size,
                       size=(nb * batch * (seq + 1),), dtype=np.int32)
    path = str(tmp_path / "tokens.bin")
    write_token_file(path, raw)
    data = FileTokens(path, cfg.vocab_size, batch, seq)

    tr = Trainer(model, oc, data, TrainerConfig())
    state = tr.init_or_restore(jax.random.PRNGKey(0))
    tr.run(state, 5)
    assert len(tr.history) == 5
    assert all(np.isfinite(h["ce_loss"]) for h in tr.history)
