import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_SAN = os.environ.get("REPRO_SANITIZE", "").lower() not in ("", "0", "false")


@pytest.fixture(autouse=_SAN)
def _ocrsan_gate():
    """With REPRO_SANITIZE set, every Runtime in the suite records (and in
    strict mode raises on) sanitizer findings.  This gate additionally
    fails any test that *recorded* a hard finding but never surfaced it —
    e.g. a runtime that never reached ``run()`` return, or a swallowed
    strict error.  Tests that intentionally seed bugs consume their
    findings via ``san_report()`` / the raised ``OcrSanError``."""
    yield
    from repro.analysis import active_sanitizers

    leaked = []
    for san in active_sanitizers():
        found = san.unconsumed_hard()
        if found:
            leaked.extend(found)
            san.consume()
    assert not leaked, "unreported sanitizer findings:\n" + \
        "\n".join(str(f) for f in leaked)
