"""§5 file IO: descriptors, chunks, write-back rules, enlargement."""
import numpy as np
import pytest

from repro.core import (ChunkOverlapError, DbMode, FileModeError, NULL_GUID,
                        Runtime, spawn_main)


def test_descriptor_delays_task(tmp_path):
    """A task depending on the descriptor runs only after the async open."""
    path = str(tmp_path / "f.bin")
    np.arange(16, dtype=np.uint32).tofile(path)
    rt = Runtime(io_latency=7.0)
    seen = {}

    def reader(paramv, depv, api):
        seen["t"] = api.rt.clock
        seen["size"] = api.file_get_size(depv[0].ptr)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb")
        tmpl = api.edt_template_create(reader, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert seen["size"] == 64
    assert seen["t"] >= 7.0            # waited for the open


def test_ro_chunk_not_written_back(tmp_path):
    path = str(tmp_path / "f.bin")
    np.full(64, 5, np.uint8).tofile(path)
    rt = Runtime()

    def toucher(paramv, depv, api):
        # RO pointer is read-only; destroying must NOT write back
        assert not depv[0].ptr.flags.writeable
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb+")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            c = api2.file_get_chunk(fg, 0, 64)
            api2.file_release(fg)
            tmpl2 = api2.edt_template_create(toucher, 0, 1)
            api2.edt_create(tmpl2, depv=[c], dep_modes=[DbMode.RO])
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    assert stats.file_bytes_written == 0
    assert np.all(np.fromfile(path, np.uint8) == 5)


def test_chunk_overlap_rejected(tmp_path):
    path = str(tmp_path / "f.bin")
    np.zeros(128, np.uint8).tofile(path)
    rt = Runtime()
    raised = {}

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb+")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            api2.file_get_chunk(fg, 0, 64)
            try:
                api2.file_get_chunk(fg, 32, 64)
            except ChunkOverlapError:
                raised["yes"] = True
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert raised.get("yes")


def test_enlarging_chunk_grows_file(tmp_path):
    """§5: a chunk past EOF enlarges a writable file even if not written."""
    path = str(tmp_path / "f.bin")
    np.zeros(32, np.uint8).tofile(path)
    rt = Runtime()

    def noop(paramv, depv, api):
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb+")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            c = api2.file_get_chunk(fg, 32, 64)     # extends to 96
            api2.file_release(fg)
            tmpl2 = api2.edt_template_create(noop, 0, 1)
            api2.edt_create(tmpl2, depv=[c], dep_modes=[DbMode.RO])
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    import os
    assert os.path.getsize(path) == 96


def test_readonly_chunk_past_eof_rejected(tmp_path):
    path = str(tmp_path / "f.bin")
    np.zeros(32, np.uint8).tofile(path)
    rt = Runtime()
    raised = {}

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb")

        def after(pv, dv, api2):
            fg = api2.file_get_guid(dv[0].ptr)
            try:
                api2.file_get_chunk(fg, 0, 64)
            except FileModeError:
                raised["yes"] = True
            return NULL_GUID

        tmpl = api.edt_template_create(after, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    assert raised.get("yes")
