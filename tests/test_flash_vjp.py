"""Differentiable Pallas flash attention: gradcheck vs the jnp twin.

The Pallas custom-VJP kernels (``repro.kernels.flash_attention``) must
match the jnp oracles — fwd and grad — across ragged sequence lengths
(block-edge padding), sliding windows, GQA groupings, and the
context-parallel stripe path (``q_offset`` global causal positioning in
*both* directions).  Sharded cases run in subprocesses with 8 forced host
devices, like ``tests/test_dist.py``.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops as kops
from repro.models.attention import flash_attention_jnp, full_attention

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _mk(key, b, sq, sk, h, kh, hd):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, sq, h, hd)),
            jax.random.normal(ks[1], (b, sk, kh, hd)),
            jax.random.normal(ks[2], (b, sk, kh, hd)))


def _grads_match(loss_a, loss_b, args, atol=3e-4):
    la, lb = loss_a(*args), loss_b(*args)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               atol=atol, rtol=atol)
    ga = jax.grad(loss_a, argnums=tuple(range(len(args))))(*args)
    gb = jax.grad(loss_b, argnums=tuple(range(len(args))))(*args)
    for x, y in zip(ga, gb):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   atol=atol, rtol=atol)


# ------------------------------------------------- deterministic gradcheck

def _check_vs_twin(seed, sq, g, kh, window, block_q, block_k):
    """Gradcheck: Pallas VJP vs flash_attention_jnp at ragged lengths.

    The jnp twin asserts block divisibility, so it runs whole-sequence
    tiles; the Pallas kernel runs the requested (non-dividing) blocks with
    zero-padded masked edge tiles — results must still agree to fp32
    tolerance, fwd and grad.
    """
    h, hd = g * kh, 16
    q, k, v = _mk(jax.random.PRNGKey(seed), 1, sq, sq, h, kh, hd)

    def loss_pallas(q_, k_, v_):
        out = kops.flash_attention(q_, k_, v_, causal=True, window=window,
                                   block_q=block_q, block_k=block_k)
        return jnp.sum(jnp.sin(out))

    def loss_twin(q_, k_, v_):
        out = flash_attention_jnp(q_, k_, v_, jnp.zeros((), jnp.float32),
                                  True, window, sq, sq)
        return jnp.sum(jnp.sin(out))

    _grads_match(loss_pallas, loss_twin, (q, k, v))


def _check_q_offset_stripe(seed, sq, off, window):
    """A q stripe at global offset ``off`` against a longer context: the
    scalar-prefetched offset must position the causal/window masks in the
    backward kernels exactly as the dense oracle does."""
    sk = sq + off
    q, k, v = _mk(jax.random.PRNGKey(seed), 2, sq, sk, 4, 2, 16)

    def loss_pallas(q_, k_, v_):
        out = kops.flash_attention(q_, k_, v_, jnp.float32(off),
                                   causal=True, window=window,
                                   block_q=16, block_k=16)
        return jnp.sum(jnp.sin(out))

    def loss_dense(q_, k_, v_):
        out = full_attention(q_, k_, v_, causal=True, window=window,
                             q_offset=off)
        return jnp.sum(jnp.sin(out))

    _grads_match(loss_pallas, loss_dense, (q, k, v))


@pytest.mark.parametrize("seed,sq,g,kh,window,block_q,block_k", [
    (0, 100, 2, 2, 0, 32, 32),      # ragged vs both block sizes, GQA
    (1, 65, 1, 2, 0, 16, 48),       # sq % block_k != 0, MQA-ish
    (2, 96, 3, 1, 24, 32, 32),      # sliding window, MHA group 3
    (3, 50, 2, 2, 13, 16, 32),      # window + ragged
])
def test_pallas_vjp_matches_jnp_twin(seed, sq, g, kh, window, block_q,
                                     block_k):
    _check_vs_twin(seed, sq, g, kh, window, block_q, block_k)


@pytest.mark.parametrize("seed,sq,off,window", [
    (0, 32, 64, 0), (1, 24, 40, 9), (2, 17, 32, 0),
])
def test_pallas_vjp_q_offset_stripe(seed, sq, off, window):
    _check_q_offset_stripe(seed, sq, off, window)


# ------------------------------------------------------- hypothesis sweep

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:                                    # pragma: no cover
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 1000),
           sq=st.integers(17, 96),        # rarely a block multiple
           g=st.sampled_from([1, 2, 3]),
           kh=st.sampled_from([1, 2]),
           window=st.sampled_from([0, 0, 7, 20]),
           block_q=st.sampled_from([16, 32]),
           block_k=st.sampled_from([16, 32, 48]))
    def test_pallas_vjp_hypothesis_sweep(seed, sq, g, kh, window, block_q,
                                         block_k):
        _check_vs_twin(seed, sq, g, kh, window, block_q, block_k)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 1000), sq=st.integers(8, 48),
           off=st.integers(0, 64), window=st.sampled_from([0, 9]))
    def test_pallas_vjp_q_offset_hypothesis_sweep(seed, sq, off, window):
        _check_q_offset_stripe(seed, sq, off, window)
else:                                                  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_pallas_vjp_hypothesis_sweep():
        pass


# --------------------------------------------------------- branch boundary

@pytest.mark.parametrize("window", [0, 12])
def test_attn_local_branches_agree_at_boundary(window):
    """`_attn_local` flips between the Pallas flash kernel and the dense
    reference on a length threshold: both branches must agree (fwd and
    grad) at the boundary, windowed or not.  This also locks in the ragged
    fix — the flash branch no longer falls back to the dense O(S²) path
    when the stripe length doesn't divide the block sizes."""
    from repro.dist.flash import _attn_local
    min_seq = 64
    bq = bk = 16
    for sq in (min_seq, min_seq + 1):          # dense side, flash side
        q, k, v = _mk(jax.random.PRNGKey(sq + window), 2, sq, sq, 4, 2, 32)

        def loss_local(q_, k_, v_):
            out = _attn_local(q_, k_, v_, window=window, block_q=bq,
                              block_k=bk, min_seq=min_seq)
            return jnp.sum(jnp.sin(out))

        def loss_dense(q_, k_, v_):
            out = full_attention(q_, k_, v_, causal=True, window=window)
            return jnp.sum(jnp.sin(out))

        _grads_match(loss_local, loss_dense, (q, k, v))


# ------------------------------------------------------- sharded (8 dev)

def _run(code: str):
    full = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys\nsys.path.insert(0, 'src')\n" + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", full], capture_output=True,
                         text=True, cwd=ROOT, timeout=560)
    assert out.returncode == 0 and "PASS" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])


def test_context_parallel_stripes_run_pallas_vjp():
    """Context-parallel causal_attention on the Pallas kernel: per-stripe
    ``q_offset`` flows into the backward kernels through scalar prefetch;
    sharded grads must equal the single-device Pallas grads."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs import get_config
    from repro.dist.flash import causal_attention
    from repro.dist.sharding import use_mesh
    from repro.models.attention import flash_min_seq

    cfg = get_config("qwen2-7b").reduced()   # 6 % 4 != 0 → seq strategy
    cfg = dataclasses.replace(cfg, num_heads=6, num_kv_heads=2,
                              attn_block_q=8, attn_block_k=8,
                              attn_flash_min_seq=8, sliding_window=24)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, hd = 2, 128, cfg.head_dim
    # every 32-row stripe (s / model axis 4) must clear the threshold, or
    # this test silently degrades to the dense fallback
    assert s // 4 > flash_min_seq(cfg), (s // 4, flash_min_seq(cfg))
    q = jax.random.normal(ks[0], (b, s, 6, hd))
    k = jax.random.normal(ks[1], (b, s, 2, hd))
    v = jax.random.normal(ks[2], (b, s, 2, hd))

    def loss(a, b_, c):
        return jnp.sum(jnp.sin(causal_attention(
            a, b_, c, cfg=cfg, window=cfg.sliding_window)))

    ref = causal_attention(q, k, v, cfg=cfg, window=cfg.sliding_window)
    g_ref = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        got = jax.jit(lambda a, b_, c: causal_attention(
            a, b_, c, cfg=cfg, window=cfg.sliding_window))(q, k, v)
        g_got = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               atol=2e-4, rtol=2e-4)
    for a, b_ in zip(g_ref, g_got):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=5e-4, rtol=5e-4)
    print("PASS")
    """)


def test_use_mesh_train_step_runs_pallas_vjp():
    """End-to-end acceptance: a ``use_mesh`` train step whose attention
    length clears ``attn_flash_min_seq`` differentiates through the Pallas
    kernels and matches the single-device step."""
    _run("""
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs import get_config
    from repro.models.model import LanguageModel
    from repro.optim import OptimizerConfig
    from repro.train.steps import init_train_state, make_train_step
    from repro.dist.sharding import use_mesh
    from repro.data import SyntheticTokens
    from repro.models.attention import flash_min_seq

    cfg = get_config("llama3.2-3b").reduced()
    cfg = dataclasses.replace(cfg, attn_block_q=8, attn_block_k=8,
                              attn_flash_min_seq=8)
    # seq 68: kv_heads 2 % model 4 != 0 → context-parallel stripes of 17
    # (> flash_min_seq 16, and ragged vs the 8-row blocks) on the mesh
    # side; 68 > 16 on the single-device side — both run the Pallas VJP
    seq = 68
    assert seq // 4 > flash_min_seq(cfg), (seq // 4, flash_min_seq(cfg))
    model = LanguageModel(cfg)
    oc = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=50)
    data = SyntheticTokens(cfg.vocab_size, batch=4, seq=seq, seed=5)
    step = make_train_step(model, oc)

    s1 = init_train_state(model, jax.random.PRNGKey(0), oc)
    b = {k: jnp.asarray(v) for k, v in data.get(0).items()}
    s1b, m1 = jax.jit(step)(s1, b)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    s2 = init_train_state(model, jax.random.PRNGKey(0), oc)
    with use_mesh(mesh):
        s2b, m2 = jax.jit(step)(s2, b)

    assert abs(float(m1["ce_loss"]) - float(m2["ce_loss"])) < 1e-3
    for a, c in zip(jax.tree_util.tree_leaves(s1b["params"]),
                    jax.tree_util.tree_leaves(s2b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=3e-4, rtol=3e-4)
    print("PASS")
    """)
