"""Satellite gauges: ONCE-event tombstone shard retirement and spill-file
fragmentation.

§3 keeps satisfied ONCE events as tombstones so late ``add_dependence``
calls replay instead of erroring.  A shard whose members are *all*
tombstones retires into a compact ``{seq: (guid, payload)}`` side table
(``Stats.tombstone_shards_retired``), and late arrivals synthesize the
tombstone back from it — replay fidelity without the per-object cost.

``Stats.spill_frag_bytes`` is the hole total in the per-node spill files
(re-materialized victims return their slots to the free list), refreshed
at every ``run()`` return.
"""
from repro.core import NULL_GUID, ObjectKind, Runtime, TaskCtx, spawn_main


def test_fully_tombstoned_event_shards_retire():
    rt = Runtime(shard_bits=2)
    ctx = TaskCtx(rt, 0, None)
    db, buf = ctx.db_create(8)
    buf[:] = 3
    evs = [ctx.event_create() for _ in range(16)]
    for e in evs:
        ctx.event_satisfy(e, db)
    rt.run()

    assert rt.stats.tombstone_shards_retired >= 1
    table = rt.nodes[0].objects
    retired = [gp for idx in table._retired_events.values()
               for gp in idx.values()]
    assert retired
    g, payload = retired[0]
    assert payload == db

    # a late lookup synthesizes the tombstone: satisfied, with payload
    o = rt.try_lookup(g)
    assert o.satisfied and o.destroyed and o.payload == db

    # live (unsatisfied) events keep their shards: none of them retired
    live = ctx.event_create()
    rt.run()
    assert rt.try_lookup(live).satisfied is False


def test_late_dependence_on_retired_event_replays():
    rt = Runtime(shard_bits=2)
    ctx = TaskCtx(rt, 0, None)
    db, buf = ctx.db_create(8)
    buf[:] = 9
    for _ in range(16):
        ctx.event_satisfy(ctx.event_create(), db)
    rt.run()
    table = rt.nodes[0].objects
    g, _payload = next(iter(next(iter(
        table._retired_events.values())).values()))

    seen = []

    def late(paramv, depv, api):
        seen.append(bytes(depv[0].ptr))
        return NULL_GUID

    tmpl = ctx.edt_template_create(late, 0, 1)
    ctx.edt_create(tmpl, depv=[g])
    rt.run()
    assert seen == [bytes([9] * 8)]


def test_destroy_of_retired_event_drops_the_entry():
    rt = Runtime(shard_bits=2)
    ctx = TaskCtx(rt, 0, None)
    for _ in range(16):
        ctx.event_satisfy(ctx.event_create(), NULL_GUID)
    rt.run()
    table = rt.nodes[0].objects
    g, _ = next(iter(next(iter(table._retired_events.values())).values()))
    before = table.live_count(ObjectKind.EVENT) \
        if hasattr(table, "live_count") else None

    ctx.event_destroy(g)
    rt.run()
    assert rt.try_lookup(g) is None
    if before is not None:
        assert table.live_count(ObjectKind.EVENT) == before


def test_spill_frag_bytes_tracks_freed_interior_slots():
    rt = Runtime(spill_threshold=2, io_latency=0.5)
    made = []

    def maker(paramv, depv, api):
        for i in range(8):
            g, b = api.db_create(16)
            b[:] = i + 1
            made.append(g)
        return NULL_GUID

    spawn_main(rt, maker)
    rt.run()
    spilled = [g for g in made if rt.lookup(g).spilled]
    assert len(spilled) == 6
    # victims packed contiguously from offset 0: no holes yet
    assert rt.stats.spill_frag_bytes == 0

    # re-materialize a strictly interior victim: its slot becomes a hole
    mid = sorted(spilled, key=lambda g: rt.lookup(g).spill_offset)[2]
    rt.spill_threshold = None

    def reader(paramv, depv, api):
        assert int(depv[0].ptr[0]) != 0
        return NULL_GUID

    ctx = TaskCtx(rt, 0, None)
    tmpl = ctx.edt_template_create(reader, 0, 1)
    ctx.edt_create(tmpl, depv=[mid])
    rt.run()
    assert not rt.lookup(mid).spilled
    assert rt.stats.spill_frag_bytes == 16
