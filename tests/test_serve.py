"""Continuous-batching serve engine: §4 labeled-GUID request slots,
§6 page-partition lifecycle, and spill-eviction bit-exactness."""
import numpy as np
import pytest

from repro.core import (EDT_PROP_MAPPED, NULL_GUID, PartitionOverlapError,
                        Runtime, TaskCtx, spawn_main)
from repro.serve.engine import (ServeEngine, StepCost, SyntheticBackend,
                                poisson_workload, run_static, _slot_creator)


# ------------------------------------------------- §4 labeled request slots

def _race_slot(order):
    """Two admissions race a ``map_get`` on the same slot label at the same
    virtual timestamp, in both arrival orders."""
    rt = Runtime(num_nodes=2)
    ctx = TaskCtx(rt, 0, None)
    m = ctx.map_create(4, _slot_creator, paramv=(64,))
    rt.run()                      # settle the map LID binding
    m = rt.resolve(m)
    got = {}

    def admit(tag):
        def body(paramv, depv, api):
            lid = api.map_get(m, 1)

            def read(pv, dv, a):
                got[tag] = dv[0].guid
                return NULL_GUID

            tmpl = api.edt_template_create(read, 0, 1)
            api.edt_create(tmpl, depv=[lid], duration=0.0)
            return NULL_GUID
        return body

    # same timestamp, from different nodes, in the given spawn order
    for tag, node in order:
        spawn_main(rt, admit(tag), node=node, duration=0.0)
    stats = rt.run()
    return got, stats


@pytest.mark.parametrize("order", [
    [("a", 0), ("b", 1)],
    [("b", 1), ("a", 0)],
])
def test_slot_allocation_race_free_both_orders(order):
    got, stats = _race_slot(order)
    # §4: the creator ran exactly once no matter the arrival order, and
    # both racers resolved to the same slot GUID
    assert stats.creator_calls == 1
    assert got["a"] == got["b"]
    assert got["a"] != NULL_GUID


def test_slot_reuse_after_retirement_memoizes_creator():
    reqs = poisson_workload(12, rate=500.0, prompt_len=(4, 8), gen=(2, 4),
                            seed=3)
    eng = ServeEngine(SyntheticBackend(page_size=4), b_cap=3, pool_pages=16,
                      max_pages=4)
    eng.run(reqs)
    # 12 requests over 3 slots: retirement frees the slot index, a later
    # admission's map_get returns the memoized entry — creator never reruns
    assert eng.rt.stats.creator_calls == 3
    for r in reqs:
        assert len(r.out) == r.gen and r.t_done >= 0


# ---------------------------------------------- §6 page-partition lifecycle

def test_pages_disjoint_and_survive_slot_reuse():
    eng = ServeEngine(SyntheticBackend(page_size=4), b_cap=3, pool_pages=10,
                      max_pages=4)
    live = {}
    orig = ServeEngine._alloc_pages

    def spy(self, sess, n):
        orig(self, sess, n)
        live[sess.req.rid] = list(sess.pages)
        owned = [p for s in self.sessions.values() for p in s.pages]
        owned += sess.pages if sess.req.rid not in {
            s.req.rid for s in self.sessions.values()} else []
        assert len(owned) == len(set(owned)), "physical page double-owned"

    ServeEngine._alloc_pages = spy
    try:
        reqs = poisson_workload(9, rate=400.0, prompt_len=(4, 10),
                                gen=(3, 6), seed=5)
        eng.run(reqs)
    finally:
        ServeEngine._alloc_pages = orig
    for r in reqs:
        exp = [(r.rid * 2654435761 + c * 97) % 50257
               for c in range(len(r.prompt), len(r.prompt) + r.gen)]
        assert r.out == exp


def test_live_page_range_rejects_overlapping_partition():
    eng = ServeEngine(SyntheticBackend(page_size=4), b_cap=2, pool_pages=8,
                      max_pages=4)
    req = poisson_workload(1, rate=100.0, prompt_len=(6, 6), gen=(64, 64),
                           seed=0)[0]
    sess = eng._admit(req)
    pb = eng.backend.page_bytes
    # the §6 runtime, not engine bookkeeping, is what makes double
    # ownership impossible: re-partitioning a page a session owns throws
    with pytest.raises(PartitionOverlapError):
        eng.ctx.db_partition(eng.cache_db, [(sess.pages[0] * pb, pb)])


def test_retirement_releases_pages_for_repartition():
    eng = ServeEngine(SyntheticBackend(page_size=4), b_cap=2, pool_pages=8,
                      max_pages=4)
    req = poisson_workload(1, rate=100.0, prompt_len=(6, 6), gen=(1, 1),
                           seed=0)[0]
    sess = eng._admit(req)       # gen=1 retires inside _admit
    assert req.t_done >= 0 and not eng.sessions
    pb = eng.backend.page_bytes
    guids = eng.ctx.db_partition(eng.cache_db, [(0, pb)])  # range is free
    assert len(guids) == 1


# -------------------------------------------------- spill-evicted sessions

def test_spill_pressure_tokens_exact_and_spills():
    reqs = poisson_workload(30, rate=300.0, prompt_len=(8, 24), gen=(8, 24),
                            seed=1)
    eng = ServeEngine(SyntheticBackend(page_size=8), b_cap=8, pool_pages=20,
                      max_pages=6, resident_budget=4)
    m = eng.run(reqs)
    # sessions exceeded the resident budget: archives really spilled, and
    # SyntheticBackend.restore_row verified every byte round-tripped
    assert m["spilled_objects"] > 0
    assert m["evictions"] > 0 and m["resumes"] > 0
    for r in reqs:
        exp = [(r.rid * 2654435761 + c * 97) % 50257
               for c in range(len(r.prompt), len(r.prompt) + r.gen)]
        assert r.out == exp


def test_continuous_beats_static_baseline():
    reqs = poisson_workload(40, rate=120.0, prompt_len=(8, 32), gen=(4, 16),
                            seed=0)
    eng = ServeEngine(SyntheticBackend(page_size=8), b_cap=8, pool_pages=64,
                      max_pages=8)
    m = eng.run(reqs)
    s = run_static(reqs, b_cap=8)
    assert m["tok_per_s"] > s["tok_per_s"]
    assert m["p99_latency_s"] < s["p99_latency_s"]


def test_model_backend_bit_exact_through_spill():
    import dataclasses as dc

    import jax

    from repro.configs import get_config
    from repro.models.model import LanguageModel
    from repro.serve.engine import ModelBackend, Request

    cfg = get_config("smollm-360m").reduced()   # fp32: equality is bit-exact
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (10, 7, 12)]

    def run(pool_pages, budget):
        bk = ModelBackend(model, params, pool_pages=pool_pages, page_size=8,
                          prompt_pad=16)
        eng = ServeEngine(bk, b_cap=3, pool_pages=pool_pages, max_pages=4,
                          resident_budget=budget)
        reqs = [Request(rid=i, arrival=1e-4 * i, prompt=p.copy(), gen=8)
                for i, p in enumerate(prompts)]
        return [r.out for r in reqs], eng.run(reqs)

    ample, _ = run(pool_pages=16, budget=None)
    tight, m = run(pool_pages=4, budget=2)      # forces evict + disk spill
    assert m["evictions"] > 0 and m["spilled_objects"] > 0
    assert ample == tight
