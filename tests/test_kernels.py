"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _mk_qkv(key, b, s, h, kh, hd, hd_v=None, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, kh, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, kh, hd_v or hd),
                          jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kh,hd,bq,bk", [
    (1, 128, 2, 1, 32, 64, 64),
    (2, 256, 4, 2, 64, 64, 128),
    (1, 192, 3, 3, 16, 64, 96),     # MHA, non-pow2 heads
    (2, 128, 8, 2, 128, 128, 128),  # single block pair
])
def test_flash_attention_shapes(b, s, h, kh, hd, bq, bk):
    q, k, v = _mk_qkv(jax.random.PRNGKey(b * s + h), b, s, h, kh, hd)
    out = ops.flash_attention(q, k, v, block_q=bq, block_k=bk, interpret=True)
    want = jnp.transpose(
        ref.flash_attention_ref(jnp.transpose(q, (0, 2, 1, 3)),
                                jnp.transpose(k, (0, 2, 1, 3)),
                                jnp.transpose(v, (0, 2, 1, 3))),
        (0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 3e-5),
                                       (jnp.bfloat16, 3e-2)])
def test_flash_attention_dtypes(dtype, tol):
    q, k, v = _mk_qkv(jax.random.PRNGKey(0), 2, 128, 4, 2, 32, dtype=dtype)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    want = jnp.transpose(
        ref.flash_attention_ref(
            jnp.transpose(q, (0, 2, 1, 3)).astype(jnp.float32),
            jnp.transpose(k, (0, 2, 1, 3)).astype(jnp.float32),
            jnp.transpose(v, (0, 2, 1, 3)).astype(jnp.float32)),
        (0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(want), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [32, 100])
def test_flash_attention_window(window):
    q, k, v = _mk_qkv(jax.random.PRNGKey(7), 2, 256, 4, 2, 32)
    out = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                              interpret=True)
    want = jnp.transpose(
        ref.flash_attention_ref(jnp.transpose(q, (0, 2, 1, 3)),
                                jnp.transpose(k, (0, 2, 1, 3)),
                                jnp.transpose(v, (0, 2, 1, 3)),
                                window=window),
        (0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 2, 16, 8, 16),
    (2, 128, 4, 32, 16, 32),
    (1, 96, 2, 64, 32, 32),
    (2, 64, 8, 8, 64, 64),          # chunk == seq (single chunk)
])
def test_ssd_scan_shapes(b, s, h, p, n, chunk):
    ks = jax.random.split(jax.random.PRNGKey(b + s + h), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y, st = ops.ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=True)
    y_ref, st_ref = ref.ssd_scan_ref(x, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=2e-3, rtol=2e-3)


def test_ssd_scan_vs_sequential():
    """Kernel (chunked) against the O(S) sequential recurrence oracle."""
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    b, s, h, p, n = 2, 64, 2, 8, 4
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    y, st = ops.ssd_scan(x, dt, A, B, C, chunk=16, interpret=True)
    y_ref, st_ref = ref.ssd_scan_sequential(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=2e-3, rtol=2e-3)


def test_ssd_scan_bf16():
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    b, s, h, p, n = 1, 64, 2, 16, 8
    x = jax.random.normal(ks[0], (b, s, h, p)).astype(jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    B = jax.random.normal(ks[3], (b, s, n)).astype(jnp.bfloat16)
    C = jax.random.normal(ks[4], (b, s, n)).astype(jnp.bfloat16)
    y, st = ops.ssd_scan(x, dt, A, B, C, chunk=16, interpret=True)
    y_ref, st_ref = ref.ssd_scan_ref(x.astype(jnp.float32), dt, A,
                                     B.astype(jnp.float32),
                                     C.astype(jnp.float32), chunk=16)
    np.testing.assert_allclose(np.asarray(y, dtype=np.float32),
                               np.asarray(y_ref), atol=0.15, rtol=0.1)


@pytest.mark.parametrize("nblk_dst,nblk_src,dst_off,src_off,size", [
    (4, 4, 1, 2, 1),
    (8, 8, 0, 4, 2),
    (2, 6, 1, 0, 1),
])
def test_partition_copy(nblk_dst, nblk_src, dst_off, src_off, size):
    blk = 256 * 128
    dst = jnp.zeros((nblk_dst * blk,), jnp.uint8)
    src = (jnp.arange(nblk_src * blk) % 251).astype(jnp.uint8)
    out = ops.partition_copy_bytes(dst, src, dst_off=dst_off * blk,
                                   src_off=src_off * blk, size=size * blk,
                                   interpret=True)
    expect = np.zeros(nblk_dst * blk, np.uint8)
    expect[dst_off * blk: (dst_off + size) * blk] = \
        np.asarray(src)[src_off * blk: (src_off + size) * blk]
    assert np.array_equal(np.asarray(out), expect)


@pytest.mark.parametrize("ranges", [
    # (dst_off, src_off, size) in bytes — lane-aligned, NOT 32 KiB-aligned
    ((0, 128, 384),),
    ((128, 0, 256), (1024, 2048, 128), (4096, 512, 640)),
    ((0, 0, 128 * 300), (128 * 700, 128 * 350, 128 * 257)),  # span blocks
])
def test_multi_partition_copy_ragged(ranges):
    """Fused N-range copy vs the numpy reference, bit-exact, at lane
    (128 B) granularity with non-block-aligned edge tiles."""
    rng = np.random.default_rng(sum(r[0] for r in ranges))
    n = 128 * 1024
    dst = rng.integers(0, 255, n).astype(np.uint8)
    src = rng.integers(0, 255, n).astype(np.uint8)
    out = ops.multi_partition_copy_bytes(
        jnp.asarray(dst), jnp.asarray(src), ranges, interpret=True)
    expect = dst.copy()
    for d_off, s_off, size in ranges:
        expect[d_off:d_off + size] = src[s_off:s_off + size]
    assert np.array_equal(np.asarray(out), expect)


def test_multi_partition_copy_many_ranges_one_call():
    """A 64-partition set materializes through a single pallas_call."""
    n = 64 * 1024
    dst = np.zeros(n, np.uint8)
    src = (np.arange(n) % 251).astype(np.uint8)
    ranges = tuple((i * 1024, ((i + 7) % 64) * 1024, 896) for i in range(64))
    out = ops.multi_partition_copy_bytes(
        jnp.asarray(dst), jnp.asarray(src), ranges, interpret=True)
    expect = dst.copy()
    for d_off, s_off, size in ranges:
        expect[d_off:d_off + size] = src[s_off:s_off + size]
    assert np.array_equal(np.asarray(out), expect)


def test_multi_partition_copy_rejects_overlap_and_misalignment():
    dst = jnp.zeros(4096, jnp.uint8)
    src = jnp.ones(4096, jnp.uint8)
    with pytest.raises(ValueError, match="overlap"):
        ops.multi_partition_copy_bytes(
            dst, src, ((0, 0, 512), (384, 1024, 256)), interpret=True)
    with pytest.raises(ValueError, match="aligned"):
        ops.multi_partition_copy_bytes(
            dst, src, ((0, 0, 100),), interpret=True)
    with pytest.raises(ValueError, match="out of bounds"):
        ops.multi_partition_copy_bytes(
            dst, src, ((3968, 0, 256),), interpret=True)
    # overlapping *sources* are fine (a gather), only destinations must be
    # disjoint
    out = ops.multi_partition_copy_bytes(
        dst, src, ((0, 0, 256), (256, 0, 256)), interpret=True)
    assert np.asarray(out)[:512].sum() == 512


def test_partition_copy_bytes_lane_aligned():
    """partition_copy_bytes now accepts 128-byte-aligned offsets (the old
    32 KiB tile constraint routes to the masked-edge kernel)."""
    n = 128 * 600
    rng = np.random.default_rng(3)
    dst = rng.integers(0, 255, n).astype(np.uint8)
    src = rng.integers(0, 255, n).astype(np.uint8)
    d_off, s_off, size = 128 * 3, 128 * 11, 128 * 257
    out = ops.partition_copy_bytes(jnp.asarray(dst), jnp.asarray(src),
                                   dst_off=d_off, src_off=s_off, size=size,
                                   interpret=True)
    expect = dst.copy()
    expect[d_off:d_off + size] = src[s_off:s_off + size]
    assert np.array_equal(np.asarray(out), expect)


# ------------------------------------------- HBM-staged DMA copy path
# Buffers past DMA_STAGE_BYTES route through the double-buffered
# make_async_copy kernel instead of the block-gather grid; same ranges
# API, same arrival-order semantics, bit-exact.

from repro.kernels import partition_copy as pc  # noqa: E402


def _dma_buffers(extra_rows=4096, seed=0):
    """dst/src just past the staging threshold (~16.5 MiB each)."""
    rows = pc.DMA_STAGE_BYTES // pc.LANES + extra_rows
    n = rows * pc.LANES
    rng = np.random.default_rng(seed)
    dst = rng.integers(0, 255, n, dtype=np.uint8)
    src = rng.integers(0, 255, n, dtype=np.uint8)
    assert pc.dma_staged(n, n)
    return dst, src, n


def test_dma_staged_threshold_routing(monkeypatch):
    """Exactly at the threshold stays on the batched grid path; one byte
    past it stages through HBM DMA — proven by blowing up the path the
    call must NOT take."""
    thr = pc.DMA_STAGE_BYTES
    assert not pc.dma_staged(thr, thr)
    assert pc.dma_staged(thr + 1, 0)
    assert pc.dma_staged(0, thr + 1)

    def boom(*a, **kw):
        raise AssertionError("wrong copy path")

    # small buffers must not touch the DMA kernel
    monkeypatch.setattr(pc, "_multi_partition_copy_dma", boom)
    small = ops.multi_partition_copy_bytes(
        jnp.zeros(4096, jnp.uint8), jnp.ones(4096, jnp.uint8),
        ((0, 0, 512),), interpret=True)
    assert np.asarray(small)[:512].sum() == 512
    monkeypatch.undo()

    # big buffers must not touch the batched grid kernel
    dst, src, _ = _dma_buffers(seed=1)
    monkeypatch.setattr(pc, "_multi_partition_copy_impl", boom)
    out = ops.multi_partition_copy_bytes(
        jnp.asarray(dst), jnp.asarray(src), ((0, 128, 128 * 64),),
        interpret=True)
    expect = dst.copy()
    expect[:128 * 64] = src[128:128 + 128 * 64]
    assert np.array_equal(np.asarray(out), expect)


def test_multi_partition_copy_dma_bit_exact():
    """>16 MiB buffers: the DMA-staged kernel is bit-exact vs the numpy
    range assignment across ragged, non-chunk-aligned ranges spanning
    the whole buffer."""
    dst, src, n = _dma_buffers(seed=2)
    L = pc.LANES
    rows = n // L
    ranges = (
        (0, 512 * L, 3000 * L),                        # head of dst
        (50_000 * L, 0, 7000 * L),                     # middle
        ((rows - 5001) * L, 60_000 * L, 5000 * L),     # tail of dst
        (40_000 * L, (rows - 129) * L, 128 * L),       # tail of src
        (30_000 * L, 30_000 * L, 257 * L),             # odd row count
    )
    out = ops.multi_partition_copy_bytes(
        jnp.asarray(dst), jnp.asarray(src), ranges, interpret=True)
    expect = dst.copy()
    for d_off, s_off, size in ranges:
        expect[d_off:d_off + size] = src[s_off:s_off + size]
    assert np.array_equal(np.asarray(out), expect)


def test_multi_partition_copy_dma_hazard_ordering():
    """DMA path keeps the batched path's hazard semantics: overlapping
    sources are a gather from the ORIGINAL src, non-copied dst rows
    survive the in-place read-modify-write (the double-buffered chunk
    merge must not tear adjacent ranges), and overlapping destinations
    are rejected up front."""
    dst, src, n = _dma_buffers(seed=3)
    L = pc.LANES
    # two ranges gather the same source rows; two more land on adjacent
    # dst rows so their chunks share RMW traffic with the gap between
    ranges = (
        (0, 1000 * L, 512 * L),
        (1024 * L, 1000 * L, 512 * L),
        (1536 * L, 256 * L, 512 * L),
        (2049 * L, 256 * L, 511 * L),
    )
    out = ops.multi_partition_copy_bytes(
        jnp.asarray(dst), jnp.asarray(src), ranges, interpret=True)
    expect = dst.copy()
    for d_off, s_off, size in ranges:
        expect[d_off:d_off + size] = src[s_off:s_off + size]
    assert np.array_equal(np.asarray(out), expect)

    with pytest.raises(ValueError, match="overlap"):
        ops.multi_partition_copy_bytes(
            jnp.asarray(dst), jnp.asarray(src),
            ((0, 0, 512 * L), (256 * L, 2048 * L, 512 * L)),
            interpret=True)


def test_flash_mla_dims():
    """qk head_dim ≠ v head_dim (deepseek MLA layout)."""
    q, k, v = _mk_qkv(jax.random.PRNGKey(9), 2, 128, 4, 4, 48, hd_v=32)
    out = ops.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    assert out.shape == (2, 128, 4, 32)
    want = jnp.transpose(
        ref.flash_attention_ref(jnp.transpose(q, (0, 2, 1, 3)),
                                jnp.transpose(k, (0, 2, 1, 3)),
                                jnp.transpose(v, (0, 2, 1, 3))),
        (0, 2, 1, 3))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


@pytest.mark.parametrize("cur,window,block_s", [
    (37, 0, 64), (256, 0, 64), (100, 48, 32), (1, 0, 128), (255, 16, 64),
])
def test_flash_decode(cur, window, block_s):
    """Flash-decode kernel vs the seq-major decode oracle (head-major cache)."""
    ks = jax.random.split(jax.random.PRNGKey(cur + window), 3)
    b, kh, g, hd, s = 2, 2, 3, 32, 256
    q = jax.random.normal(ks[0], (b, 1, kh * g, hd))
    kc = jax.random.normal(ks[1], (b, kh, s, hd))
    vc = jax.random.normal(ks[2], (b, kh, s, hd))
    o = ops.flash_decode(q, kc, vc, jnp.asarray(cur), window=window,
                         block_s=block_s, interpret=True)
    want = ref.flash_decode_ref(q, kc, vc, jnp.asarray(cur), window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(want),
                               atol=3e-5, rtol=3e-5)


def test_flash_decode_bf16():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    b, kh, g, hd, s = 1, 2, 2, 64, 128
    q = jax.random.normal(ks[0], (b, 1, kh * g, hd)).astype(jnp.bfloat16)
    kc = jax.random.normal(ks[1], (b, kh, s, hd)).astype(jnp.bfloat16)
    vc = jax.random.normal(ks[2], (b, kh, s, hd)).astype(jnp.bfloat16)
    o = ops.flash_decode(q, kc, vc, jnp.asarray(100), block_s=64,
                         interpret=True)
    want = ref.flash_decode_ref(q.astype(jnp.float32),
                                kc.astype(jnp.float32),
                                vc.astype(jnp.float32), jnp.asarray(100))
    np.testing.assert_allclose(np.asarray(o, dtype=np.float32),
                               np.asarray(want), atol=5e-2, rtol=5e-2)
