"""End-to-end system test: train through the OCR-runtime trainer with §5
chunked checkpoints, restore, then serve tokens from the trained model."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import ckpt
from repro.configs import get_config
from repro.data import SyntheticTokens
from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig


def test_train_checkpoint_serve_roundtrip(tmp_path):
    cfg = get_config("llama3.2-3b").reduced()
    model = LanguageModel(cfg)
    oc = OptimizerConfig(peak_lr=5e-3, warmup_steps=10, total_steps=400,
                         weight_decay=0.0)
    data = SyntheticTokens(cfg.vocab_size, batch=16, seq=32, seed=11,
                           mode="markov")

    tc = TrainerConfig(ckpt_dir=str(tmp_path), ckpt_every=20,
                       async_ckpt=False)
    tr = Trainer(model, oc, data, tc)
    state = tr.init_or_restore(jax.random.PRNGKey(0))
    state = tr.run(state, 60)

    losses = [h["ce_loss"] for h in tr.history]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses

    # the model learned the markov chain: greedy decode follows it
    tree, step = ckpt.restore(str(tmp_path))
    assert step == 60
    params = jax.tree_util.tree_map(jnp.asarray, tree)["params"]

    tokens = jnp.asarray([[7, (7 * 31 + 7) % cfg.vocab_size]], jnp.int32)
    logits, cache = jax.jit(model.prefill)(params, {"tokens": tokens})
    want = (int(tokens[0, -1]) * 31 + 7) % cfg.vocab_size
    top5 = np.argsort(np.asarray(logits[0]))[-5:]
    assert want in top5, (want, top5)
    pred = want

    # decode two more steps following the chain
    # grow the seq axis (axis -2 of head-major (L,B,K,S,hd)) by 4 tokens
    cache = jax.tree_util.tree_map(
        lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 2)
                          + [(0, 4), (0, 0)]),
        cache)
    cur = jnp.asarray(tokens.shape[1], jnp.int32)
    tok = jnp.asarray([[pred]], jnp.int32)
    hits = 0
    for i in range(2):
        logits2, cache = jax.jit(model.decode_step)(params, cache, tok,
                                                    cur + i)
        want_i = (int(tok[0, 0]) * 31 + 7) % cfg.vocab_size
        top5_i = np.argsort(np.asarray(logits2[0]))[-5:]
        if want_i in top5_i:
            hits += 1
        tok = jnp.asarray([[want_i]], jnp.int32)
    assert hits >= 1
