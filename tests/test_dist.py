"""Distributed parity: sharded paths must equal the single-device oracle.

These run in subprocesses with ``--xla_force_host_platform_device_count=8``
so the main test session keeps seeing one device (per the dry-run contract).
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str):
    full = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys\nsys.path.insert(0, 'src')\n" + textwrap.dedent(code))
    out = subprocess.run([sys.executable, "-c", full], capture_output=True,
                         text=True, cwd=ROOT, timeout=560)
    assert out.returncode == 0 and "PASS" in out.stdout, \
        (out.stdout[-1500:], out.stderr[-3000:])


def test_moe_shardmap_parity():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models import moe as M
    from repro.dist.sharding import use_mesh
    import dataclasses

    cfg = get_config("deepseek-v2-236b").reduced()
    cfg = dataclasses.replace(cfg, num_experts=8, experts_per_token=2)
    params = M.moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))

    y_ref, aux_ref = M.moe_ffn(params, x, cfg)          # no mesh

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        y_sh, aux_sh = jax.jit(lambda p, xx: M.moe_ffn(p, xx, cfg))(params, x)

    np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_sh),
                               atol=2e-4, rtol=2e-4)
    assert abs(float(aux_ref["loss"]) - float(aux_sh["loss"])) < 1e-5
    assert float(aux_sh["dropped"]) == float(aux_ref["dropped"]) == 0.0

    # gradients too
    def loss(p, xx):
        y, a = M.moe_ffn(p, xx, cfg)
        return jnp.sum(y ** 2) + 0.01 * a["loss"]
    g_ref = jax.grad(loss)(params, x)
    with use_mesh(mesh):
        g_sh = jax.jit(jax.grad(loss))(params, x)
    for a, b in zip(jax.tree_util.tree_leaves(g_ref),
                    jax.tree_util.tree_leaves(g_sh)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=5e-3, rtol=5e-3)
    print("PASS")
    """)


def test_seq_parallel_attention_parity():
    _run("""
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs import get_config
    from repro.dist.flash import causal_attention
    from repro.dist.sharding import use_mesh

    cfg = get_config("qwen2-7b").reduced()   # 4 heads → seq strategy on 8
    cfg = dataclasses.replace(cfg, num_heads=6, num_kv_heads=2,
                              attn_block_q=16, attn_block_k=16)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, hd = 2, 64, cfg.head_dim
    q = jax.random.normal(ks[0], (b, s, 6, hd))
    k = jax.random.normal(ks[1], (b, s, 2, hd))
    v = jax.random.normal(ks[2], (b, s, 2, hd))

    ref = causal_attention(q, k, v, cfg=cfg)            # no mesh

    mesh = jax.make_mesh((2, 4), ("data", "model"))     # 6 % 4 != 0 → seq
    with use_mesh(mesh):
        got = jax.jit(lambda a, b_, c: causal_attention(a, b_, c, cfg=cfg))(
            q, k, v)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               atol=2e-4, rtol=2e-4)

    # grads through the shard_map path
    def loss(a, b_, c):
        return jnp.sum(jnp.sin(causal_attention(a, b_, c, cfg=cfg)))
    g_ref = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    with use_mesh(mesh):
        g_got = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))(q, k, v)
    for a, b_ in zip(g_ref, g_got):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=5e-4, rtol=5e-4)
    print("PASS")
    """)


def test_flash_decode_lse_combine_parity():
    _run("""
    import jax, jax.numpy as jnp, numpy as np, dataclasses
    from repro.configs import get_config
    from repro.dist.flash import decode_update_and_attend
    from repro.dist.sharding import use_mesh

    cfg = get_config("llama3.2-3b").reduced()
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    b, smax, h, kh, hd = 4, 64, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jax.random.normal(ks[0], (b, 1, h, hd))
    kn = jax.random.normal(ks[1], (b, 1, kh, hd))
    vn = jax.random.normal(ks[2], (b, 1, kh, hd))
    kc = jax.random.normal(ks[3], (b, kh, smax, hd))   # head-major caches
    vc = jax.random.normal(ks[4], (b, kh, smax, hd))
    cur = jnp.asarray(37, jnp.int32)

    o_ref, kc_ref, vc_ref = decode_update_and_attend(
        q, kn, vn, kc, vc, cur, cfg=cfg)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_mesh(mesh):
        o, kc2, vc2 = jax.jit(lambda *a: decode_update_and_attend(
            *a, cfg=cfg))(q, kn, vn, kc, vc, cur)
    np.testing.assert_allclose(np.asarray(o_ref), np.asarray(o),
                               atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(kc_ref), np.asarray(kc2),
                               atol=1e-6)
    print("PASS")
    """)


def test_param_shardings_cover_all_archs():
    _run("""
    import jax
    from repro.configs import all_arch_names, get_config
    from repro.dist.sharding import ShardCtx, param_shardings, use_mesh
    from repro.launch.specs import params_only_specs

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ShardCtx(mesh)
    for arch in all_arch_names():
        cfg = get_config(arch)
        shapes = params_only_specs(cfg)
        sh = param_shardings(shapes, ctx)
        # every leaf gets a sharding whose spec divides its shape
        def check(path, leaf, s):
            for dim, axes in zip(leaf.shape, s.spec):
                if axes is None:
                    continue
                names = axes if isinstance(axes, tuple) else (axes,)
                total = 1
                for n in names:
                    total *= mesh.shape[n]
                assert dim % total == 0, (arch, path, leaf.shape, s.spec)
        jax.tree_util.tree_map_with_path(
            lambda p, l, s: check(p, l, s), shapes, sh)
    print("PASS")
    """)


def test_train_step_sharded_matches_single_device():
    _run("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.models.model import LanguageModel
    from repro.optim import OptimizerConfig
    from repro.train.steps import init_train_state, make_train_step
    from repro.dist.sharding import use_mesh
    from repro.data import SyntheticTokens

    cfg = get_config("llama3.2-3b").reduced()
    model = LanguageModel(cfg)
    oc = OptimizerConfig(peak_lr=1e-3, warmup_steps=2, total_steps=50)
    data = SyntheticTokens(cfg.vocab_size, batch=4, seq=32, seed=5)
    step = make_train_step(model, oc)

    s1 = init_train_state(model, jax.random.PRNGKey(0), oc)
    b = {k: jnp.asarray(v) for k, v in data.get(0).items()}
    s1b, m1 = jax.jit(step)(s1, b)

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    s2 = init_train_state(model, jax.random.PRNGKey(0), oc)
    with use_mesh(mesh):
        s2b, m2 = jax.jit(step)(s2, b)

    assert abs(float(m1["ce_loss"]) - float(m2["ce_loss"])) < 1e-3
    for a, c in zip(jax.tree_util.tree_leaves(s1b["params"]),
                    jax.tree_util.tree_leaves(s2b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   atol=3e-4, rtol=3e-4)
    print("PASS")
    """)
