"""Batched serving example across three architecture families.

Prefills a batch of prompts and decodes tokens for a dense (llama-style),
an SSM (mamba2 — O(1) decode state), and a hybrid (zamba2) reduced model;
prints per-family tokens/s.  The decode KV caches are head-major
partitioned blocks (§6 on the cache; see DESIGN.md).

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import LanguageModel

B, PROMPT, GEN = 4, 24, 12


def serve(arch: str) -> None:
    import dataclasses
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, param_dtype=cfg.dtype)
    model = LanguageModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(key, (B, PROMPT), 0,
                                          cfg.vocab_size)}
    logits, cache = jax.jit(model.prefill)(params, batch)

    def grow(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name in ("k", "v", "c_kv", "k_rope"):
            pad = [(0, 0)] * leaf.ndim
            pad[-2] = (0, GEN)
            return jnp.pad(leaf, pad)
        return leaf
    cache = jax.tree_util.tree_map_with_path(grow, cache)

    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    # warmup/compile
    _, cache = decode(params, cache, tok, jnp.asarray(PROMPT, jnp.int32))
    t0 = time.perf_counter()
    for i in range(1, GEN):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(PROMPT + i, jnp.int32))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    state_note = ""
    if cfg.family == "ssm":
        state_note = " (cache size independent of context — SSD state only)"
    print(f"{arch:16s} [{cfg.family:6s}] {B * (GEN - 1) / dt:7.1f} tok/s"
          f"{state_note}")


if __name__ == "__main__":
    for arch in ("llama3.2-3b", "mamba2-1.3b", "zamba2-1.2b"):
        serve(arch)
