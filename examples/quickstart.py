"""Quickstart: the OCR-extensions runtime in five minutes.

Walks the paper's four extensions with the public API:
  §3 local identifiers (futures)    §4 labeled GUID maps
  §5 file-mapped data blocks        §6 data block partitioning

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (DB_COPY_PARTITION, DB_PROP_NO_ACQUIRE, DbMode,
                        EDT_PROP_LID, IdType, NULL_GUID, Runtime,
                        UNINITIALIZED_GUID, id_type, spawn_main)


def demo_lids():
    """§3: creating remote tasks without blocking round-trips."""
    rt = Runtime(num_nodes=4, net_latency=5.0)

    def worker(paramv, depv, api):
        return NULL_GUID

    def main(paramv, depv, api):
        tmpl = api.edt_template_create(worker, 0, 1)
        # LID creation returns immediately — a *future* for the GUID
        task, _ = api.edt_create(tmpl, depv=[UNINITIALIZED_GUID],
                                 props=EDT_PROP_LID, placement=2)
        print(f"  created remote task, id type = {id_type(task).value}")
        # API calls on the LID are deferred and patched on resolution
        api.add_dependence(NULL_GUID, task, 0, DbMode.NULL)
        # ocrGetGuid is the one blocking call, if you really need the GUID
        guid = api.get_guid(task)
        print(f"  resolved to {guid}")
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    print(f"  stats: msgs={stats.messages_sent} "
          f"blocking={stats.blocking_roundtrips} "
          f"deferred={stats.messages_deferred}")


def demo_partitioning():
    """§6: disjoint EW partitions execute in parallel."""
    rt = Runtime()
    out = {}

    def work(paramv, depv, api):
        depv[0].ptr.view(np.uint32)[:] *= np.uint32(paramv[0])
        api.db_destroy(depv[0].guid)
        return NULL_GUID

    def finish(paramv, depv, api):
        out["sum"] = int(depv[0].ptr.view(np.uint32).sum())
        return NULL_GUID

    def main(paramv, depv, api):
        db, ptr = api.db_create(1024 * 4)
        ptr.view(np.uint32)[:] = 1
        api.db_release(db)
        parts = api.db_partition(db, [(0, 2048), (2048, 2048)])
        tmpl = api.edt_template_create(work, 1, 1)
        api.edt_create(tmpl, paramv=[2], depv=[parts[0]],
                       dep_modes=[DbMode.EW], duration=10)
        api.edt_create(tmpl, paramv=[6], depv=[parts[1]],
                       dep_modes=[DbMode.EW], duration=10)
        # the parent is quiescent until both partitions are destroyed
        ftmpl = api.edt_template_create(finish, 0, 1)
        api.edt_create(ftmpl, depv=[db], dep_modes=[DbMode.RO])
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    print(f"  sum = {out['sum']} (512·2 + 512·6 = 4096); "
          f"makespan = {stats.makespan:.0f} (parallel, not 2×10 serial)")


def demo_fileio():
    """§5: file-mapped chunks with dirty write-back."""
    path = tempfile.mktemp()
    np.arange(64, dtype=np.uint32).tofile(path)
    rt = Runtime()

    def double(paramv, depv, api):
        depv[0].ptr.view(np.uint32)[:] *= 2
        api.db_destroy(depv[0].guid)         # EW ⇒ write-back on destroy
        return NULL_GUID

    def main(paramv, depv, api):
        f, desc = api.file_open(path, "rb+")

        def after_open(pv, dv, api2):        # runs once the file is open
            size = api2.file_get_size(dv[0].ptr)
            fg = api2.file_get_guid(dv[0].ptr)
            tmpl2 = api2.edt_template_create(double, 0, 1)
            for off in (0, size // 2):       # two disjoint chunks
                chunk = api2.file_get_chunk(fg, off, size // 2)
                api2.edt_create(tmpl2, depv=[chunk], dep_modes=[DbMode.EW])
            api2.file_release(fg)
            return NULL_GUID

        tmpl = api.edt_template_create(after_open, 0, 1)
        api.edt_create(tmpl, depv=[desc])
        return NULL_GUID

    spawn_main(rt, main)
    rt.run()
    data = np.fromfile(path, np.uint32)
    print(f"  file doubled in 2 parallel chunks: ok={np.array_equal(data, np.arange(64, dtype=np.uint32) * 2)}")
    os.unlink(path)


def demo_zero_copy():
    """§6.3: ocrDbCopy with DB_COPY_PARTITION is zero-copy."""
    rt = Runtime()

    def main(paramv, depv, api):
        block, ptr = api.db_create(1024)
        ptr[:] = 7
        api.db_release(block)
        view, _ = api.db_create(512, props=DB_PROP_NO_ACQUIRE)
        api.db_copy(view, 0, block, 256, 512, DB_COPY_PARTITION)
        return NULL_GUID

    spawn_main(rt, main)
    stats = rt.run()
    print(f"  zero-copy bytes={stats.bytes_zero_copy} copied={stats.bytes_copied}")


if __name__ == "__main__":
    print("§3 local identifiers:")
    demo_lids()
    print("§6 partitioning:")
    demo_partitioning()
    print("§5 file IO:")
    demo_fileio()
    print("§6.3 zero-copy:")
    demo_zero_copy()
    print("done.")
