"""The paper's §4 wavefront as a *pipeline-parallel* schedule on real compute.

A 2-D labeled-GUID map over (microbatch × stage) where each cell runs one
jitted transformer-stage forward and satisfies the pre-slots of its right
(next microbatch, same stage) and down (same microbatch, next stage)
neighbours — the exact dependence structure of GPipe/1F1B, driven by the
paper's creator-function mechanism.

Run:  PYTHONPATH=src python examples/wavefront_pipeline.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import (DbMode, EDT_PROP_MAPPED, NULL_GUID, Runtime,
                        UNINITIALIZED_GUID, spawn_main)
from repro.models import blocks
from repro.models.layers import cast_params

MICRO = 4      # microbatches
STAGES = 3     # pipeline stages (layers per stage: 1 smoke layer)
B, S = 2, 32

cfg = get_config("llama3.2-3b").reduced()
key = jax.random.PRNGKey(0)
stage_params = [blocks.decoder_layer_init(jax.random.fold_in(key, i), cfg,
                                          "dense") for i in range(STAGES)]
positions = jnp.arange(S)[None, :]


@jax.jit
def stage_fwd(params, x):
    y, _ = blocks.decoder_layer_train(params, x, cfg, positions, "dense")
    return y


def main() -> None:
    rt = Runtime(num_nodes=STAGES, net_latency=0.5)
    # activations flowing between cells, keyed by (micro, stage)
    acts = {(m, -1): jax.random.normal(jax.random.fold_in(key, 100 + m),
                                       (B, S, cfg.d_model)) * 0.02
            for m in range(MICRO)}
    done = []
    state = {}

    def creator(ctx, lid, index, paramv, guidv):
        m, s = index % MICRO, index // MICRO
        deps = [NULL_GUID if m == 0 else UNINITIALIZED_GUID,
                NULL_GUID if s == 0 else UNINITIALIZED_GUID]
        ctx.edt_create(guidv[0], paramv=[index], depv=deps,
                       props=EDT_PROP_MAPPED, placement=s % STAGES)

    def cell(paramv, depv, api):
        idx = paramv[0]
        m, s = idx % MICRO, idx // MICRO
        acts[(m, s)] = stage_fwd(stage_params[s], acts[(m, s - 1)])
        done.append((m, s, api.rt.clock))
        if m + 1 < MICRO:                   # free the right neighbour
            t = api.map_get(state["map"], (m + 1) + s * MICRO)
            api.add_dependence(NULL_GUID, t, 0, DbMode.NULL)
        if s + 1 < STAGES:                  # free the down neighbour
            t = api.map_get(state["map"], m + (s + 1) * MICRO)
            api.add_dependence(NULL_GUID, t, 1, DbMode.NULL)
        return NULL_GUID

    def main_edt(paramv, depv, api):
        tmpl = api.edt_template_create(cell, 1, 2)
        state["map"] = api.map_create(MICRO * STAGES, creator, guidv=[tmpl])
        api.map_get(state["map"], 0)        # seed cell (0, 0)
        return NULL_GUID

    spawn_main(rt, main_edt)
    stats = rt.run()

    print(f"executed {len(done)} cells; virtual makespan={stats.makespan:.1f} "
          f"(critical path = {MICRO + STAGES - 1} waves)")
    print("wavefront order (micro, stage, t):")
    for m, s, t in done:
        print(f"  m{m} s{s} @ {t:5.1f}")

    # numerics check vs running the stages sequentially
    for m in range(MICRO):
        x = acts[(m, -1)]
        for s in range(STAGES):
            x = stage_fwd(stage_params[s], x)
        err = float(jnp.max(jnp.abs(x - acts[(m, STAGES - 1)])))
        assert err == 0.0, err
    print("pipeline output == sequential output (exact)")


if __name__ == "__main__":
    main()
