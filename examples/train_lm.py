"""End-to-end driver: train a reduced LM for a few hundred steps through the
fault-tolerant trainer, with §5 chunked checkpoints, a mid-run simulated
node failure + restart, then greedy-decode from the trained model.

Run:  PYTHONPATH=src python examples/train_lm.py            (~3 min CPU)
"""
import os
import shutil
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import ckpt
from repro.configs import get_config
from repro.data import SyntheticTokens
from repro.models.model import LanguageModel
from repro.optim import OptimizerConfig
from repro.train.trainer import Trainer, TrainerConfig

STEPS = 240
FAIL_AT = 150

def main() -> None:
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")
    cfg = get_config("llama3.2-3b").reduced()
    model = LanguageModel(cfg)
    oc = OptimizerConfig(peak_lr=5e-3, warmup_steps=10, total_steps=STEPS,
                         weight_decay=0.0)
    data = SyntheticTokens(cfg.vocab_size, batch=16, seq=32, seed=11,
                           mode="markov")

    # ---- phase 1: train with periodic §5 chunked checkpoints; a simulated
    # fail-stop kills the run at step FAIL_AT
    tc = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=50, async_ckpt=False,
                       fail_at_step=FAIL_AT)
    tr = Trainer(model, oc, data, tc)
    state = tr.init_or_restore(jax.random.PRNGKey(0))
    tr.run(state, STEPS)
    print(f"run 1 died at step {max(h['step'] for h in tr.history)} "
          f"(injected failure); last committed ckpt = "
          f"step_{ckpt.latest_step(ckpt_dir)}")

    # ---- phase 2: restart from the last committed manifest and finish
    tc2 = TrainerConfig(ckpt_dir=ckpt_dir, ckpt_every=50, async_ckpt=False)
    tr2 = Trainer(model, oc, data, tc2)
    state = tr2.init_or_restore(jax.random.PRNGKey(0))
    print(f"restarted from step {tr2.start_step}")
    state = tr2.run(state, STEPS - tr2.start_step)
    hist = tr2.history
    print(f"final: step {hist[-1]['step']} "
          f"loss={hist[-1]['ce_loss']:.3f} acc={hist[-1]['accuracy']:.3f}")

    # ---- phase 3: serve — the model should have learned the affine chain
    params = state["params"]
    t0 = 7
    toks = [t0]
    for _ in range(6):
        toks.append((toks[-1] * 31 + 7) % cfg.vocab_size)
    tokens = jnp.asarray([toks[:2]], jnp.int32)
    logits, cache = jax.jit(model.prefill)(params, {"tokens": tokens})
    cache = jax.tree_util.tree_map(
        lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 2) + [(0, 8), (0, 0)]),
        cache)
    cur, tok = 2, jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    preds = [int(tok[0, 0])]
    decode = jax.jit(model.decode_step, donate_argnums=(1,))
    for i in range(4):
        logits, cache = decode(params, cache, tok, jnp.asarray(cur + i))
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        preds.append(int(tok[0, 0]))
    want = toks[2:7]
    hits = sum(p == w for p, w in zip(preds, want))
    print(f"greedy decode follows the learned chain: {hits}/5 "
          f"(pred={preds}, want={want})")
    shutil.rmtree(ckpt_dir)


if __name__ == "__main__":
    main()
