"""Render EXPERIMENTS.md tables from results/dryrun.json (+ baseline)."""
import json
import os
import sys

ROOT = os.path.join(os.path.dirname(__file__), "..")


def table(cells, mesh):
    lines = [
        "| arch × shape | compute s | memory s | collective s | dominant "
        "| useful | HBM fit (args+temp GB / 16) |",
        "|---|---|---|---|---|---|---|",
    ]
    for k in sorted(cells):
        v = cells[k]
        if v["mesh"] != mesh:
            continue
        name = f"{v['arch']} × {v['shape']}"
        if v["status"] == "skipped":
            lines.append(f"| {name} | — | — | — | skipped | — | "
                         f"{v.get('reason','')[:46]} |")
            continue
        r = v["roofline"]
        m = v["memory"]
        tot = (m["temp_size_in_bytes"] + m["argument_size_in_bytes"]) / 1e9
        fit = f"{tot:.1f} {'✓' if tot <= 16 else '✗'}"
        lines.append(
            f"| {name} | {r['compute_s']:.4f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.4f} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {fit} |")
    return "\n".join(lines)


def main():
    with open(os.path.join(ROOT, "results", "dryrun.json")) as f:
        cells = json.load(f)["cells"]
    print("### Single-pod (16×16 = 256 chips)\n")
    print(table(cells, "16x16"))
    print("\n### Multi-pod (2×16×16 = 512 chips)\n")
    print(table(cells, "2x16x16"))

    # collective breakdown for the three hillclimb cells
    print("\n### Collective breakdown (hillclimb cells)\n")
    print("| cell | all-gather GB | all-reduce GB | reduce-scatter GB | "
          "all-to-all GB |")
    print("|---|---|---|---|---|")
    for k in ("deepseek-v2-236b|train_4k|16x16",
              "smollm-360m|train_4k|16x16",
              "zamba2-1.2b|long_500k|16x16"):
        v = cells[k]
        c = v["collectives"]["per_kind"]
        print(f"| {k} | {c['all-gather']/1e9:.1f} | "
              f"{c['all-reduce']/1e9:.1f} | {c['reduce-scatter']/1e9:.1f} | "
              f"{c['all-to-all']/1e9:.1f} |")


if __name__ == "__main__":
    main()
