"""Fail-soft perf-trajectory diff: fresh BENCH_*.json vs committed snapshots.

Compares every ``makespan*`` key (deterministic virtual time — noise-free,
so a tight threshold is meaningful), monitoring-registry histogram
quantiles (``*_hist_*`` / ``*.p50`` / ``*.p99`` — fixed bucket edges, so
likewise deterministic and lower-is-better) and, more loosely, ``*_ms``
wall-time keys.  A regression beyond the threshold emits a GitHub Actions warning
annotation (``::warning::``) and is reported in the exit summary, but the
exit code stays 0 — perf drift warns, it does not block (ROADMAP "perf
trajectory").

``--hard SECTION[,SECTION...]`` opts named sections (e.g. ``flash``) into
fail-HARD mode: any key of theirs regressing beyond 20% exits non-zero.
Use it for sections whose snapshot was measured on the CI runner class
itself (the flash kernels-vs-twin sweep), where a >20% drift means a
kernel or planner change, not runner noise.

Usage:
  python scripts/bench_diff.py --new . --old benchmarks/snapshots
  python scripts/bench_diff.py --new bench-out --hard flash
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

MAKESPAN_THRESHOLD = 0.20      # virtual time: >20% regression warns
WALL_THRESHOLD = 1.00          # wall time: noisy CI runners, warn at 2x
HARD_THRESHOLD = 0.20          # --hard sections: >20% regression FAILS


def compare(old: dict, new: dict, name: str,
            hard: bool = False) -> list[str]:
    warnings = []
    for key, ov in sorted(old.items()):
        nv = new.get(key)
        if not isinstance(ov, (int, float)) or not isinstance(nv, (int, float)):
            continue
        if ov <= 0 or nv <= 0:
            continue
        # throughput keys are HIGHER-is-better: a regression is the ratio
        # *dropping*, not rising (check before the generic *_s suffix —
        # tok_per_s ends with _s too)
        higher_better = key.endswith("_per_s") or "_per_s_" in key
        if hard:
            threshold = HARD_THRESHOLD
        elif higher_better:
            threshold = MAKESPAN_THRESHOLD   # virtual time: deterministic
        elif key.startswith(("makespan", "p50_", "p99_")):
            threshold = MAKESPAN_THRESHOLD   # latency percentiles likewise
        elif "_hist_" in key or key.endswith((".p50", ".p99")):
            # monitoring-registry histogram quantiles (fixed bucket edges,
            # virtual time): deterministic LOWER-is-better, tight threshold
            threshold = MAKESPAN_THRESHOLD
        elif key.endswith("_bytes") or "_bytes_" in key:
            # byte counters (e.g. MoE a2a exchange volume, HLO collective
            # traffic) are LOWER-is-better and deterministic — derived from
            # compiled HLO, not timers — so they get the tight threshold
            threshold = MAKESPAN_THRESHOLD
        elif key.endswith("_ms") or key.endswith("_s"):
            threshold = WALL_THRESHOLD
        else:
            continue               # counters: tracked, not thresholded
        ratio = nv / ov
        if higher_better:
            if ratio < 1.0 - threshold:
                warnings.append(
                    f"{name}:{key} regressed {ratio:.2f}x (throughput "
                    f"{ov:.6g} -> {nv:.6g}, threshold -{threshold:.0%}"
                    f"{', HARD' if hard else ''})")
        elif ratio > 1.0 + threshold:
            warnings.append(
                f"{name}:{key} regressed {ratio:.2f}x "
                f"({ov:.6g} -> {nv:.6g}, threshold +{threshold:.0%}"
                f"{', HARD' if hard else ''})")
    return warnings


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--new", default=".", help="dir with fresh BENCH_*.json")
    ap.add_argument("--old", default="benchmarks/snapshots",
                    help="dir with committed snapshots")
    ap.add_argument("--hard", default="", metavar="SECTION[,SECTION...]",
                    help="sections (short names, e.g. 'flash') whose "
                         f"regressions beyond {HARD_THRESHOLD:.0%} exit "
                         "non-zero instead of warning")
    args = ap.parse_args()
    hard_sections = {s.strip() for s in args.hard.split(",") if s.strip()}

    warnings = []
    hard_failures = []
    compared = 0
    old_names = {os.path.basename(p) for p in
                 glob.glob(os.path.join(args.old, "BENCH_*.json"))}
    new_names = {os.path.basename(p) for p in
                 glob.glob(os.path.join(args.new, "BENCH_*.json"))}
    for name in sorted(old_names):
        new_path = os.path.join(args.new, name)
        section = name[len("BENCH_"):-len(".json")]
        if name not in new_names:
            if section in hard_sections:
                hard_failures.append(f"{name} missing from fresh run")
            print(f"::warning::bench_diff: {name} missing from fresh run")
            continue
        with open(os.path.join(args.old, name)) as f:
            old = json.load(f)
        with open(new_path) as f:
            new = json.load(f)
        compared += 1
        hard = section in hard_sections
        found = compare(old, new, name, hard=hard)
        warnings.extend(found)
        if hard:
            hard_failures.extend(found)

    # a fresh section with no committed snapshot is NOT silently skipped:
    # a newly added bench must enter the perf trajectory, so the unmatched
    # sections are listed fail-soft until their snapshot is committed
    unmatched = sorted(new_names - old_names)
    for name in unmatched:
        print(f"::warning::bench_diff: {name} has no snapshot in "
              f"{args.old} — commit one so the new section joins the "
              f"perf trajectory")

    print(f"bench_diff: compared {compared} snapshot(s), "
          f"{len(warnings)} regression(s), {len(unmatched)} "
          f"section(s) without a snapshot"
          + (f" ({', '.join(unmatched)})" if unmatched else ""))
    for w in warnings:
        print(f"::warning::{w}")
        print(f"  {w}", file=sys.stderr)
    # fail-soft by default: warnings annotate the run, the job stays
    # green — EXCEPT --hard sections, whose regressions block
    if hard_failures:
        print(f"::error::bench_diff: {len(hard_failures)} hard "
              f"regression(s) in --hard section(s)")
        sys.exit(1)


if __name__ == "__main__":
    main()
