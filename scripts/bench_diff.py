"""Fail-soft perf-trajectory diff: fresh BENCH_*.json vs committed snapshots.

Compares every ``makespan*`` key (deterministic virtual time — noise-free,
so a tight threshold is meaningful) and, more loosely, ``*_ms`` wall-time
keys.  A regression beyond the threshold emits a GitHub Actions warning
annotation (``::warning::``) and is reported in the exit summary, but the
exit code stays 0 — perf drift warns, it does not block (ROADMAP "perf
trajectory").

Usage:
  python scripts/bench_diff.py --new . --old benchmarks/snapshots
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

MAKESPAN_THRESHOLD = 0.20      # virtual time: >20% regression warns
WALL_THRESHOLD = 1.00          # wall time: noisy CI runners, warn at 2x


def compare(old: dict, new: dict, name: str) -> list[str]:
    warnings = []
    for key, ov in sorted(old.items()):
        nv = new.get(key)
        if not isinstance(ov, (int, float)) or not isinstance(nv, (int, float)):
            continue
        if ov <= 0 or nv <= 0:
            continue
        if key.startswith("makespan"):
            threshold = MAKESPAN_THRESHOLD
        elif key.endswith("_ms") or key.endswith("_s"):
            threshold = WALL_THRESHOLD
        else:
            continue               # counters: tracked, not thresholded
        ratio = nv / ov
        if ratio > 1.0 + threshold:
            warnings.append(
                f"{name}:{key} regressed {ratio:.2f}x "
                f"({ov:.6g} -> {nv:.6g}, threshold +{threshold:.0%})")
    return warnings


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--new", default=".", help="dir with fresh BENCH_*.json")
    ap.add_argument("--old", default="benchmarks/snapshots",
                    help="dir with committed snapshots")
    args = ap.parse_args()

    warnings = []
    compared = 0
    old_names = {os.path.basename(p) for p in
                 glob.glob(os.path.join(args.old, "BENCH_*.json"))}
    new_names = {os.path.basename(p) for p in
                 glob.glob(os.path.join(args.new, "BENCH_*.json"))}
    for name in sorted(old_names):
        new_path = os.path.join(args.new, name)
        if name not in new_names:
            print(f"::warning::bench_diff: {name} missing from fresh run")
            continue
        with open(os.path.join(args.old, name)) as f:
            old = json.load(f)
        with open(new_path) as f:
            new = json.load(f)
        compared += 1
        warnings.extend(compare(old, new, name))

    # a fresh section with no committed snapshot is NOT silently skipped:
    # a newly added bench must enter the perf trajectory, so the unmatched
    # sections are listed fail-soft until their snapshot is committed
    unmatched = sorted(new_names - old_names)
    for name in unmatched:
        print(f"::warning::bench_diff: {name} has no snapshot in "
              f"{args.old} — commit one so the new section joins the "
              f"perf trajectory")

    print(f"bench_diff: compared {compared} snapshot(s), "
          f"{len(warnings)} regression(s), {len(unmatched)} "
          f"section(s) without a snapshot"
          + (f" ({', '.join(unmatched)})" if unmatched else ""))
    for w in warnings:
        print(f"::warning::{w}")
        print(f"  {w}", file=sys.stderr)
    # fail-soft: warnings annotate the run; the job stays green


if __name__ == "__main__":
    main()
